"""Tenant-side walkthrough of the SA service (DESIGN.md §18).

Starts an in-process StudyServer over the pathology workflow, serves it
on an ephemeral TCP port, then drives it as two tenants would:

* tenant ``alice`` submits a MOAT study and polls it to completion;
* tenant ``bob`` submits the *same spec* concurrently — the content
  signature matches, so the Manager executes the tasks once and both
  jobs observe the same objective vector;
* ``bob`` then submits a wide grid sweep and cancels it mid-flight,
  which frees the workers without touching alice's results.

Run:  PYTHONPATH=src python examples/sa_client.py
"""

from __future__ import annotations

import threading

from repro.app.pipeline import pathology_service_build
from repro.service import ServiceClient, StudyServer, StudySpec


def main() -> None:
    server = StudyServer.from_build(
        pathology_service_build,
        {"size": 32, "n_tiles": 2, "seed": 0},
        n_workers=2,
    )
    addr = server.serve_background("127.0.0.1:0")
    print(f"server on {addr}")
    try:
        alice = ServiceClient(addr, "alice")
        bob = ServiceClient(addr, "bob")

        moat = StudySpec(sampler="moat", n_trajectories=2, seed=7)
        job_a = alice.submit(moat)
        job_b = bob.submit(moat)  # identical signature: executes once
        print(f"alice submitted {job_a}; bob submitted {job_b}")

        res_a = alice.result(job_a, timeout=300)
        res_b = bob.result(job_b, timeout=300)
        assert res_a["state"] == res_b["state"] == "DONE", (res_a, res_b)
        obj_a = res_a["result"]["objective"]
        obj_b = res_b["result"]["objective"]
        assert obj_a == obj_b, "shared execution must agree bit-for-bit"
        print(f"moat objective ({len(obj_a)} runs): {obj_a[:4]} ...")
        print(
            "tasks executed — alice's job: "
            f"{res_a['result']['tasks_executed']}, bob's (shared): "
            f"{res_b['result']['tasks_executed']}"
        )

        sweep = StudySpec(sampler="grid", names=["T1", "FH", "RC"])
        job_c = bob.submit(sweep)
        # cancel from a second thread while the sweep is mid-flight
        threading.Timer(0.3, lambda: bob.cancel(job_c)).start()
        res_c = bob.result(job_c, timeout=300)
        print(f"sweep {job_c} ended {res_c['state']}")

        stats = alice.server_stats()
        print(
            "server: "
            f"{stats['registry']['jobs']} jobs, cache hits "
            f"{stats['cache']['hits']}, tenant dispatch "
            f"{stats['scheduler'].get('tenant_dispatch')}"
        )
        alice.close()
        bob.close()
    finally:
        server.close()


if __name__ == "__main__":
    main()

"""End-to-end LM training driver with fault tolerance.

Trains a reduced-config model on the deterministic synthetic pipeline,
checkpointing asynchronously every --ckpt-every steps, and AUTO-RESUMES from
the latest checkpoint (kill it mid-run and restart to see). At production
scale the same step function runs under the (16,16)/(2,16,16) meshes via
launch/dryrun.py shardings.

    PYTHONPATH=src python examples/train_lm.py --arch yi_6b --steps 30
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config, reduced_config
from repro.data import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import OptConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    shape = dataclasses.replace(
        SHAPES["train_4k"], seq_len=args.seq, global_batch=args.batch
    )
    pipe = TokenPipeline(cfg, shape, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    start = 0
    if ckpt.latest_step() is not None:  # fault-tolerant auto-resume
        (params, opt_state), meta = ckpt.restore((params, opt_state))
        pipe.restore(meta["pipeline"])
        start = meta["pipeline"]["step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, None, OptConfig(lr=1e-3, warmup_steps=10)))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        pipe.step = step + 1
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            ckpt.save_async(
                step + 1, (params, opt_state), metadata={"pipeline": pipe.state()}
            )
        if step % 5 == 0 or step + 1 == args.steps:
            print(
                f"step {step:4d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t0)/(step-start+1):.2f}s/step)"
            )
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

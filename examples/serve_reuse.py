"""The paper's technique as an LM-serving feature (core/sa_serve.py).

An SA study over a serving pipeline's parameters — prompt choice, decoding
controls, acceptance threshold — executed with reuse-tree merging + RMSR
memory-bounded scheduling: parameter sets sharing a prompt share ONE prefill
(derived prefix caching); the activePaths bound caps live KV caches against
the HBM budget.

    PYTHONPATH=src python examples/serve_reuse.py
"""

import itertools

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.sa_serve import run_sa_serve
from repro.models import init_params


def main() -> None:
    cfg = reduced_config(get_config("gemma3_1b"))
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = {
        pid: rng.integers(0, cfg.vocab_size, (1, 12)).astype(np.int32)
        for pid in range(3)
    }
    # the SA grid: 3 prompts × 2 penalties × 2 top-k × 3 thresholds = 36 sets
    sets = [
        tuple(sorted({
            "prompt_id": pid, "rep_penalty": rp, "top_k": tk, "threshold": th,
        }.items()))
        for pid, rp, tk, th in itertools.product(
            range(3), (1.0, 1.3), (4, 16), (0.1, 0.3, 0.5)
        )
    ]
    out = run_sa_serve(
        cfg, params, prompts, sets, gen_len=6, max_len=32,
        hbm_budget_bytes=1 << 28, policy="rmsr",
    )
    print(
        f"{len(sets)} parameter sets -> {out['tasks_executed']}/{out['tasks_total']} "
        f"pipeline tasks executed ({out['reuse_fraction']*100:.0f}% reuse): "
        f"3 prefills, {out['tasks_executed']-3-len(sets)//1} generates deduped"
    )
    print(f"engine(rmsr) active_paths={out['active_paths']} "
          f"peak={out['peak_bytes']/1e6:.1f}MB")
    rates = out["accept_rate"]
    print("accept rates by (prompt, rp, top_k, thr):")
    for rid, ps in enumerate(sets[:6]):
        print(f"  {dict(ps)} -> {rates[rid]:.2f}")


if __name__ == "__main__":
    main()

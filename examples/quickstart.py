"""Quickstart: a parameter sensitivity analysis with computation reuse.

Runs a small MOAT screening study over the pathology pipeline on a synthetic
tile, executes it with RMSR (maximal merging, memory-bounded depth-first
scheduling), and prints parameter importance plus the reuse accounting.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.app import run_study, synthetic_tile
from repro.core import ParamSpace, moat_indices, morris_trajectories

SPACE = ParamSpace.from_dict(
    {
        "B": [210, 230], "G": [210, 230], "R": [210, 230],
        "T1": [2.5, 5.0], "T2": [2.5, 5.0],
        "G1": [20, 40], "G2": [10, 20],
        "minS": [2, 10], "maxS": [900, 1200],
        "minSPL": [5, 20], "minSS": [2, 10], "maxSS": [900, 1200],
        "FH": [4, 8], "RC": [4, 8], "WConn": [4, 8],
    }
)


def main() -> None:
    tile = synthetic_tile(96, 96, seed=7)
    sets, moves = morris_trajectories(SPACE, 3, seed=0)
    print(f"MOAT study: {len(sets)} runs over {SPACE.dim} parameters")

    out = run_study(tile, sets, strategy="rmsr", active_paths=4)
    print(
        f"reuse: {out['tasks_executed']}/{out['tasks_total']} tasks executed "
        f"({out['reuse_fraction']*100:.1f}% eliminated), "
        f"wall {out['wall_seconds']:.1f}s"
    )

    res = moat_indices(SPACE, [1.0 - d for d in out["dice"]], moves)
    print("\nparameter importance (mu*, descending):")
    for name in res.ranking()[:8]:
        print(f"  {name:8s} mu*={res.mu_star[name]:.4f} sigma={res.sigma[name]:.4f}")


if __name__ == "__main__":
    main()

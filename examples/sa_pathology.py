"""End-to-end driver: distributed SA study over multiple tiles.

The Manager dispatches merged-stage buckets demand-driven to Workers
(threads here; nodes in production), with straggler backup-tasks enabled.
Compares no-reuse vs RMSR wall-clock on real JAX execution and computes
Spearman correlations of each parameter against the Dice difference.

    PYTHONPATH=src python examples/sa_pathology.py [--runs 48] [--tiles 2]
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, TABLE1_SPACE
from repro.core import (
    Workflow,
    correlation_indices,
    dice,
    morris_trajectories,
    rtma_buckets,
)
from repro.core.params import ParamSpace
from repro.core.rmsr import execute_merged_stage
from repro.runtime import Manager, run_study_distributed

SPACE = ParamSpace.from_dict(
    {
        "B": [210, 220, 230], "G": [210, 220, 230], "R": [210, 220, 230],
        "T1": [2.5, 5.0, 7.5], "T2": [2.5, 5.0, 7.5],
        "G1": [20, 40, 60], "G2": [10, 20, 30],
        "minS": [2, 10, 20], "maxS": [900, 1200, 1500],
        "minSPL": [5, 20, 40], "minSS": [2, 10, 20], "maxSS": [900, 1200, 1500],
        "FH": [4, 8], "RC": [4, 8], "WConn": [4, 8],
    }
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=48)
    ap.add_argument("--tiles", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--size", type=int, default=72)
    args = ap.parse_args()

    sets, _ = morris_trajectories(SPACE, max(1, args.runs // (SPACE.dim + 1)), seed=3)
    sets = sets[: args.runs]
    wf = build_workflow(args.size, args.size)
    norm, seg = wf.stages
    ref = TABLE1_SPACE.default()

    all_scores = {rid: [] for rid in range(len(sets))}
    t_naive = t_rmsr = 0.0
    for tidx in range(args.tiles):
        tile = synthetic_tile(args.size, args.size, seed=tidx)
        state = norm.tasks[0].fn({"raw": jnp.asarray(tile)})
        insts = Workflow(stages=(seg,)).instantiate(list(sets))[seg.name]

        # reference mask under default parameters
        ref_state = state
        d = dict(ref)
        for t in seg.tasks:
            ref_state = t.fn(ref_state, **{k: d[k] for k in t.param_names})
        ref_mask = ref_state["mask"]

        # naive: every instance independently
        t0 = time.perf_counter()
        for inst in insts[: max(4, len(insts) // 8)]:  # subsample for timing
            s = state
            dd = dict(inst.params)
            for t in seg.tasks:
                s = t.fn(s, **{k: dd[k] for k in t.param_names})
        t_naive += (time.perf_counter() - t0) * len(insts) / max(4, len(insts) // 8)

        # RMSR via the distributed Manager (demand-driven buckets)
        buckets = rtma_buckets(seg, insts, len(insts))
        t0 = time.perf_counter()
        results = run_study_distributed(
            buckets,
            lambda bk: execute_merged_stage(bk.tree(seg), state, active_paths=4),
            n_workers=args.workers,
            manager=Manager(straggler_factor=4.0),
        )
        t_rmsr += time.perf_counter() - t0
        for rid, out in results.items():
            all_scores[rid].append(float(dice(out["mask"], ref_mask)))

    mean_scores = [1.0 - float(np.mean(all_scores[r])) for r in range(len(sets))]
    print(f"naive (est) {t_naive:.1f}s vs RMSR+Manager {t_rmsr:.1f}s "
          f"-> {t_naive/max(t_rmsr,1e-9):.2f}x")
    corr = correlation_indices(SPACE, sets, mean_scores)
    print("top parameters by |spearman|:")
    for name, v in sorted(corr.items(), key=lambda kv: -abs(kv[1]["spearman"]))[:8]:
        print(f"  {name:8s} spearman={v['spearman']:+.3f} pearson={v['pearson']:+.3f}")


if __name__ == "__main__":
    main()

"""End-to-end driver: distributed SA study over multiple tiles.

A thin caller of the StudyPlanner engine: the study is planned ONCE
(plan→bucket→schedule), then the same plan is executed on every tile, the
Manager dispatching buckets demand-driven to Workers (threads here; nodes in
production) with straggler backup-tasks enabled. Compares the no-reuse
policy's planned work against the hybrid policy's real wall-clock and
computes Spearman correlations of each parameter against the Dice
difference.

    PYTHONPATH=src python examples/sa_pathology.py [--runs 48] [--tiles 2]
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, TABLE1_SPACE
from repro.core import correlation_indices, dice, morris_trajectories
from repro.core.params import ParamSpace
from repro.engine import ClusterSpec, execute_plan, plan_study

SPACE = ParamSpace.from_dict(
    {
        "B": [210, 220, 230], "G": [210, 220, 230], "R": [210, 220, 230],
        "T1": [2.5, 5.0, 7.5], "T2": [2.5, 5.0, 7.5],
        "G1": [20, 40, 60], "G2": [10, 20, 30],
        "minS": [2, 10, 20], "maxS": [900, 1200, 1500],
        "minSPL": [5, 20, 40], "minSS": [2, 10, 20], "maxSS": [900, 1200, 1500],
        "FH": [4, 8], "RC": [4, 8], "WConn": [4, 8],
    }
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=48)
    ap.add_argument("--tiles", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--size", type=int, default=72)
    args = ap.parse_args()

    sets, _ = morris_trajectories(SPACE, max(1, args.runs // (SPACE.dim + 1)), seed=3)
    sets = sets[: args.runs]
    wf = build_workflow(args.size, args.size)
    cluster = ClusterSpec(n_workers=args.workers, straggler_factor=4.0)

    # Plan once (input-independent), execute on every tile.
    plan = plan_study(wf, sets, cluster=cluster, policy="hybrid",
                      max_bucket_size=len(sets), active_paths=4)
    ref_plan = plan_study(wf, [TABLE1_SPACE.default()], policy="rmsr")
    sub = sets[: max(4, len(sets) // 8)]
    naive_plan = plan_study(wf, sub, policy="none")
    print(f"plan: {plan.tasks_executed}/{plan.tasks_total} tasks "
          f"({plan.reuse_fraction*100:.0f}% reuse) in {plan.bucket_count()} buckets")

    all_scores = {rid: [] for rid in range(len(sets))}
    t_hybrid = 0.0
    n_naive = 0
    t_naive_measured = 0.0
    for tidx in range(args.tiles):
        raw = {"raw": jnp.asarray(synthetic_tile(args.size, args.size, seed=tidx))}
        ref_mask = execute_plan(ref_plan, raw).outputs[0]["mask"]

        # naive baseline: time a subsample of independent runs, extrapolate
        t0 = time.perf_counter()
        execute_plan(naive_plan, raw)
        t_naive_measured += time.perf_counter() - t0
        n_naive += len(sub)

        t0 = time.perf_counter()
        result = execute_plan(plan, raw)
        t_hybrid += time.perf_counter() - t0
        for rid, out in result.outputs.items():
            all_scores[rid].append(float(dice(out["mask"], ref_mask)))

    t_naive = t_naive_measured * (len(sets) * args.tiles) / max(n_naive, 1)
    mean_scores = [1.0 - float(np.mean(all_scores[r])) for r in range(len(sets))]
    print(f"naive (est) {t_naive:.1f}s vs engine(hybrid)+Manager {t_hybrid:.1f}s "
          f"-> {t_naive/max(t_hybrid,1e-9):.2f}x")
    corr = correlation_indices(SPACE, sets, mean_scores)
    print("top parameters by |spearman|:")
    for name, v in sorted(corr.items(), key=lambda kv: -abs(kv[1]["spearman"]))[:8]:
        print(f"  {name:8s} spearman={v['spearman']:+.3f} pearson={v['pearson']:+.3f}")


if __name__ == "__main__":
    main()

"""End-to-end driver: distributed SA study over a multi-tile dataset.

A thin caller of the StudyPlanner engine's streaming executor. The study is
planned ONCE (plan→bucket→schedule; plans are input-independent), then the
whole tile dataset is pipelined through that single plan by
``execute_study``: one persistent Manager session spans every tile and
stage, stage edges are per-tile (tile A segments while tile B normalizes),
and straggler backup-tasks stay enabled throughout. Compares the no-reuse
policy's planned work against the hybrid policy's real wall-clock and
computes Spearman correlations of each parameter against the Dice
difference.

Usage (README-level):

    PYTHONPATH=src python examples/sa_pathology.py [--runs 48] [--tiles 4]
                                                   [--workers 2] [--size 72]
                                                   [--backend thread|process]

    # --backend process swaps the Manager's Worker pool for RPC worker
    # PROCESSES behind the same WorkerBackend API (DESIGN.md §13): spawn
    # workers rebuild the workflow+plan from picklable specs, and results
    # cross the process boundary only as SharedStore keys. Fast-path flags
    # (DESIGN.md §14) ride the spec: --backend 'process[none]' replays the
    # pre-fast-path wire, 'process[-shm]' drops one mechanism, etc.

    # --hierarchy 4 splits the Manager into 4 sub-manager pumps with
    # locality-aware dispatch and work stealing (DESIGN.md §15); results
    # stay bit-identical to the flat scheduler. 'auto' sizes the fan-out
    # from the pool; 'fanout=4,-steal' tunes individual features.

    # Adaptive mode (DESIGN.md §11): a multi-round MOAT -> prune -> VBD ->
    # refine study driven by repro.study.StudyDriver — one persistent
    # Manager session and result store across rounds, each round planning
    # only its delta against the cached trie:
    PYTHONPATH=src python examples/sa_pathology.py --adaptive [--rounds 4]

    # Fleet mode (DESIGN.md §12): the same adaptive study sharded across K
    # StudyDriver *processes* pooling one crash-safe SharedStore directory
    # (atomic writes + per-key file locks + manifest); round N+1 plans
    # against the union of every process's committed keys:
    PYTHONPATH=src python examples/sa_pathology.py --fleet 2 [--rounds 4]

    # Library form — dataset-level study in three lines:
    from repro.engine import ClusterSpec, execute_study, plan_study
    plan = plan_study(workflow, param_sets, policy="hybrid")
    stream = execute_study(plan, tiles, cluster=ClusterSpec(n_workers=8))
    # stream.outputs[tile][run_id] — bit-identical to per-tile execute_plan;
    # stream.throughput / stream.parallel_efficiency — paper §IV-D metrics.
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, TABLE1_SPACE
from repro.core import correlation_indices, dice, morris_trajectories
from repro.core.params import ParamSpace
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study

SPACE = ParamSpace.from_dict(
    {
        "B": [210, 220, 230], "G": [210, 220, 230], "R": [210, 220, 230],
        "T1": [2.5, 5.0, 7.5], "T2": [2.5, 5.0, 7.5],
        "G1": [20, 40, 60], "G2": [10, 20, 30],
        "minS": [2, 10, 20], "maxS": [900, 1200, 1500],
        "minSPL": [5, 20, 40], "minSS": [2, 10, 20], "maxSS": [900, 1200, 1500],
        "FH": [4, 8], "RC": [4, 8], "WConn": [4, 8],
    }
)


def run_adaptive(args) -> None:
    """Adaptive multi-round study: screen, prune, quantify, refine — with
    cross-round incremental planning and the persistent result store."""
    from repro.app.pipeline import run_adaptive_study

    tiles = [synthetic_tile(args.size, args.size, seed=t) for t in range(args.tiles)]
    out = run_adaptive_study(
        tiles,
        space=SPACE,
        max_rounds=args.rounds,
        n_workers=args.workers,
        seed=3,
        backend=args.backend,
        hierarchy=args.hierarchy,
    )
    dispatch = ", ".join(f"{k}={v}" for k, v in out["dispatch_counts"].items())
    print(
        f"adaptive study [{out['backend']} backend, {dispatch or 'no dispatch'}]: "
        f"{out['rounds']} rounds, "
        f"{out['tasks_executed']}/{out['tasks_requested']} tasks executed "
        f"(reuse factor {out['reuse_factor']:.2f}x), "
        f"cache {out['cache_hits']} hits / {out['cache_misses']} misses / "
        f"{out['cache_spills']} spills / {out['cache_flushed']} flushed, "
        f"{out['wall_seconds']:.1f}s"
    )
    for r in out["rounds_detail"]:
        known = f", {r['planned_known']} known from prior rounds" if r["planned_known"] else ""
        print(
            f"  [{r['kind']:6s}] {r['n_new']}/{r['n_proposed']} new runs, "
            f"{r['tasks_executed']} tasks executed{known} — {r['decision'].get('reason', '')}"
        )
        ranking = r["analysis"].get("ranking")
        if ranking:
            print(f"           importance: {' > '.join(ranking[:6])}")
    print(f"surviving parameters: {out['active']}")


def run_fleet(args) -> None:
    """Fleet mode: shard the adaptive study across N processes pooling one
    crash-safe SharedStore directory."""
    import tempfile

    from repro.app.pipeline import run_fleet_study

    store_dir = args.store_dir or tempfile.mkdtemp(prefix="rtf_fleet_")
    out = run_fleet_study(
        n_procs=args.fleet,
        store_dir=store_dir,
        size=args.size,
        n_tiles=args.tiles,
        space=SPACE,
        max_rounds=args.rounds,
        n_workers=args.workers,
        seed=3,
    )
    fleet = out["fleet"]
    print(
        f"fleet study ({fleet['n_procs']} procs over {store_dir}): "
        f"{out['rounds']} rounds, "
        f"{out['tasks_executed']}/{out['tasks_requested']} combined tasks "
        f"(reuse factor {out['reuse_factor']:.2f}x), "
        f"{fleet['committed_keys']} committed store keys, "
        f"{fleet['store_disk_hits']} cross-process rehydrations, "
        f"{fleet['dedup_writes']} lock-elided double-writes, "
        f"{fleet['corrupt']} corrupt reads, {out['wall_seconds']:.1f}s"
    )
    for r in out["rounds_detail"]:
        print(
            f"  [{r['kind']:6s}] {r['n_new']}/{r['n_proposed']} new runs, "
            f"{r['tasks_executed']} tasks executed — "
            f"{r['decision'].get('reason', '')}"
        )
    print(f"surviving parameters: {out['active']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=48)
    ap.add_argument("--tiles", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--size", type=int, default=72)
    ap.add_argument("--adaptive", action="store_true",
                    help="multi-round adaptive study (MOAT -> prune -> VBD -> refine)")
    ap.add_argument("--rounds", type=int, default=4, help="adaptive round budget")
    ap.add_argument("--fleet", type=int, default=0, metavar="K",
                    help="shard the adaptive study across K processes "
                         "pooling one SharedStore")
    ap.add_argument("--store-dir", default=None,
                    help="SharedStore directory for --fleet (default: fresh tmpdir)")
    ap.add_argument("--backend", default="thread",
                    help="WorkerBackend for the study's Manager session: "
                         "'thread' (default, in-process Workers) or "
                         "'process' — RPC worker processes pooling a "
                         "SharedStore — or 'socket' — a TCP fleet "
                         "(DESIGN.md §16) whose workers join by address, "
                         "e.g. 'socket[store=obj:/data/sa]'. Fast-path "
                         "flags select per DESIGN.md §14, e.g. "
                         "'process[none]' or 'process[-shm]'")
    ap.add_argument("--hierarchy", default=None,
                    help="scheduler topology for the Manager session "
                         "(DESIGN.md §15): 'flat' (default, one pump), an "
                         "integer fan-out, 'auto', or a spec string like "
                         "'fanout=4,-steal,block=16'")
    args = ap.parse_args()
    if args.backend != "thread" and not args.backend.startswith(
        ("process", "socket")
    ):
        ap.error(f"--backend must be 'thread', 'process[...]' or "
                 f"'socket[...]', got {args.backend!r}")

    if args.fleet > 0:
        run_fleet(args)
        return
    if args.adaptive:
        run_adaptive(args)
        return

    sets, _ = morris_trajectories(SPACE, max(1, args.runs // (SPACE.dim + 1)), seed=3)
    sets = sets[: args.runs]
    wf = build_workflow(args.size, args.size)
    cluster = ClusterSpec(n_workers=args.workers, straggler_factor=4.0)

    # Plan once (input-independent), stream every tile through the one plan.
    plan = plan_study(wf, sets, cluster=cluster, policy="hybrid",
                      max_bucket_size=len(sets), active_paths=4)
    ref_plan = plan_study(wf, [TABLE1_SPACE.default()], policy="rmsr")
    sub = sets[: max(4, len(sets) // 8)]
    naive_plan = plan_study(wf, sub, policy="none")
    print(f"plan: {plan.tasks_executed}/{plan.tasks_total} tasks "
          f"({plan.reuse_fraction*100:.0f}% reuse) in {plan.bucket_count()} buckets")

    tiles_np = [synthetic_tile(args.size, args.size, seed=t) for t in range(args.tiles)]
    tiles = [{"raw": jnp.asarray(im)} for im in tiles_np]
    backend = None
    if args.backend.startswith("process"):
        from repro.app.pipeline import pathology_rpc_build
        from repro.runtime import ProcessRpcBackend
        from repro.runtime.transport import process_flag_kwargs

        backend = ProcessRpcBackend(
            build=pathology_rpc_build, build_kwargs={"images": tiles_np},
            **process_flag_kwargs(args.backend),
        )
    elif args.backend.startswith("socket"):
        from repro.app.pipeline import pathology_rpc_build
        from repro.runtime import SocketBackend, socket_flag_kwargs

        kwargs = socket_flag_kwargs(args.backend)
        kwargs.setdefault("store", args.store_dir)
        if kwargs["store"] is None:
            del kwargs["store"]  # backend owns a throwaway tempdir
        backend = SocketBackend(
            build=pathology_rpc_build, build_kwargs={"images": tiles_np},
            **kwargs,
        )

    # reference masks first: the 1-run reference plan, streamed over all
    # tiles — also serves as the jit warm-up so the timings below are fair
    ref_stream = execute_study(ref_plan, tiles, cluster=cluster)
    ref_masks = [ref_stream.outputs[t][0]["mask"] for t in range(args.tiles)]

    # naive baseline: time a subsample of independent runs, extrapolate
    t0 = time.perf_counter()
    execute_plan(naive_plan, tiles[0])
    t_naive = (time.perf_counter() - t0) * (len(sets) * args.tiles) / len(sub)

    t0 = time.perf_counter()
    try:
        stream = execute_study(plan, tiles, cluster=cluster, backend=backend,
                               hierarchy=args.hierarchy)
        t_hybrid = time.perf_counter() - t0  # before cleanup: timing the
    finally:                                 # study, not the rmtree
        if backend is not None:
            backend.cleanup()  # throwaway tempdir store

    all_scores = {
        rid: [float(dice(stream.outputs[t][rid]["mask"], ref_masks[t]))
              for t in range(args.tiles)]
        for rid in range(len(sets))
    }
    mean_scores = [1.0 - float(np.mean(all_scores[r])) for r in range(len(sets))]
    print(f"naive (est) {t_naive:.1f}s vs streaming engine(hybrid) {t_hybrid:.1f}s "
          f"-> {t_naive/max(t_hybrid,1e-9):.2f}x  "
          f"[{stream.backend} backend, {stream.throughput:.2f} tiles/s, "
          f"eff={stream.parallel_efficiency:.2f}, "
          f"{stream.manager_sessions} Manager session]")
    sched = stream.scheduler
    if sched.get("fanout", 1) > 1:
        print(f"scheduler [{sched['mode']} fanout={sched['fanout']}]: "
              f"{sched['steals']} steals ({sched['steal_items']} items), "
              f"locality hit-rate {sched['locality_hit_rate']:.2f}, "
              f"pump occupancy {sched['pump_occupancy']:.2f}, "
              f"mean worker idle {sched['worker_idle_fraction']:.2f}")
    corr = correlation_indices(SPACE, sets, mean_scores)
    print("top parameters by |spearman|:")
    for name, v in sorted(corr.items(), key=lambda kv: -abs(kv[1]["spearman"]))[:8]:
        print(f"  {name:8s} spearman={v['spearman']:+.3f} pearson={v['pearson']:+.3f}")


if __name__ == "__main__":
    main()

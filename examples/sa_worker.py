"""Remote worker entrypoint: join a pathology SA fleet by TCP address.

The multi-host counterpart of ``sa_pathology.py --backend socket`` — run
this on ANY host that can reach the leader's control-plane address and the
study's store root (a shared directory, or an ``obj:<root>`` object store
that needs no shared filesystem at all):

    # on the leader (listens on a fixed port, waits for external workers):
    PYTHONPATH=src python examples/sa_pathology.py \
        --backend 'socket[0.0.0.0:7077,external]' --workers 2 \
        --store-dir obj:/data/sa-store

    # on each worker host:
    PYTHONPATH=src:examples python examples/sa_worker.py \
        --connect leader-host:7077 --tiles 4 --size 72

Inputs never cross the wire: the worker REGENERATES the synthetic tiles
deterministically (same seeds as the leader — ``synthetic_tile(size,
size, seed=t)`` for t in 0..tiles-1), so leader and workers agree on the
dataset by construction, and results cross hosts only as store keys. For a
real dataset the pattern is the same — give every host a build that loads
identical tiles (e.g. from the object store) instead of synthesising them.

This wraps the generic ``python -m repro.runtime.net worker`` CLI: that
entrypoint takes any ``--build module:callable``; this one bakes in the
pathology build and its tile-regeneration arguments. Store spec, option
flags and heartbeat cadence all arrive from the leader in the welcome
frame, so the only coordination needed is the address (and a matching
--tiles/--size, which the leader's run prints).
"""

import argparse

from repro.app import synthetic_tile
from repro.app.pipeline import pathology_rpc_build
from repro.runtime.net import run_worker


def pathology_worker_build(n_tiles: int = 4, size: int = 72):
    """Spawn/remote-importable build: regenerate the leader's synthetic
    tiles (deterministic seeds) and hand them to the standard RPC build."""
    tiles = [synthetic_tile(size, size, seed=t) for t in range(n_tiles)]
    return pathology_rpc_build(tiles)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Join a pathology SA socket fleet (DESIGN.md §16)"
    )
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="the leader's control-plane address")
    ap.add_argument("--tiles", type=int, default=4,
                    help="tile count — must match the leader's --tiles")
    ap.add_argument("--size", type=int, default=72,
                    help="tile size — must match the leader's --size")
    ap.add_argument("--id", type=int, default=None,
                    help="re-register under a previously assigned worker id")
    ap.add_argument("--store", default=None,
                    help="override the leader's store spec for this host "
                         "(plain directory or obj:<root>)")
    args = ap.parse_args()
    wid = run_worker(
        args.connect,
        build=pathology_worker_build,
        build_kwargs={"n_tiles": args.tiles, "size": args.size},
        worker_id=args.id,
        store=args.store,
    )
    print(f"worker {wid} retired cleanly")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Mixtral 8x7B [arXiv:2401.04088]: 32L, d_model 4096, 32 heads (GQA kv=8),
d_ff 14336 per expert, 8 experts top-2, sliding-window attention, vocab 32000."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    window=4096,
    rope_theta=1e6,
)

"""PaliGemma 3B [arXiv:2407.07726]: SigLIP vision frontend (STUB — precomputed
patch embeddings) + gemma decoder: 18L, d_model 2048, 8 heads (GQA kv=1,
head_dim 256), d_ff 16384, vocab 257216, 256 image patches."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma_3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="siglip",
    num_patches=256,
    rope_theta=1e4,
)

"""Gemma 3 1B pretrained [hf:google/gemma-3-1b-pt]: 26L, d_model 1152,
4 heads (GQA kv=1, head_dim 256), d_ff 6912, vocab 262144; 5:1
local:global attention (local window 512, every 6th layer global)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    local_window=512,
    global_every=6,
    rope_theta=1e6,
)

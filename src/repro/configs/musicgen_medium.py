"""MusicGen medium [arXiv:2306.05284]: decoder-only over EnCodec tokens
(frontend STUB — precomputed frame embeddings), 48L, d_model 1536, 24 heads
(MHA kv=24, head_dim 64), d_ff 6144, 4 codebooks × vocab 2048."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="encodec",
    num_codebooks=4,
    rope_theta=1e4,
)

"""Model / shape configuration system.

Every assigned architecture gets a ``ModelConfig`` (exact published numbers)
in ``repro/configs/<id>.py`` and registers itself here. Shapes are the four
assigned input-shape cells; ``train_*`` lowers ``train_step`` and
``prefill_*`` / ``decode_*`` / ``long_*`` lower ``serve_step``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "reduced_config",
    "supports_long_context",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention pattern ---
    window: Optional[int] = None        # uniform sliding window (Mistral/Mixtral)
    local_window: Optional[int] = None  # local:global pattern (gemma3)
    global_every: int = 0               # every k-th layer is global (gemma3: 6)
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2) / RWKV ---
    ssm_state: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    attn_every: int = 0                 # zamba2: shared attn block cadence
    rwkv: bool = False
    # --- modality frontend stubs ---
    frontend: Optional[str] = None      # siglip | encodec
    num_patches: int = 0
    num_codebooks: int = 0
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/logits dims
        shard over any mesh axis (granite's 49155 is not divisible by 16)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def ssm_heads(self) -> int:
        if not (self.ssm_state or self.rwkv):
            return 0
        d_inner = self.ssm_expand * self.d_model if not self.rwkv else self.d_model
        return d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind: 'attn' | 'mamba' | 'rwkv'. For zamba2, 'mamba'
        everywhere with the shared 'attn' block applied at ``attn_every``
        cadence (handled by the model; kinds list marks those slots)."""
        if self.rwkv:
            return ("rwkv",) * self.num_layers
        if self.family == "hybrid":
            return tuple(
                "mamba+attn" if (i + 1) % self.attn_every == 0 else "mamba"
                for i in range(self.num_layers)
            )
        return ("attn",) * self.num_layers

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Effective attention window per layer (seq_len == full/global)."""
        out = []
        for i in range(self.num_layers):
            if self.window is not None:
                out.append(min(self.window, seq_len))
            elif self.local_window is not None and self.global_every:
                is_global = (i + 1) % self.global_every == 0
                out.append(seq_len if is_global else min(self.local_window, seq_len))
            else:
                out.append(seq_len)
        return tuple(out)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (embedding + stacked layers + head)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embedding
        total += v * d  # lm head (untied)
        total += d  # final norm
        per_layer = 0
        kinds = self.layer_kinds()
        n_attn = sum(1 for k in kinds if "attn" in k and self.family != "hybrid")
        n_mamba = sum(1 for k in kinds if "mamba" in k)
        n_rwkv = sum(1 for k in kinds if k == "rwkv")
        attn_params = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim + self.num_heads * self.head_dim * d
        if self.num_experts:
            ffn = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        else:
            ffn = 3 * d * self.d_ff
        if self.family in ("dense", "moe", "vlm", "audio"):
            per_layer = attn_params + ffn + 2 * d
            total += self.num_layers * per_layer
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state * 1 + self.ssm_heads) + d_in * d + d_in  # in/out proj + dt + conv-ish
            total += n_mamba * (mamba + 2 * d)
            # one SHARED attention block (weights reused at every application)
            total += attn_params + 3 * d * self.d_ff + 2 * d
        elif self.family == "ssm":
            per = d * d * 4 + 3 * d * self.d_ff + 2 * d  # r/k/v/g + channel mix
            total += n_rwkv * per
        if self.frontend == "encodec":
            total += (self.num_codebooks - 1) * v * d  # extra codebook heads
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.num_experts * 3 * d * self.d_ff
        active_ffn = self.experts_per_token * 3 * d * self.d_ff
        return int(self.param_count() - self.num_layers * (dense_ffn - active_ffn))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "mixtral_8x7b",
    "granite_moe_1b_a400m",
    "gemma3_1b",
    "phi3_medium_14b",
    "granite_3_8b",
    "yi_6b",
    "zamba2_2p7b",
    "paligemma_3b",
    "rwkv6_1p6b",
    "musicgen_medium",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k is run only for sub-quadratic archs (SWA / local:global /
    SSM / hybrid); pure full-attention archs skip it (DESIGN.md §6)."""
    return (
        cfg.window is not None
        or cfg.local_window is not None
        or cfg.family in ("ssm", "hybrid")
    )


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A smoke-test-sized config of the same family: small widths/depths,
    few experts, tiny vocab — runs a real step on one CPU device."""
    return dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4 if cfg.family != "hybrid" else 6),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        window=min(cfg.window, 32) if cfg.window else None,
        local_window=min(cfg.local_window, 16) if cfg.local_window else None,
        global_every=cfg.global_every,
        attn_every=3 if cfg.attn_every else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if (cfg.ssm_state or cfg.rwkv) else 0,
        num_patches=16 if cfg.num_patches else 0,
    )

"""Zamba2 2.7B [arXiv:2411.15242]: 54 Mamba2 layers (d_model 2560,
ssm_state 64) with a SHARED attention+MLP block (32 heads MHA, head_dim 80,
d_ff 10240) applied every 6th layer, vocab 32000."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_2p7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    rope_theta=1e4,
)

"""Granite 3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L, d_model 1024, 16 heads (GQA kv=8), per-expert d_ff 512, 32 experts
top-8, vocab 49155 (padded to 49408 for sharding)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_1b_a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    rope_theta=1e4,
)

"""Architecture configs (one module per assigned architecture) + shapes."""

from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    reduced_config,
    supports_long_context,
)

"""Granite 3.0 8B base [hf:ibm-granite family]: 40L, d_model 4096, 32 heads
(GQA kv=8), d_ff 12800, vocab 49155 (padded to 49408)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_3_8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=1e4,
)

"""RWKV-6 (Finch) 1.6B [arXiv:2404.05892]: attention-free, 24L, d_model 2048
(32 state heads of 64), channel-mix d_ff 7168, vocab 65536, data-dependent
per-channel decay."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1p6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rwkv=True,
    ssm_head_dim=64,
    ssm_state=64,
)

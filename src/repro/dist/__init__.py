"""Distributed-execution helpers: the mesh-aware sharding layer every model
forward, launcher and the elastic runtime share (DESIGN.md §5)."""

from repro.dist.sharding import (  # noqa: F401
    ParallelCtx,
    cache_shardings,
    constrain_hidden,
    constrain_qkv,
    input_shardings,
    make_ctx,
    param_shardings,
)

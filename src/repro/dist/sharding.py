"""Mesh-aware sharding (DESIGN.md §5).

One :class:`ParallelCtx` describes how a step runs on a mesh: which axes
carry data parallelism (``dp`` — 'pod' and 'data' when present) and which
axis carries model parallelism (``model``). ``ctx=None`` everywhere means
single-device execution — every helper here degrades to a no-op / fully
replicated layout in that case, and every constraint is divisibility-guarded
so an awkward shape silently falls back to replication on that dim instead
of failing to compile.

Layout rules:

* **params at rest** — FSDP: the largest divisible dim of every rank-≥2 leaf
  is sharded over 'data'; rank-<2 leaves (norms, biases) are replicated.
* **activations** — batch over ``dp``; attention heads over 'model'
  (``constrain_qkv``); the hidden dim stays unsharded so GSPMD picks the
  collective placement (``constrain_hidden``).
* **KV caches** — batch dim over ``dp``, kv-head dim over 'model'.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParallelCtx",
    "make_ctx",
    "param_shardings",
    "input_shardings",
    "cache_shardings",
    "constrain_qkv",
    "constrain_hidden",
    "shard_map_compat",
]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, across jax versions
    (jax < 0.5 only ships jax.experimental.shard_map with `check_rep`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """How one step is parallelised over a mesh."""

    mesh: Optional[Mesh]
    mode: str = "train"  # "train" (SP/FSDP layouts) | "serve" (TP layouts)
    dp: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    analysis: bool = False  # unroll scans so HLO analysis sees every layer


def make_ctx(mesh: Optional[Mesh], *, mode: str = "train") -> ParallelCtx:
    if mesh is None:
        return ParallelCtx(mesh=None, mode=mode)
    names = tuple(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    return ParallelCtx(mesh=mesh, mode=mode, dp=dp, model_axis=model_axis)


def _axis_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes if isinstance(axes, (tuple, list)) else (axes,):
        size *= mesh.shape[a]
    return size


def _dp_if_divisible(ctx: ParallelCtx, dim: int):
    if ctx.dp and dim % _axis_size(ctx.mesh, ctx.dp) == 0:
        return ctx.dp
    return None


def _model_if_divisible(ctx: ParallelCtx, dim: int):
    if ctx.model_axis and dim % _axis_size(ctx.mesh, ctx.model_axis) == 0:
        return ctx.model_axis
    return None


# ---------------------------------------------------------------------------
# At-rest layouts
# ---------------------------------------------------------------------------


def param_shardings(tree: Any, ctx: Optional[ParallelCtx]) -> Any:
    """FSDP at-rest layout: shard the largest divisible dim of each rank-≥2
    leaf over 'data'. Accepts arrays or ShapeDtypeStructs; returns a
    matching pytree of NamedShardings (or None off-mesh)."""
    if ctx is None or ctx.mesh is None:
        return None
    mesh = ctx.mesh
    data = "data" if "data" in mesh.axis_names else None

    def leaf_sharding(x) -> NamedSharding:
        shape = tuple(x.shape)
        if data is None or len(shape) < 2:
            return NamedSharding(mesh, P())
        size = mesh.shape[data]
        divisible = [d for d in range(len(shape)) if shape[d] % size == 0 and shape[d] > 0]
        if not divisible:
            return NamedSharding(mesh, P())
        d = max(divisible, key=lambda i: shape[i])
        spec = [None] * len(shape)
        spec[d] = data
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf_sharding, tree)


def input_shardings(cfg, shape, ctx: Optional[ParallelCtx]) -> Dict[str, P]:
    """Batch-over-dp PartitionSpecs for every input of this step shape."""
    from repro.launch.inputs import input_specs

    specs = input_specs(cfg, shape)
    if ctx is None or ctx.mesh is None:
        return {k: P() for k in specs}
    out: Dict[str, P] = {}
    for name, sds in specs.items():
        batch = _dp_if_divisible(ctx, sds.shape[0])
        out[name] = P(*([batch] + [None] * (len(sds.shape) - 1)))
    return out


def cache_shardings(cfg, shape, ctx: Optional[ParallelCtx]) -> Callable[[Any], Any]:
    """Returns a pytree-mapper: KV-cache leaves get batch-over-dp and
    kv-heads-over-model (leading layer dim replicated)."""

    def mapper(tree: Any) -> Any:
        if ctx is None or ctx.mesh is None:
            return jax.tree.map(lambda x: None, tree)
        kv = getattr(cfg, "num_kv_heads", 0)

        def leaf_sharding(x) -> NamedSharding:
            spec = [None] * len(x.shape)
            for d, n in enumerate(x.shape):
                if d > 0 and n == shape.global_batch and spec[d] is None:
                    spec[d] = _dp_if_divisible(ctx, n)
                    break
            for d in range(len(x.shape) - 1, 0, -1):
                if x.shape[d] == kv and spec[d] is None:
                    spec[d] = _model_if_divisible(ctx, x.shape[d])
                    break
            return NamedSharding(ctx.mesh, P(*spec))

        return jax.tree.map(leaf_sharding, tree)

    return mapper


# ---------------------------------------------------------------------------
# In-flight constraints
# ---------------------------------------------------------------------------


def _constrain(x, ctx: ParallelCtx, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain_qkv(q, k, v, ctx: Optional[ParallelCtx]):
    """Shard attention heads over 'model' and batch over dp: (b, s, h, hd)."""
    if ctx is None or ctx.mesh is None:
        return q, k, v

    def one(t):
        b, _, h, _ = t.shape
        return _constrain(
            t, ctx, P(_dp_if_divisible(ctx, b), None, _model_if_divisible(ctx, h), None)
        )

    return one(q), one(k), one(v)


def constrain_hidden(x, cfg, ctx: Optional[ParallelCtx]):
    """Batch-over-dp for the (b, s, d) hidden stream; the hidden dim stays
    unsharded (GSPMD chooses where the matmul collectives land)."""
    if ctx is None or ctx.mesh is None:
        return x
    return _constrain(x, ctx, P(_dp_if_divisible(ctx, x.shape[0]), None, None))

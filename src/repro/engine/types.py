"""Plan/result datatypes of the unified StudyPlanner engine (DESIGN.md §3).

A :class:`StudyPlan` is the ahead-of-time artifact of ``plan_study``: per
stage, per upstream-input group, a list of :class:`BucketPlan`s, each holding
its merged reuse tree and the exact :class:`~repro.core.rmsr.ScheduleResult`
(execution order + provable peak-bytes) the executor will follow. Because the
schedule is computed at plan time, ``peak_bytes`` is a *proof* about the
execution, not an estimate — the executor replays the order and frees buffers
per the same liveness rule the accounting used.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.reuse import ReuseTree
from repro.core.rmsr import ScheduleResult
from repro.core.workflow import StageInstance, StageSpec, Workflow

__all__ = [
    "MemoryBudget",
    "ClusterSpec",
    "BucketPlan",
    "StagePlan",
    "StudyPlan",
    "StudyResult",
    "StudyStreamResult",
]

POLICIES = ("none", "stage", "rtma", "rmsr", "hybrid")

# Policies whose semantics include task-level (trie) reuse; only these may
# share merged prefixes through the executor's run-level result cache —
# caching under "none"/"stage" would silently upgrade the baselines.
CACHING_POLICIES = ("rtma", "rmsr", "hybrid")

DEFAULT_MAX_BUCKET = 8
DEFAULT_CACHE_BYTES = 128 << 20


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Memory constraints the planner solves against.

    ``bytes``       — per-worker budget for ALL live state: schedule buffers
                      plus the result cache. The planner sizes RTMA buckets
                      (``max_bucket_for_budget``) and RMSR ``active_paths``
                      (``min_active_paths``) against ``schedule_bytes`` =
                      bytes − cache reservation, so schedule peak + cache
                      together stay under ``bytes``.
    ``cache_bytes`` — byte cap of the executor's run-level result cache
                      (0 disables it). Under a finite budget the effective
                      cap is clamped to bytes/8 so the cache can never
                      crowd out the schedule.
    """

    bytes: Optional[int] = None
    cache_bytes: int = DEFAULT_CACHE_BYTES

    @property
    def effective_cache_bytes(self) -> int:
        if self.bytes is None:
            return self.cache_bytes
        return min(self.cache_bytes, self.bytes // 8)

    @property
    def schedule_bytes(self) -> Optional[int]:
        """What the planner may let live buffers reach; the cache retains up
        to ``effective_cache_bytes`` on top, keeping the total under
        ``bytes``."""
        if self.bytes is None:
            return None
        return self.bytes - self.effective_cache_bytes


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """How ``execute_plan`` dispatches buckets through the Manager."""

    n_workers: int = 1
    max_attempts: int = 3
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 3.0
    enable_backup_tasks: bool = True


@dataclasses.dataclass
class BucketPlan:
    """One merged coarse task: a reuse tree plus its frozen schedule."""

    stage_index: int
    stage_name: str
    group_key: Tuple[Any, ...]  # upstream-signature this bucket's input hangs on
    instances: List[StageInstance]
    tree: ReuseTree
    schedule: ScheduleResult
    active_paths: int
    discipline: str  # "lifo" (RMSR depth-first) | "fifo" (RTMA breadth-eligible)
    # Trie nodes of this bucket already recorded in the TrieLedger at plan
    # time (prior-round work the persistent result store will serve as
    # hits); 0 for non-incremental plans.
    known_nodes: int = 0

    @property
    def run_ids(self) -> List[int]:
        return [i.run_id for i in self.instances]

    @property
    def cache_scope(self) -> Tuple[Any, ...]:
        """Cache-key prefix: buckets of the same stage whose instances share
        the same upstream outputs may share merged-prefix results."""
        return (self.stage_index, self.stage_name, self.group_key)


@dataclasses.dataclass
class StagePlan:
    stage: StageSpec
    index: int
    buckets: List[BucketPlan]
    tasks_total: int

    @property
    def tasks_executed(self) -> int:
        return sum(b.tree.unique_task_count() for b in self.buckets)

    @property
    def tasks_known(self) -> int:
        return sum(b.known_nodes for b in self.buckets)

    @property
    def peak_bytes(self) -> int:
        return max((b.schedule.peak_bytes for b in self.buckets), default=0)

    @property
    def work_seconds(self) -> float:
        return sum(b.schedule.total_cost for b in self.buckets)

    @property
    def makespan(self) -> float:
        return sum(b.schedule.makespan for b in self.buckets)


@dataclasses.dataclass
class StudyPlan:
    workflow: Workflow
    n_runs: int
    policy: str
    stages: List[StagePlan]
    memory: MemoryBudget
    cluster: Optional[ClusterSpec] = None
    # Incremental planning (plan_study(..., ledger=...)): cache keys this
    # plan introduces that the TrieLedger did not know. The caller commits
    # them (ledger.add_all) once the plan has executed successfully.
    ledger_pending: Optional[List[Tuple[Any, ...]]] = None
    # The picklable planning arguments this plan was built from (param
    # sets, policy, bucketing knobs, memory budget). Planning is
    # deterministic, so a worker process holding the same Workflow rebuilds
    # a structurally identical plan from the recipe — how a StudyPlan
    # crosses the RPC boundary without serialising task closures
    # (DESIGN.md §13).
    recipe: Optional[Dict[str, Any]] = None

    @property
    def tasks_total(self) -> int:
        return sum(s.tasks_total for s in self.stages)

    @property
    def tasks_executed(self) -> int:
        return sum(s.tasks_executed for s in self.stages)

    @property
    def tasks_known(self) -> int:
        """Merged tasks already in the cross-round TrieLedger at plan time
        (expected to be served by the persistent result store)."""
        return sum(s.tasks_known for s in self.stages)

    @property
    def tasks_new(self) -> int:
        """The incremental-plan delta: merged tasks this plan introduces on
        top of what prior rounds already computed."""
        return self.tasks_executed - self.tasks_known

    @property
    def reuse_fraction(self) -> float:
        total = self.tasks_total
        return 1.0 - self.tasks_executed / total if total else 0.0

    @property
    def peak_bytes(self) -> int:
        """Peak live bytes of any single in-flight bucket — the per-worker
        guarantee. With W concurrent workers the node-level peak is bounded
        by the sum of the W largest bucket peaks."""
        return max((s.peak_bytes for s in self.stages), default=0)

    @property
    def active_paths(self) -> int:
        return max((b.active_paths for s in self.stages for b in s.buckets), default=1)

    @property
    def work_seconds(self) -> float:
        return sum(s.work_seconds for s in self.stages)

    @property
    def makespan(self) -> float:
        """Single-worker serial makespan model (buckets back-to-back); the
        cluster-level model lives in runtime.simulator."""
        return sum(s.makespan for s in self.stages)

    @property
    def cache_enabled(self) -> bool:
        return self.policy in CACHING_POLICIES and self.memory.effective_cache_bytes > 0

    def bucket_count(self) -> int:
        return sum(len(s.buckets) for s in self.stages)


@dataclasses.dataclass
class StudyResult:
    """Outputs of ``execute_plan``: final-stage state per run, plus the
    actual execution accounting (may differ from the plan's when the result
    cache absorbs retries/backup tasks or cross-bucket shared prefixes)."""

    outputs: Dict[int, Any]
    tasks_executed: int
    cache_hits: int
    retries: int
    backups_launched: int
    wall_seconds: float
    per_stage_executed: List[int] = dataclasses.field(default_factory=list)
    # run-level ResultCache deltas for this execution (0 when caching is
    # disabled): misses, spill-tier writes, and store rehydrations.
    cache_misses: int = 0
    cache_spills: int = 0
    cache_rehydrations: int = 0
    # which WorkerBackend dispatched this execution, and how many leases it
    # was handed (this call's delta of Manager.dispatch_counts)
    backend: str = "thread"
    dispatch_counts: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StudyStreamResult:
    """Outputs of ``execute_study``: one study-wide streaming execution of a
    plan over many inputs through a single persistent Manager session
    (DESIGN.md §10).

    ``outputs[i][run_id]`` is the final-stage state of run ``run_id`` on
    input ``i`` — bit-identical to ``execute_plan(plan, inputs[i])``.
    ``per_input`` carries the per-input accounting (task counts, cache hits,
    per-stage executed, submit→complete latency); ``retries`` /
    ``backups_launched`` are session-wide because the persistent Manager
    spans all inputs. ``busy_seconds`` sums the winning attempts' wall-times,
    so ``parallel_efficiency`` matches the paper's busy/(makespan×workers)
    definition.
    """

    outputs: Dict[int, Dict[int, Any]]
    per_input: List[StudyResult]
    n_inputs: int
    n_workers: int
    tasks_executed: int
    cache_hits: int
    retries: int
    backups_launched: int
    wall_seconds: float
    busy_seconds: float
    manager_sessions: int = 1
    # run-level ResultCache deltas for this study (0 when caching is
    # disabled); with an external round-persistent cache these are THIS
    # call's contribution, not the cache's lifetime totals.
    cache_misses: int = 0
    cache_spills: int = 0
    cache_rehydrations: int = 0
    # which WorkerBackend the session dispatched through, and the leases it
    # was handed during this study (delta of Manager.dispatch_counts)
    backend: str = "thread"
    dispatch_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Manager.scheduler_stats() snapshot at study end: hierarchy mode and
    # fanout, steal/locality counters, pump occupancy, per-worker busy
    # seconds and mean idle fraction (DESIGN.md §15)
    scheduler: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def throughput(self) -> float:
        """Completed inputs per second of study wall-clock."""
        from repro.core.metrics import throughput

        return throughput(self.n_inputs, self.wall_seconds)

    @property
    def parallel_efficiency(self) -> float:
        from repro.core.metrics import parallel_efficiency

        return parallel_efficiency(
            self.busy_seconds, self.wall_seconds, self.n_workers
        )

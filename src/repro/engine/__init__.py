"""Unified StudyPlanner engine: one plan→bucket→schedule→dispatch pipeline
for every SA workload (DESIGN.md §3/§4).

``plan_study`` composes the paper's contributions — stage-level dedup, reuse
trees (RTMA merging), memory-bounded AOT schedules (RMSR) — behind one
pluggable bucketing policy, and ``execute_plan`` dispatches the planned
buckets demand-driven through the Manager runtime with run-level result
caching. The pathology app, the SA-over-serving workload, the examples and
every benchmark are thin callers of these two functions.
"""

from repro.engine.types import (  # noqa: F401
    BucketPlan,
    ClusterSpec,
    MemoryBudget,
    StagePlan,
    StudyPlan,
    StudyResult,
)
from repro.engine.planner import plan_study  # noqa: F401
from repro.engine.executor import ResultCache, execute_bucket, execute_plan  # noqa: F401

"""Unified StudyPlanner engine: one plan→bucket→schedule→dispatch pipeline
for every SA workload (DESIGN.md §3/§4).

``plan_study`` composes the paper's contributions — stage-level dedup, reuse
trees (RTMA merging), memory-bounded AOT schedules (RMSR) — behind one
pluggable bucketing policy; ``execute_study`` streams a whole dataset of
inputs through one plan inside a single persistent Manager session with
per-input stage edges and input-scoped result caching (DESIGN.md §10); and
``execute_plan`` is its one-input special case. The pathology app, the
SA-over-serving workload, the examples and every benchmark are thin callers
of these functions.
"""

from repro.engine.types import (  # noqa: F401
    BucketPlan,
    ClusterSpec,
    MemoryBudget,
    StagePlan,
    StudyPlan,
    StudyResult,
    StudyStreamResult,
)
from repro.engine.planner import TrieLedger, plan_study  # noqa: F401
from repro.engine.executor import ResultCache, execute_bucket, execute_plan  # noqa: F401
from repro.engine.streaming import execute_study  # noqa: F401

"""execute_study — the dataset-level streaming executor (DESIGN.md §10).

The paper's headline numbers come from SA over *datasets*: hundreds of
whole-slide tiles flowing through the Manager-Worker runtime at >92%
parallel efficiency. A :class:`~repro.engine.types.StudyPlan` is
input-independent ("plan once, execute on every tile"), so the dataset
dimension is pure execution: ``execute_study(plan, inputs)`` drives many
inputs through one plan concurrently inside a **single persistent Manager
session** spanning every input and stage.

The global per-stage barrier of the one-input executor becomes a
**per-input dependency edge**: stage *s+1* buckets of input *i* are
submitted the moment the last stage-*s* bucket of input *i* completes (a
Manager completion callback), so tile A can be in segmentation while tile B
is still normalizing and Workers never idle at a stage boundary waiting for
an unrelated tile. Parameter-free stages still collapse to one shared
execution *per input* (that is a plan property), and the run-level
:class:`~repro.engine.executor.ResultCache` is keyed with an input-scoped
segment so cross-input collisions are structurally impossible — tasks are
pure functions of ``(input, params)`` and the input differs.

``execute_plan`` is the K=1 special case and delegates here, which is what
makes the differential guarantee cheap to state: ``execute_study`` over K
inputs is bit-identical to K sequential ``execute_plan`` calls under every
policy and worker count, while starting one Manager session instead of K.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Any, List, Optional, Sequence

from repro.engine.executor import ResultCache, execute_bucket
from repro.engine.types import (
    ClusterSpec,
    StudyPlan,
    StudyResult,
    StudyStreamResult,
)
from repro.runtime.manager import Manager, TaskCancelled, WorkItem

__all__ = ["execute_study", "study_task_keys"]


def study_task_keys(
    plan: "StudyPlan", n_inputs: int, key_prefix: str = ""
) -> List[str]:
    """The complete, deterministic list of WorkItem keys ``execute_study``
    will submit for ``plan`` over ``n_inputs`` inputs. The service registry
    precomputes these for admission control (task quotas), per-job
    refcounting and cancellation — no callback channel from the executor
    is needed, because keys are a pure function of (plan, input index)."""
    keys: List[str] = []
    for i in range(n_inputs):
        for sp in plan.stages:
            for bi in range(len(sp.buckets)):
                keys.append(
                    f"{key_prefix}in{i}:{sp.index}:{sp.stage.name}:{bi}"
                )
    return keys

# Unique plan ids for spec-capable backends: an external Manager session
# may execute many plans (adaptive rounds), and worker processes cache the
# rebuilt plans by this id.
_PLAN_IDS = itertools.count()  # guard: _PLAN_IDS_LOCK
_PLAN_IDS_LOCK = threading.Lock()


class _InputState:
    """Mutable per-input progress record; guarded by the study lock."""

    __slots__ = (
        "current", "routed", "remaining", "executed", "hits",
        "t_submit", "t_done",
    )

    def __init__(self, plan: StudyPlan, input_state: Any):
        self.current = {rid: input_state for rid in range(plan.n_runs)}
        self.routed: dict = {}
        self.remaining = [len(sp.buckets) for sp in plan.stages]
        self.executed = [0] * len(plan.stages)
        self.hits = [0] * len(plan.stages)
        self.t_submit = 0.0
        self.t_done = 0.0


def execute_study(
    plan: StudyPlan,
    inputs: Sequence[Any],
    *,
    cluster: Optional[ClusterSpec] = None,
    cache: Optional[ResultCache] = None,
    manager: Optional[Manager] = None,
    backend: Any = None,
    hierarchy: Any = None,
    input_keys: Optional[Sequence[Any]] = None,
    key_prefix: str = "",
    shared: bool = False,
    tenant: str = "",
    priority: int = 0,
    cancel_event: Optional[threading.Event] = None,
    on_progress: Optional[Any] = None,
) -> StudyStreamResult:
    """Execute a :class:`StudyPlan` on every input in ``inputs``, pipelined
    through one persistent Manager session.

    Outputs are bit-identical to sequential per-input execution: buckets
    replay frozen schedules of pure tasks, routing is keyed by ``run_id``
    alone, and the result cache carries an input-scoped key segment. The
    first permanently-failed bucket (Manager retries exhausted) aborts the
    study after the session drains, re-raising the original exception.

    Multi-round (adaptive-study) extensions, all default-off:

    * ``cache``     — an external, round-persistent :class:`ResultCache`
      (optionally spill-store-backed). Honoured only when the plan's policy
      admits caching (``plan.cache_enabled``), so the ``none``/``stage``
      baselines stay honest. Without it a fresh per-study cache is built.
    * ``manager``   — an external, already-``start``-ed Manager session to
      submit into; the session is drained but left running for the next
      round. Accounting (retries, backups, busy seconds) reports this
      call's delta, and ``manager_sessions`` is 0 (no session started
      here).
    * ``input_keys``— stable per-input identities for the cache's input
      scope segment (default: the positional index). Required for
      cross-round reuse: round *N*'s "tile «a»" must key identically to
      round 1's.
    * ``key_prefix``— disambiguates WorkItem keys inside a shared session
      (the Manager memoises results by key, so two rounds submitting
      ``in0:…`` verbatim would collide).

    ``backend`` selects the session's WorkerBackend (default: in-process
    Worker threads; mutually exclusive with ``manager``, whose own backend
    is used). ``hierarchy`` selects the session's scheduler topology
    (DESIGN.md §15): ``None``/"flat" keeps the single-pump Manager,
    ``"fanout=N"`` (or an int, ``"auto"``, or a
    :class:`~repro.runtime.hierarchy.HierarchySpec`) splits dispatch
    across N sub-manager pumps with locality-aware routing and work
    stealing — outputs stay bit-identical, only placement changes; also
    mutually exclusive with ``manager``. The session's scheduler counters
    (pump occupancy, steals, locality hit-rate) are returned in
    ``StudyStreamResult.scheduler``. With a **spec-capable** backend (``ProcessRpcBackend``) the
    executor ships no closures: it broadcasts the plan's ``recipe`` (the
    picklable planning arguments — workers rebuild the plan against their
    own ``build()`` context) and each WorkItem carries a ``("bucket",
    plan_id, input, stage, bucket)`` spec. Workers resolve stage inputs
    from the shared store by deterministic result keys and commit outputs
    back the same way, so only store keys ever cross the process boundary.

    **Service mode** (DESIGN.md §18), all default-off:

    * ``shared``      — submit WorkItems as content-addressed shared work:
      a key another concurrent study already has pending subscribes this
      study's callback instead of executing twice, and a settled key is
      served from the Manager memo. Requires a ``key_prefix`` derived from
      task CONTENT (the service hashes the study recipe) so identical keys
      always denote identical pure work. In shared mode the study waits on
      its own completion event instead of ``mgr.drain()`` (other tenants'
      work may still be pending in the session) and does NOT ``forget``
      its keys — the owner (the service registry) releases them when no
      live job references them.
    * ``tenant`` / ``priority`` — fair-share class and within-tenant
      dispatch priority stamped on every WorkItem (Manager DRR dispatch).
    * ``cancel_event`` — when set, no further stages are submitted and
      the study raises :class:`TaskCancelled`; the owner is responsible
      for revoking in-flight keys via ``mgr.cancel`` (only those no other
      job references).
    * ``on_progress`` — ``on_progress(done, total)`` called after every
      settled bucket (Manager pump thread; must be cheap and non-raising).
    """
    cluster = cluster or plan.cluster or ClusterSpec()
    inputs = list(inputs)
    if input_keys is None:
        input_keys = list(range(len(inputs)))
    else:
        input_keys = list(input_keys)
        if len(input_keys) != len(inputs):
            raise ValueError("input_keys must align 1:1 with inputs")
    if not plan.cache_enabled:
        cache = None
    elif cache is None:
        cache = ResultCache(plan.memory.effective_cache_bytes)
    if manager is None:
        owns_manager = True
        mgr = Manager(
            backend=backend,
            max_attempts=cluster.max_attempts,
            heartbeat_timeout=cluster.heartbeat_timeout,
            straggler_factor=cluster.straggler_factor,
            enable_backup_tasks=cluster.enable_backup_tasks,
            hierarchy=hierarchy,
        )
    else:
        owns_manager = False
        mgr = manager
        if backend is not None:
            raise ValueError(
                "pass backend= when the executor owns the session; an "
                "external Manager already carries its own backend"
            )
        if hierarchy is not None:
            raise ValueError(
                "pass hierarchy= when the executor owns the session; an "
                "external Manager already carries its own hierarchy"
            )
        if not mgr.is_running:
            raise RuntimeError("external Manager session must be started")
    spec_mode = bool(getattr(mgr.backend, "supports_specs", False))
    plan_id: Optional[str] = None
    if spec_mode and plan.recipe is None:
        raise ValueError(
            "this StudyPlan carries no recipe; re-plan with plan_study() to "
            "execute it on a spec-capable (process) backend"
        )
    retries0, backups0, busy0 = mgr.retries, mgr.backups_launched, mgr.busy_seconds
    dispatch0 = dict(mgr.dispatch_counts)
    cache0 = (
        (cache.misses, cache.spills, cache.rehydrations)
        if cache is not None
        else (0, 0, 0)
    )
    states = [_InputState(plan, inp) for inp in inputs]
    errors: List[BaseException] = []
    lock = threading.Lock()
    n_stages = len(plan.stages)
    total_tasks = sum(len(sp.buckets) for sp in plan.stages) * len(inputs)

    submitted: List[str] = []  # list.append is atomic; drained before reads
    # Shared-mode completion accounting (guarded by ``lock``): submitted-
    # but-unsettled keys, settled count, and whether the initial per-input
    # seeding loop is still running (so a tiny study finishing its first
    # input before the second is seeded cannot signal done prematurely).
    outstanding = [0]
    done_tasks = [0]
    seeding = [True]
    done_event = threading.Event()

    def submit_stage(i: int, si: int) -> None:
        if cancel_event is not None and cancel_event.is_set():
            return
        stage_plan = plan.stages[si]
        st = states[i]
        for bi, bucket in enumerate(stage_plan.buckets):
            src = st.current[bucket.run_ids[0]]
            key = f"{key_prefix}in{i}:{stage_plan.index}:{stage_plan.stage.name}:{bi}"
            submitted.append(key)
            with lock:
                outstanding[0] += 1
            # a shared submit of an already-settled key fires the callback
            # synchronously on THIS thread — the lock is not held here
            mgr.submit(
                WorkItem(
                    key=key,
                    fn=lambda b=bucket, s=src, k=input_keys[i]: execute_bucket(
                        b, s, cache, scope=("input", k) + b.cache_scope
                    ),
                    # spec-capable backends ship this instead of the
                    # closure; workers hold the same plan (rebuilt from the
                    # recipe) and resolve src from the shared store
                    spec=("bucket", plan_id, i, si, bi) if spec_mode else None,
                    # reuse-tree prefix for locality-aware hierarchical
                    # dispatch: input first (stage s+1 chases stage s's
                    # worker), then the bucket's trie scope
                    path=(f"{key_prefix}{input_keys[i]}",) + bucket.cache_scope,
                    callback=lambda _key, value, i=i, si=si: on_bucket(i, si, value),
                    shared=shared,
                    tenant=tenant,
                    priority=priority,
                )
            )

    def on_bucket(i: int, si: int, value: Any) -> None:
        """Per-item completion callback (Manager pump thread, outside the
        Manager lock): fold the bucket into input i's stage accumulator;
        when the stage closes, route outputs and submit the next stage —
        the per-input dependency edge."""
        st = states[i]
        advance = False
        with lock:
            st.remaining[si] -= 1
            if isinstance(value, Exception):
                errors.append(value)
            else:
                bucket_results, executed, hits = value
                st.executed[si] += executed
                st.hits[si] += hits
                st.routed.update(bucket_results)
                if st.remaining[si] == 0:
                    missing = set(range(plan.n_runs)) - set(st.routed)
                    if missing:
                        errors.append(
                            RuntimeError(
                                f"input {i}: stage {plan.stages[si].stage.name!r} "
                                f"produced no output for {len(missing)} runs "
                                f"(first: {sorted(missing)[:5]})"
                            )
                        )
                    else:
                        st.current = st.routed  # run_id-routed dataflow
                        st.routed = {}
                        if si + 1 < n_stages:
                            advance = True
                        else:
                            st.t_done = time.perf_counter()
        if advance:
            submit_stage(i, si + 1)
        done = 0
        with lock:
            outstanding[0] -= 1
            done_tasks[0] += 1
            done = done_tasks[0]
            if outstanding[0] == 0 and not seeding[0]:
                done_event.set()
        if on_progress is not None:
            on_progress(done, total_tasks)

    t0 = time.perf_counter()
    if owns_manager:
        mgr.start(cluster.n_workers)
    if spec_mode:
        # Broadcast the study context before any lease can reference it
        # (pipes are ordered). The plan id is session-unique so adaptive
        # rounds sharing one session never collide in the workers' caches.
        with _PLAN_IDS_LOCK:
            plan_id = f"plan{next(_PLAN_IDS)}"
        mgr.backend.install_study(
            plan_id=plan_id,
            recipe=plan.recipe,
            key_prefix=key_prefix,
            input_keys=list(input_keys),
            cache_enabled=plan.cache_enabled,
        )
    try:
        for i in range(len(inputs)):
            states[i].t_submit = time.perf_counter()
            submit_stage(i, 0)
        with lock:
            seeding[0] = False
            if outstanding[0] == 0:
                done_event.set()
        if shared:
            # wait for THIS study's keys only — mgr.drain() would also
            # wait on every other tenant's pending work in the session
            while not done_event.wait(0.05):
                if cancel_event is not None and cancel_event.is_set():
                    break
            if not done_event.is_set():
                raise TaskCancelled(
                    f"study cancelled: {key_prefix or '<unprefixed>'}"
                )
        else:
            mgr.drain()
    finally:
        if owns_manager:
            mgr.close()
        elif not shared:
            # shared session: outputs were consumed via callbacks; release
            # the memoised results so a many-round study stays bounded.
            # (In shared mode the service registry owns the release — keys
            # may still be referenced by other live jobs.)
            mgr.forget(submitted)
    if errors:
        raise errors[0]
    wall = time.perf_counter() - t0

    per_input = [
        StudyResult(
            outputs=st.current,
            tasks_executed=sum(st.executed),
            cache_hits=sum(st.hits),
            retries=0,  # session-wide: see StudyStreamResult.retries
            backups_launched=0,
            wall_seconds=st.t_done - st.t_submit,
            per_stage_executed=list(st.executed),
        )
        for st in states
    ]
    dispatch_delta = {
        name: count - dispatch0.get(name, 0)
        for name, count in mgr.dispatch_counts.items()
        if count - dispatch0.get(name, 0)
    }
    return StudyStreamResult(
        outputs={i: r.outputs for i, r in enumerate(per_input)},
        per_input=per_input,
        n_inputs=len(inputs),
        n_workers=cluster.n_workers,
        tasks_executed=sum(r.tasks_executed for r in per_input),
        cache_hits=sum(r.cache_hits for r in per_input),
        retries=mgr.retries - retries0,
        backups_launched=mgr.backups_launched - backups0,
        wall_seconds=wall,
        busy_seconds=mgr.busy_seconds - busy0,
        manager_sessions=1 if owns_manager else 0,
        cache_misses=(cache.misses - cache0[0]) if cache is not None else 0,
        cache_spills=(cache.spills - cache0[1]) if cache is not None else 0,
        cache_rehydrations=(
            (cache.rehydrations - cache0[2]) if cache is not None else 0
        ),
        backend=mgr.backend_name,
        dispatch_counts=dispatch_delta,
        scheduler=mgr.scheduler_stats(),
    )

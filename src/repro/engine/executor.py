"""execute_plan — the dispatch half of the unified StudyPlanner engine.

``execute_bucket`` replays one bucket's frozen schedule
(:func:`~repro.core.rmsr.replay_schedule`) with the run-level cache plugged
in; it is the unit of work both executors dispatch through the Manager.
``execute_plan`` executes a plan on ONE input and is the K=1 special case
of the streaming dataset executor (:mod:`repro.engine.streaming`): one
persistent Manager session, leaf outputs routed by ``run_id`` into the next
stage's buckets the moment the input's stage closes, so dataflow crosses
stage boundaries without caller wiring.

The run-level :class:`ResultCache` is keyed by ``(input, stage,
upstream-group, trie-path)``: a retried or backup bucket replays its
schedule but every already-computed merged prefix is a cache hit, and
sibling buckets of the same group share prefixes the bucketing could not
merge, while the input segment makes cross-input collisions structurally
impossible. Tasks are pure functions of ``(input, params)``, so cached
reuse is bit-identical to recomputation.
"""

from __future__ import annotations

import collections
import threading
from typing import Any, Dict, Optional, Tuple

from repro.core.rmsr import replay_schedule
from repro.engine.types import BucketPlan, ClusterSpec, StudyPlan, StudyResult
from repro.runtime.storage import HierarchicalStore

__all__ = ["ResultCache", "execute_bucket", "execute_plan"]


class ResultCache:
    """Thread-safe LRU cache of merged-task outputs, bounded in bytes.

    Entries are weighted by the task's declared ``output_bytes`` (the same
    model the schedule's liveness proof uses); an entry larger than the cap
    is never admitted to the RAM tier.

    With a ``spill_store`` (a :class:`repro.runtime.HierarchicalStore`), the
    cache becomes the top of a hierarchy instead of a discard-on-evict LRU:
    evicted and oversized entries are *spilled* to the store (RAM tier +
    content-addressed npz disk tier), and a RAM miss consults the store
    before reporting failure — a rehydrated entry counts as a hit and is
    served from the store (which promotes disk reads into its own
    LRU-bounded RAM tier) without re-entering this cache's declared-bytes
    accounting. This is what carries results across adaptive-study rounds
    and across process restarts (``repro.study``): the store's disk keys
    are content-addressed, so a cache rebuilt over the same directory
    resolves prior-round results instead of recomputing them.

    Counters: ``hits`` (successful lookups, either tier), ``rehydrations``
    (the subset served by the spill store), ``misses`` (failed lookups) and
    ``spills`` (entries written to the store on eviction/oversize).
    """

    def __init__(
        self, max_bytes: int, *, spill_store: Optional[HierarchicalStore] = None
    ):
        self.max_bytes = int(max_bytes)
        self.spill_store = spill_store
        self._entries: "collections.OrderedDict[Tuple, Tuple[Any, int]]" = (
            collections.OrderedDict()
        )  # guard: _lock
        self._bytes = 0  # guard: _lock
        self._lock = threading.Lock()
        self.hits = 0  # guard: _lock
        self.misses = 0  # guard: _lock
        self.spills = 0  # guard: _lock
        self.rehydrations = 0  # guard: _lock

    @staticmethod
    def _store_key(key: Tuple) -> str:
        # repr of the canonical key tuple (strings / numbers / nested
        # tuples) is deterministic across processes; the store content-
        # addresses it on disk (storage.stable_key).
        return repr(key)

    def get(self, key: Tuple) -> Tuple[bool, Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return True, self._entries[key][0]
        # store consultation happens OUTSIDE the cache lock: rehydration can
        # be a disk read, and holding the cache-wide lock across it would
        # serialize every worker's cache access behind one npz load.
        if self.spill_store is not None:
            value = self.spill_store.get(self._store_key(key))
            if value is not None:
                # served without re-admission: the declared output_bytes
                # that governed admission is not recoverable here, and
                # re-admitting by measured size would let a deliberately
                # oversized entry slip into the RAM tier. Repeated reads
                # stay cheap — the store promotes disk hits into its own
                # LRU-bounded RAM tier.
                with self._lock:
                    self.hits += 1
                    self.rehydrations += 1
                return True, value
        with self._lock:
            self.misses += 1
        return False, None

    def put(self, key: Tuple, value: Any, nbytes: int) -> None:
        nbytes = max(0, int(nbytes))
        spilled = []
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return
            if nbytes > self.max_bytes:
                # never admitted to RAM, but too valuable to drop when a
                # spill tier exists (it may be a whole merged prefix)
                if self.spill_store is not None:
                    self.spills += 1
                    spilled.append((key, value))
            else:
                self._entries[key] = (value, nbytes)
                self._bytes += nbytes
                while self._bytes > self.max_bytes and self._entries:
                    k, (v, b) = self._entries.popitem(last=False)
                    self._bytes -= b
                    if self.spill_store is not None:
                        self.spills += 1
                        spilled.append((k, v))
        # Spill I/O runs OUTSIDE the cache lock, mirroring get(): with a
        # SharedStore a spill can be a file-locked disk write, and holding
        # the cache-wide lock across it would serialize every worker. A
        # concurrent get() of a just-evicted, not-yet-spilled key reads as
        # a miss and recomputes — tasks are pure, so that is only wasted
        # work, never a wrong value.
        for k, v in spilled:
            self.spill_store.put(self._store_key(k), v)

    def flush(self) -> int:
        """Write every live entry through to the spill store's **disk**
        tier (durability barrier before persisting a StudyState, and the
        fleet workers' publish point — peers resolve the flushed keys on
        their next store consultation): the cache's RAM entries are pushed
        into the store, then the store's own RAM tier — which also holds
        previously-evicted entries that never reached disk — is persisted
        wholesale. No-op without a spill store; entries stay admitted.

        Returns the number of entries persisted to the disk tier (the
        store-RAM snapshot ``persist_all`` wrote through, which includes
        every cache entry just pushed) — 0 without a spill store. Callers
        surface it in study summaries so a silent no-op flush is visible.
        """
        if self.spill_store is None:
            return 0
        with self._lock:
            snapshot = [(key, value) for key, (value, _) in self._entries.items()]
        for key, value in snapshot:
            self.spill_store.put(self._store_key(key), value)
        return self.spill_store.persist_all()

    def counters(self) -> Dict[str, int]:
        """Point-in-time counter snapshot — the cache half of the RPC
        workers' warm-cache stats (heartbeats ship it; the backend's
        ``stats()`` aggregates it across the pool)."""
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "spills": self.spills,
                "rehydrations": self.rehydrations,
                "entries": len(self._entries),
            }


def execute_bucket(
    bucket: BucketPlan,
    input_state: Any,
    cache: Optional[ResultCache] = None,
    *,
    scope: Optional[Tuple[Any, ...]] = None,
) -> Tuple[Dict[int, Any], int, int]:
    """Replay a bucket's frozen schedule (``rmsr.replay_schedule``) with the
    run-level cache plugged in under ``scope`` (default: the bucket's own
    cache scope; the streaming executor prefixes an input segment). Returns
    ``(run_id -> leaf output, tasks executed, cache hits)``."""
    lookup = store = None
    if cache is not None:
        key_scope = bucket.cache_scope if scope is None else scope

        def lookup(pk):
            return cache.get(key_scope + (pk,))

        def store(pk, out, task, params):
            cache.put(key_scope + (pk,), out, task.bound_bytes(params))

    return replay_schedule(
        bucket.tree, bucket.schedule.order, input_state, lookup=lookup, store=store
    )


def execute_plan(
    plan: StudyPlan,
    input_state: Any,
    *,
    cluster: Optional[ClusterSpec] = None,
    backend: Any = None,
    hierarchy: Any = None,
) -> StudyResult:
    """Execute a :class:`StudyPlan` on one input, returning per-run outputs.

    Results are bit-identical across policies and worker counts: tasks are
    pure, every bucket replays a frozen schedule, and stage routing is keyed
    by ``run_id`` alone. This is ``execute_study`` with a one-element
    dataset — same session machinery, same cache keying, same accounting.
    ``backend`` is the session's WorkerBackend spec (default: in-process
    Worker threads; pass a ``ProcessRpcBackend`` for RPC worker processes);
    ``hierarchy`` is the session's scheduler topology (DESIGN.md §15 —
    flat single pump by default, ``"fanout=N"`` for manager-of-managers).
    """
    from repro.engine.streaming import execute_study  # circular at import time

    stream = execute_study(
        plan, [input_state], cluster=cluster, backend=backend,
        hierarchy=hierarchy,
    )
    only = stream.per_input[0]
    return StudyResult(
        outputs=only.outputs,
        tasks_executed=only.tasks_executed,
        cache_hits=only.cache_hits,
        retries=stream.retries,
        backups_launched=stream.backups_launched,
        wall_seconds=stream.wall_seconds,
        per_stage_executed=only.per_stage_executed,
        cache_misses=stream.cache_misses,
        cache_spills=stream.cache_spills,
        cache_rehydrations=stream.cache_rehydrations,
        backend=stream.backend,
        dispatch_counts=dict(stream.dispatch_counts),
    )

"""execute_plan — the dispatch half of the unified StudyPlanner engine.

Stages run in order (a stage is a barrier); within a stage, every bucket is
a :class:`~repro.runtime.manager.WorkItem` dispatched demand-driven through
the Manager (heartbeats, retries, straggler backup tasks). Leaf outputs are
routed by ``run_id`` into the next stage's instances, so dataflow crosses
stage boundaries without caller wiring.

The run-level :class:`ResultCache` is keyed by ``(stage, upstream-group,
trie-path)``: a retried or backup bucket replays its schedule but every
already-computed merged prefix is a cache hit, and sibling buckets of the
same group share prefixes the bucketing could not merge. Tasks are pure
functions of ``(input, params)``, so cached reuse is bit-identical to
recomputation.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.rmsr import replay_schedule
from repro.runtime.manager import Manager, WorkItem
from repro.engine.types import BucketPlan, ClusterSpec, StudyPlan, StudyResult

__all__ = ["ResultCache", "execute_bucket", "execute_plan"]


class ResultCache:
    """Thread-safe LRU cache of merged-task outputs, bounded in bytes.

    Entries are weighted by the task's declared ``output_bytes`` (the same
    model the schedule's liveness proof uses); an entry larger than the cap
    is never admitted.
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._entries: "collections.OrderedDict[Tuple, Tuple[Any, int]]" = (
            collections.OrderedDict()
        )
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple) -> Tuple[bool, Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return True, self._entries[key][0]
            self.misses += 1
            return False, None

    def put(self, key: Tuple, value: Any, nbytes: int) -> None:
        nbytes = max(0, int(nbytes))
        if nbytes > self.max_bytes:
            return
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return
            self._entries[key] = (value, nbytes)
            self._bytes += nbytes
            while self._bytes > self.max_bytes and self._entries:
                _, (_, b) = self._entries.popitem(last=False)
                self._bytes -= b


def execute_bucket(
    bucket: BucketPlan,
    input_state: Any,
    cache: Optional[ResultCache] = None,
) -> Tuple[Dict[int, Any], int, int]:
    """Replay a bucket's frozen schedule (``rmsr.replay_schedule``) with the
    run-level cache plugged in under the bucket's cache scope. Returns
    ``(run_id -> leaf output, tasks executed, cache hits)``."""
    lookup = store = None
    if cache is not None:
        scope = bucket.cache_scope

        def lookup(pk):
            return cache.get(scope + (pk,))

        def store(pk, out, task, params):
            cache.put(scope + (pk,), out, task.bound_bytes(params))

    return replay_schedule(
        bucket.tree, bucket.schedule.order, input_state, lookup=lookup, store=store
    )


def execute_plan(
    plan: StudyPlan,
    input_state: Any,
    *,
    cluster: Optional[ClusterSpec] = None,
) -> StudyResult:
    """Execute a :class:`StudyPlan` on one input, returning per-run outputs.

    Results are bit-identical across policies and worker counts: tasks are
    pure, every bucket replays a frozen schedule, and stage routing is keyed
    by ``run_id`` alone.
    """
    cluster = cluster or plan.cluster or ClusterSpec()
    cache = (
        ResultCache(plan.memory.effective_cache_bytes) if plan.cache_enabled else None
    )
    t0 = time.perf_counter()

    current: Dict[int, Any] = {rid: input_state for rid in range(plan.n_runs)}
    total_executed = 0
    total_hits = 0
    total_retries = 0
    total_backups = 0
    per_stage_executed: List[int] = []
    for stage_plan in plan.stages:
        mgr = Manager(
            max_attempts=cluster.max_attempts,
            heartbeat_timeout=cluster.heartbeat_timeout,
            straggler_factor=cluster.straggler_factor,
            enable_backup_tasks=cluster.enable_backup_tasks,
        )
        for bi, bucket in enumerate(stage_plan.buckets):
            inp = current[bucket.run_ids[0]]
            mgr.submit(
                WorkItem(
                    key=f"{stage_plan.index}:{stage_plan.stage.name}:{bi}",
                    fn=lambda b=bucket, s=inp: execute_bucket(b, s, cache),
                )
            )
        per_bucket = mgr.run(cluster.n_workers, expected=len(stage_plan.buckets))
        total_retries += mgr.retries
        total_backups += mgr.backups_launched

        stage_executed = 0
        routed: Dict[int, Any] = {}
        for value in per_bucket.values():
            if isinstance(value, Exception):
                raise value
            bucket_results, executed, hits = value
            stage_executed += executed
            total_hits += hits
            routed.update(bucket_results)
        missing = set(range(plan.n_runs)) - set(routed)
        if missing:
            raise RuntimeError(
                f"stage {stage_plan.stage.name!r} produced no output for "
                f"{len(missing)} runs (first: {sorted(missing)[:5]})"
            )
        per_stage_executed.append(stage_executed)
        total_executed += stage_executed
        current = routed  # run_id-routed dataflow into the next stage

    return StudyResult(
        outputs=current,
        tasks_executed=total_executed,
        cache_hits=total_hits,
        retries=total_retries,
        backups_launched=total_backups,
        wall_seconds=time.perf_counter() - t0,
        per_stage_executed=per_stage_executed,
    )

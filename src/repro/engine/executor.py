"""execute_plan — the dispatch half of the unified StudyPlanner engine.

``execute_bucket`` replays one bucket's frozen schedule
(:func:`~repro.core.rmsr.replay_schedule`) with the run-level cache plugged
in; it is the unit of work both executors dispatch through the Manager.
``execute_plan`` executes a plan on ONE input and is the K=1 special case
of the streaming dataset executor (:mod:`repro.engine.streaming`): one
persistent Manager session, leaf outputs routed by ``run_id`` into the next
stage's buckets the moment the input's stage closes, so dataflow crosses
stage boundaries without caller wiring.

The run-level :class:`ResultCache` is keyed by ``(input, stage,
upstream-group, trie-path)``: a retried or backup bucket replays its
schedule but every already-computed merged prefix is a cache hit, and
sibling buckets of the same group share prefixes the bucketing could not
merge, while the input segment makes cross-input collisions structurally
impossible. Tasks are pure functions of ``(input, params)``, so cached
reuse is bit-identical to recomputation.
"""

from __future__ import annotations

import collections
import threading
from typing import Any, Dict, Optional, Tuple

from repro.core.rmsr import replay_schedule
from repro.engine.types import BucketPlan, ClusterSpec, StudyPlan, StudyResult

__all__ = ["ResultCache", "execute_bucket", "execute_plan"]


class ResultCache:
    """Thread-safe LRU cache of merged-task outputs, bounded in bytes.

    Entries are weighted by the task's declared ``output_bytes`` (the same
    model the schedule's liveness proof uses); an entry larger than the cap
    is never admitted.
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._entries: "collections.OrderedDict[Tuple, Tuple[Any, int]]" = (
            collections.OrderedDict()
        )
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple) -> Tuple[bool, Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return True, self._entries[key][0]
            self.misses += 1
            return False, None

    def put(self, key: Tuple, value: Any, nbytes: int) -> None:
        nbytes = max(0, int(nbytes))
        if nbytes > self.max_bytes:
            return
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return
            self._entries[key] = (value, nbytes)
            self._bytes += nbytes
            while self._bytes > self.max_bytes and self._entries:
                _, (_, b) = self._entries.popitem(last=False)
                self._bytes -= b


def execute_bucket(
    bucket: BucketPlan,
    input_state: Any,
    cache: Optional[ResultCache] = None,
    *,
    scope: Optional[Tuple[Any, ...]] = None,
) -> Tuple[Dict[int, Any], int, int]:
    """Replay a bucket's frozen schedule (``rmsr.replay_schedule``) with the
    run-level cache plugged in under ``scope`` (default: the bucket's own
    cache scope; the streaming executor prefixes an input segment). Returns
    ``(run_id -> leaf output, tasks executed, cache hits)``."""
    lookup = store = None
    if cache is not None:
        key_scope = bucket.cache_scope if scope is None else scope

        def lookup(pk):
            return cache.get(key_scope + (pk,))

        def store(pk, out, task, params):
            cache.put(key_scope + (pk,), out, task.bound_bytes(params))

    return replay_schedule(
        bucket.tree, bucket.schedule.order, input_state, lookup=lookup, store=store
    )


def execute_plan(
    plan: StudyPlan,
    input_state: Any,
    *,
    cluster: Optional[ClusterSpec] = None,
) -> StudyResult:
    """Execute a :class:`StudyPlan` on one input, returning per-run outputs.

    Results are bit-identical across policies and worker counts: tasks are
    pure, every bucket replays a frozen schedule, and stage routing is keyed
    by ``run_id`` alone. This is ``execute_study`` with a one-element
    dataset — same session machinery, same cache keying, same accounting.
    """
    from repro.engine.streaming import execute_study  # circular at import time

    stream = execute_study(plan, [input_state], cluster=cluster)
    only = stream.per_input[0]
    return StudyResult(
        outputs=only.outputs,
        tasks_executed=only.tasks_executed,
        cache_hits=only.cache_hits,
        retries=stream.retries,
        backups_launched=stream.backups_launched,
        wall_seconds=stream.wall_seconds,
        per_stage_executed=only.per_stage_executed,
    )

"""plan_study — the planning half of the unified StudyPlanner engine.

One pipeline for every SA workload (DESIGN.md §3/§4):

  1. **group**    — stage-*k* instances are partitioned by their *upstream
                    signature* (the concatenated task keys of stages < k).
                    Two runs share a group iff every upstream task they
                    consumed agrees, i.e. iff they receive bit-identical
                    stage inputs — the precondition for merging them. A
                    parameter-free stage yields a single group containing a
                    single-path trie, so it collapses to one shared
                    execution automatically.
  2. **bucket**   — a pluggable policy splits each group into merge units:
                    ``"rtma"``   paper baseline, buckets capped by
                                 ``max_bucket_for_budget`` (breadth-eligible
                                 execution, width-proportional memory);
                    ``"rmsr"``   one maximal bucket, ``active_paths`` solved
                                 against the budget (depth-first execution);
                    ``"hybrid"`` RTMA-sized buckets each scheduled by RMSR —
                                 the paper's Fig 6/7 matrix as one API;
                    ``"stage"``  coarse-grain dedup only;
                    ``"none"``   the no-reuse baseline.
  3. **schedule** — every bucket's reuse tree is traversed ahead-of-time
                    (``simulate_execution``) to freeze the execution order
                    and prove its peak live bytes.

The resulting :class:`StudyPlan` is input-independent: plan once, execute on
many inputs (tiles, prompt batches) via ``execute_plan``.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.params import ParamSet
from repro.core.reuse import build_reuse_tree
from repro.core.rmsr import min_active_paths, simulate_execution, tree_peak_bytes
from repro.core.rtma import max_bucket_for_budget, rtma_buckets
from repro.core.workflow import StageInstance, StageSpec, Workflow
from repro.engine.types import (
    DEFAULT_MAX_BUCKET,
    POLICIES,
    BucketPlan,
    ClusterSpec,
    MemoryBudget,
    StagePlan,
    StudyPlan,
)

__all__ = ["TrieLedger", "plan_study"]

_ALL_ELIGIBLE = 10**9  # "unbounded workers": RTMA's whole frontier is live


class TrieLedger:
    """Cross-round record of planned trie paths — the "cached trie" an
    adaptive study plans its delta against (DESIGN.md §11).

    Members are the deterministic ``repr`` of the executor's input-agnostic
    cache keys (``bucket.cache_scope + (trie-path,)``), so ledger membership
    means exactly: *a prior plan scheduled this merged task, and the
    persistent result store holds (or held) its output*. ``plan_study``
    consults the ledger to annotate each bucket's ``known_nodes`` — the
    plan-time prediction of which merged tasks the store will serve — and
    records the rest, making the next round's plan incremental too.

    The ledger is a plain string set, so it serialises into a StudyState
    checkpoint losslessly (``to_list``/``from_list``).
    """

    def __init__(self, entries: Optional[Iterable[str]] = None):
        self._seen: Set[str] = set(entries or ())

    def __len__(self) -> int:
        return len(self._seen)

    def __contains__(self, key: Tuple) -> bool:
        return repr(key) in self._seen

    def add_all(self, keys: Iterable[Tuple]) -> None:
        self._seen.update(repr(k) for k in keys)

    def merge(self, entries: Iterable[str]) -> None:
        """Union already-serialised entries (``to_list`` output from another
        process's ledger) into this one — the fleet-merge path: round N+1
        plans against the union of every process's committed keys."""
        self._seen.update(entries)

    def to_list(self) -> List[str]:
        return sorted(self._seen)

    @classmethod
    def from_list(cls, entries: Iterable[str]) -> "TrieLedger":
        return cls(entries)


def _annotate_with_ledger(
    stage_plans: List[StagePlan], ledger: TrieLedger
) -> List[Tuple]:
    """Mark each bucket's trie nodes as known/new against the ledger.

    Knownness is assessed against the ledger *at entry* (prior rounds), not
    against siblings of this plan — intra-plan duplicate prefixes are the
    run-level cache's business and are already visible in the measured
    hit counters. Returns the plan's NEW keys; the caller commits them to
    the ledger only once the plan has actually executed (ledger membership
    means "the store holds, or held, this output" — a plan that fails
    mid-execution must not poison the next round's accounting).
    """
    new_keys: List[Tuple] = []
    for sp in stage_plans:
        for bucket in sp.buckets:
            known = 0
            stack: List[Tuple[Any, Tuple]] = [
                (child, ()) for child in bucket.tree.root.children.values()
            ]
            while stack:
                node, prefix = stack.pop()
                pk = prefix + (node.key,)
                full = bucket.cache_scope + (pk,)
                if full in ledger:
                    known += 1
                else:
                    new_keys.append(full)
                stack.extend((c, pk) for c in node.children.values())
            bucket.known_nodes = known
    return new_keys


def _rtma_bucket_size(
    stage: StageSpec,
    instances: Sequence[StageInstance],
    memory: MemoryBudget,
    max_bucket_size: Optional[int],
) -> int:
    if max_bucket_size is not None:
        return max(1, max_bucket_size)
    if memory.schedule_bytes is not None:
        return max_bucket_for_budget(
            stage, instances, memory.schedule_bytes, tree_peak_bytes
        )
    return DEFAULT_MAX_BUCKET


def _by_signature(
    instances: Sequence[StageInstance],
) -> Dict[Any, List[StageInstance]]:
    """Stage-level dedup grouping: one entry per distinct full task-key
    signature (the same equivalence ``reuse.stage_level_dedup`` uses)."""
    by_sig: Dict[Any, List[StageInstance]] = {}
    for inst in instances:
        by_sig.setdefault(inst.task_keys(), []).append(inst)
    return by_sig


def _plan_group(
    stage_index: int,
    stage: StageSpec,
    group_key: Any,
    instances: List[StageInstance],
    policy: str,
    memory: MemoryBudget,
    max_bucket_size: Optional[int],
    active_paths: Optional[int],
    workers: Optional[int],
) -> List[BucketPlan]:
    if policy == "none":
        parts: List[List[StageInstance]] = [[i] for i in instances]
    elif policy == "stage":
        by_sig = _by_signature(instances)
        parts = [by_sig[k] for k in sorted(by_sig, key=repr)]
    elif policy == "rmsr":
        parts = [list(instances)]
    else:  # rtma | hybrid
        # stage-level dedup first: bucket one representative per distinct
        # signature, then re-attach the duplicates to their representative's
        # bucket (same trie path, so the node count is unchanged and every
        # run_id still routes).
        by_sig = _by_signature(instances)
        reps = [group[0] for group in by_sig.values()]
        bsize = _rtma_bucket_size(stage, reps, memory, max_bucket_size)
        parts = [
            [inst for rep in bk.instances for inst in by_sig[rep.task_keys()]]
            for bk in rtma_buckets(stage, reps, bsize)
        ]

    out: List[BucketPlan] = []
    depth_first = policy in ("rmsr", "hybrid")
    for part in parts:
        tree = build_reuse_tree(stage, part)
        if depth_first:
            paths = active_paths
            if paths is None:
                if memory.schedule_bytes is not None:
                    paths = min_active_paths(tree, memory.schedule_bytes) or 1
                else:
                    paths = 1
            sched = simulate_execution(tree, paths, discipline="lifo")
            disc = "lifo"
        else:
            paths = workers if workers is not None else _ALL_ELIGIBLE
            sched = simulate_execution(tree, paths, discipline="fifo")
            disc = "fifo"
        out.append(
            BucketPlan(
                stage_index=stage_index,
                stage_name=stage.name,
                group_key=group_key,
                instances=part,
                tree=tree,
                schedule=sched,
                active_paths=paths,
                discipline=disc,
            )
        )
    return out


def plan_study(
    workflow: Workflow,
    param_sets: Sequence[ParamSet],
    *,
    memory: Optional[MemoryBudget] = None,
    cluster: Optional[ClusterSpec] = None,
    policy: str = "hybrid",
    max_bucket_size: Optional[int] = None,
    active_paths: Optional[int] = None,
    workers: Optional[int] = None,
    ledger: Optional[TrieLedger] = None,
) -> StudyPlan:
    """Plan an SA study: stage-level dedup, per-stage reuse trees, pluggable
    bucketing, AOT schedules with exact peak-bytes, and multi-stage routing.

    ``workers`` only parameterises the breadth-eligible (RTMA) makespan
    model; ``active_paths`` overrides the budget-solved RMSR bound.

    **Incremental path** (adaptive multi-round studies, DESIGN.md §11):
    passing a :class:`TrieLedger` makes the plan *delta-aware*. Callers
    (``repro.study.StudyDriver``) first drop ParamSets whose outputs prior
    rounds already produced, so ``param_sets`` is the round's delta
    run-list; the ledger then annotates every bucket with ``known_nodes`` —
    trie paths a prior round planned, whose outputs the persistent result
    store will serve as cache hits — and ``plan.tasks_new`` is the true
    marginal work of this round. The plan's not-yet-known keys are staged
    on ``plan.ledger_pending``; callers commit them with
    ``ledger.add_all(plan.ledger_pending)`` after the plan executes
    successfully, so a failed round never records phantom results.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    memory = memory or MemoryBudget()
    param_sets = list(param_sets)
    by_stage = workflow.instantiate(param_sets)

    # Upstream signature per run: grows one element per planned stage; runs
    # with equal signatures provably receive identical stage inputs.
    upstream: Dict[int, tuple] = {rid: () for rid in range(len(param_sets))}
    stage_plans: List[StagePlan] = []
    for si, stage in enumerate(workflow.stages):
        instances = by_stage[stage.name]
        groups: Dict[tuple, List[StageInstance]] = {}
        for inst in instances:
            groups.setdefault(upstream[inst.run_id], []).append(inst)
        buckets: List[BucketPlan] = []
        for gkey in sorted(groups, key=repr):
            buckets.extend(
                _plan_group(
                    si, stage, gkey, groups[gkey], policy, memory,
                    max_bucket_size, active_paths, workers,
                )
            )
        stage_plans.append(
            StagePlan(
                stage=stage,
                index=si,
                buckets=buckets,
                tasks_total=len(instances) * len(stage.tasks),
            )
        )
        for inst in instances:
            upstream[inst.run_id] = upstream[inst.run_id] + (inst.task_keys(),)

    ledger_pending = (
        _annotate_with_ledger(stage_plans, ledger) if ledger is not None else None
    )

    return StudyPlan(
        workflow=workflow,
        n_runs=len(param_sets),
        policy=policy,
        stages=stage_plans,
        memory=memory,
        cluster=cluster,
        ledger_pending=ledger_pending,
        # Everything needed to rebuild this plan against the same workflow
        # in another process (planning is deterministic; the ledger only
        # annotates counters, so it is deliberately absent). All values are
        # picklable — ParamSets are tuples of (name, primitive).
        recipe={
            "param_sets": [tuple(ps) for ps in param_sets],
            "policy": policy,
            "max_bucket_size": max_bucket_size,
            "active_paths": active_paths,
            "workers": workers,
            "memory_bytes": memory.bytes,
            "cache_bytes": memory.cache_bytes,
        },
    )

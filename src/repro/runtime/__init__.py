"""Distributed runtime: Manager-Worker demand-driven dispatch behind the
transport-agnostic WorkerBackend boundary (threads or RPC worker
processes), hierarchical storage, fault tolerance (heartbeats/retry/backup
tasks), elastic scaling, and the paper-scale cluster simulator."""

from repro.runtime.fairshare import FairQueue, TaskCancelled  # noqa: F401
from repro.runtime.hierarchy import (  # noqa: F401
    HierarchySpec,
    parse_hierarchy,
)
from repro.runtime.manager import Manager, WorkItem, run_study_distributed  # noqa: F401
from repro.runtime.net import (  # noqa: F401
    SocketBackend,
    run_worker,
    socket_flag_kwargs,
)
from repro.runtime.objstore import (  # noqa: F401
    InMemoryObjectStore,
    LocalFSObjectStore,
    ObjectBackedStore,
    ObjectStore,
)
from repro.runtime.transport import (  # noqa: F401
    Completion,
    Lease,
    ProcessRpcBackend,
    RemoteTaskError,
    ThreadBackend,
    TransportError,
    WorkerBackend,
    WorkerStatus,
    make_backend,
)
from repro.runtime.simulator import (  # noqa: F401
    AutotuneResult,
    ClusterSim,
    StreamSim,
    autotune_stream,
    simulate_cluster,
    simulate_stream,
)
from repro.runtime.storage import (  # noqa: F401
    HierarchicalStore,
    SharedStore,
    mount_store,
)

"""Fair-share dispatch queue — deficit round robin across tenants with
priority buckets inside each tenant (DESIGN.md §18).

The Manager's global queue was a plain FIFO deque, which is exactly right
for a single study but starves everyone else the moment a long-lived
service session multiplexes tenants: one tenant submitting 10k buckets
ahead of a 10-bucket job monopolises every dispatch slot until its backlog
drains. :class:`FairQueue` keeps the deque surface the Manager's dispatch
paths already speak (``append`` / ``appendleft`` / ``popleft`` / ``in`` /
iteration) while making ``popleft`` a **deficit-round-robin** draw across
tenants:

* each tenant owns one logical queue, internally split into priority
  buckets (higher :attr:`~repro.runtime.manager.WorkItem.priority` first,
  FIFO within a priority);
* a round-robin ring visits tenants with queued work; each visit grants
  the tenant its *quantum* (= its weight, default 1.0) of deficit credit,
  and every pop spends 1.0 — so a weight-2 tenant drains twice as fast as
  a weight-1 tenant, and a weight-0.25 tenant still pops once every four
  ring rotations (monotonic progress, never starvation);
* a tenant's unspent credit is capped and zeroed when its queue empties,
  so an idle tenant cannot bank credit and later burst past its share.

With a single tenant (every WorkItem carrying the default ``tenant=""``
and ``priority=0``) the structure degenerates to the exact FIFO order of
the deque it replaces — the single-study schedules, and therefore their
outputs, are unchanged byte for byte.

All mutation happens under the owning Manager's lock (the instance has no
lock of its own), mirroring how the hierarchical sub-queues are guarded.
"""

from __future__ import annotations

import collections
from typing import Dict, Iterator, List, Optional

__all__ = ["FairQueue", "TaskCancelled"]

# Unspent deficit credit a tenant may bank while it has queued work: big
# enough to let a high-weight tenant burst a few items per visit, small
# enough that fairness is enforced within every ring rotation or two.
_DEFICIT_CAP = 8.0


class TaskCancelled(Exception):
    """Settled value of a WorkItem revoked by :meth:`Manager.cancel`: the
    key's callback fires exactly once with this exception, any in-flight
    lease is poisoned (its eventual completion is dropped), and the key
    can be resubmitted as a fresh lifecycle after ``forget``."""


class FairQueue:
    """Deficit-round-robin multi-tenant queue of WorkItems.

    Items must expose ``key``, ``tenant`` and ``priority`` attributes
    (:class:`~repro.runtime.manager.WorkItem` does). Not thread-safe by
    itself — the Manager mutates it under its own lock.
    """

    def __init__(self) -> None:
        # tenant -> priority -> FIFO deque of items
        self._buckets: Dict[str, Dict[int, collections.deque]] = {}
        self._counts: Dict[str, int] = {}
        self._ring: List[str] = []  # tenant visit order (insertion order)
        self._cursor = 0
        self._deficit: Dict[str, float] = {}
        self._weights: Dict[str, float] = {}
        self._len = 0

    # -- configuration --------------------------------------------------
    def set_weight(self, tenant: str, weight: float) -> None:
        """Set a tenant's fair-share quantum (default 1.0). Values below
        a small positive floor are clamped — a zero weight would mean
        literal starvation, and the whole point of DRR is that every
        tenant makes progress."""
        self._weights[tenant] = max(0.05, float(weight))

    # -- deque surface ---------------------------------------------------
    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0

    def __iter__(self) -> Iterator:
        """Snapshot iteration (tenant ring order, priority-major). Used by
        the Manager's purge/failover scans; scheduling order is defined by
        ``popleft``, not by iteration."""
        for tenant in self._ring:
            prios = self._buckets.get(tenant)
            if not prios:
                continue
            for prio in sorted(prios, reverse=True):
                yield from prios[prio]

    def _tenant_of(self, item) -> str:
        return getattr(item, "tenant", "") or ""

    def _ensure_tenant(self, tenant: str) -> None:
        if tenant not in self._buckets:
            self._buckets[tenant] = {}
            self._counts[tenant] = 0
            self._deficit.setdefault(tenant, 0.0)
            self._ring.append(tenant)

    def append(self, item) -> None:
        tenant = self._tenant_of(item)
        self._ensure_tenant(tenant)
        prio = int(getattr(item, "priority", 0) or 0)
        self._buckets[tenant].setdefault(prio, collections.deque()).append(item)
        self._counts[tenant] += 1
        self._len += 1

    def appendleft(self, item) -> None:
        """Return an item to the head of its (tenant, priority) bucket —
        the unlease/revert path. The pop that removed it spent a unit of
        the tenant's deficit; refund it so fairness accounting is exact."""
        tenant = self._tenant_of(item)
        self._ensure_tenant(tenant)
        prio = int(getattr(item, "priority", 0) or 0)
        self._buckets[tenant].setdefault(
            prio, collections.deque()
        ).appendleft(item)
        self._counts[tenant] += 1
        self._len += 1
        self._deficit[tenant] = min(
            self._deficit.get(tenant, 0.0) + 1.0, _DEFICIT_CAP
        )

    def _pop_tenant(self, tenant: str):
        prios = self._buckets[tenant]
        prio = max(prios)
        bucket = prios[prio]
        item = bucket.popleft()
        if not bucket:
            del prios[prio]
        self._counts[tenant] -= 1
        self._len -= 1
        return item

    def popleft(self):
        """DRR draw: the next item the dispatch path should lease."""
        if not self._len:
            raise IndexError("pop from an empty FairQueue")
        ring = self._ring
        n = len(ring)
        if n == 1:  # single tenant: exact FIFO-within-priority, no credit
            return self._pop_tenant(ring[0])
        # Bounded scan: each full rotation grants every backlogged tenant
        # its quantum (>= 0.05), so some deficit reaches 1.0 within at
        # most ceil(1/min_weight) rotations.
        for _ in range(n * 32):
            tenant = ring[self._cursor % n]
            count = self._counts.get(tenant, 0)
            if count and self._deficit.get(tenant, 0.0) >= 1.0:
                self._deficit[tenant] -= 1.0
                item = self._pop_tenant(tenant)
                if not self._counts[tenant]:
                    # an emptied tenant banks nothing: credit accrues only
                    # against real backlog
                    self._deficit[tenant] = 0.0
                    self._cursor = (self._cursor + 1) % n
                elif self._deficit[tenant] < 1.0:
                    # quantum spent: yield the ring to the next tenant (a
                    # high-weight tenant keeps the floor while it can
                    # still afford a pop — that IS its larger share)
                    self._cursor = (self._cursor + 1) % n
                return item
            if count:
                self._deficit[tenant] = min(
                    self._deficit.get(tenant, 0.0)
                    + self._weights.get(tenant, 1.0),
                    _DEFICIT_CAP,
                )
                if self._deficit[tenant] >= 1.0:
                    continue  # spend it on this same visit
            else:
                self._deficit[tenant] = 0.0
            self._cursor = (self._cursor + 1) % n
        # Pathological weights (everyone clamped tiny): degrade to FIFO
        # across the ring rather than spin.
        for tenant in ring:
            if self._counts.get(tenant, 0):
                return self._pop_tenant(tenant)
        raise IndexError("FairQueue length drifted")  # pragma: no cover

    # -- bulk surgery (purge paths) --------------------------------------
    def remove_keys(self, keys) -> int:
        """Drop every queued item whose ``key`` is in ``keys`` (forget /
        cancel / resubmission purges). Returns the number removed."""
        keyset = set(keys)
        removed = 0
        # analysis: ok[spawn] purge sweep, not key derivation — removal is
        # order-independent (membership test against a frozen keyset)
        for tenant, prios in self._buckets.items():
            for prio in list(prios):
                bucket = prios[prio]
                if not any(it.key in keyset for it in bucket):
                    continue
                kept = collections.deque(
                    it for it in bucket if it.key not in keyset
                )
                dropped = len(bucket) - len(kept)
                if kept:
                    prios[prio] = kept
                else:
                    del prios[prio]
                self._counts[tenant] -= dropped
                removed += dropped
        self._len -= removed
        return removed

    def clear(self) -> None:
        for tenant in self._ring:
            self._buckets[tenant] = {}
            self._counts[tenant] = 0
            self._deficit[tenant] = 0.0
        self._len = 0

    # -- introspection ----------------------------------------------------
    def depths(self) -> Dict[str, int]:
        """tenant -> queued items (only tenants with backlog)."""
        return {t: c for t, c in self._counts.items() if c}

    def head_tenant(self) -> Optional[str]:
        for tenant, count in self._counts.items():
            if count:
                return tenant
        return None

"""Discrete-event simulator of the Manager-Worker cluster at paper scale
(256 nodes × 28 cores) — drives the fig8 multi-node scalability benchmark.

Cost model: per-bucket compute times come from *measured* JAX task
wall-times composed over the bucket's merged task tree (the same model the
paper's gains rest on: reuse changes WHICH tasks run, not how fast a task
is). Per-bucket dispatch latency and per-tile I/O are charged per the RTF's
demand-driven protocol; node_speed jitter injects stragglers.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["ClusterSim", "simulate_cluster", "StreamSim", "simulate_stream"]


@dataclasses.dataclass
class ClusterSim:
    makespan: float
    busy_time: float
    n_nodes: int
    cores_per_node: int

    @property
    def parallel_efficiency(self) -> float:
        return self.busy_time / (self.makespan * self.n_nodes * self.cores_per_node)


def simulate_cluster(
    bucket_costs: Sequence[float],
    *,
    n_nodes: int,
    cores_per_node: int = 28,
    dispatch_latency: float = 2e-3,
    io_per_bucket: float = 0.05,
    node_speed_sigma: float = 0.03,
    seed: int = 0,
) -> ClusterSim:
    """Demand-driven list scheduling of buckets onto node-cores.

    Each core pulls the next bucket when free (the RTF protocol). Node speed
    is jittered (shared-memory/I-O contention, the paper's §IV-D explanation
    for sub-ideal multicore speedups is modelled as a per-node slowdown).
    """
    rng = np.random.default_rng(seed)
    speeds = 1.0 + rng.normal(0, node_speed_sigma, n_nodes).clip(-0.2, 0.2)
    # executor heap: (free_time, core_id); cores indexed node-major
    n_cores = n_nodes * cores_per_node
    heap = [(0.0, i) for i in range(n_cores)]
    heapq.heapify(heap)
    busy = 0.0
    makespan = 0.0
    for cost in sorted(bucket_costs, reverse=True):  # LPT demand-driven
        t, core = heapq.heappop(heap)
        node = core // cores_per_node
        dur = cost / speeds[node] + io_per_bucket
        end = t + dispatch_latency + dur
        busy += dur
        makespan = max(makespan, end)
        heapq.heappush(heap, (end, core))
    return ClusterSim(
        makespan=makespan,
        busy_time=busy,
        n_nodes=n_nodes,
        cores_per_node=cores_per_node,
    )


@dataclasses.dataclass
class StreamSim:
    """Result of :func:`simulate_stream` — the streaming dataset executor at
    paper scale (many tiles through one multi-stage plan)."""

    makespan: float
    busy_time: float
    n_inputs: int
    n_nodes: int
    cores_per_node: int

    @property
    def parallel_efficiency(self) -> float:
        from repro.core.metrics import parallel_efficiency

        return parallel_efficiency(
            self.busy_time, self.makespan, self.n_nodes * self.cores_per_node
        )

    @property
    def throughput(self) -> float:
        from repro.core.metrics import throughput

        return throughput(self.n_inputs, self.makespan)


def simulate_stream(
    stage_bucket_costs: Sequence[Sequence[float]],
    n_inputs: int,
    *,
    n_nodes: int,
    cores_per_node: int = 28,
    dispatch_latency: float = 2e-3,
    io_per_bucket: float = 0.05,
    node_speed_sigma: float = 0.03,
    input_cost_sigma: float = 0.05,
    seed: int = 0,
    barrier: bool = False,
) -> StreamSim:
    """Discrete-event model of ``execute_study`` at paper scale.

    ``stage_bucket_costs[s]`` is the per-bucket compute cost list of stage
    *s* of ONE input's plan (the frozen schedules' makespans); every input
    replays the same plan with a per-input cost jitter (tile content
    varies). Dependency structure mirrors the executor: with
    ``barrier=False`` (streaming), stage *s+1* buckets of input *i* become
    ready when input *i* finishes stage *s* — inputs pipeline freely across
    stages. With ``barrier=True`` (the pre-streaming global barrier), stage
    *s+1* opens only after EVERY input finished stage *s* — the idle tail
    this executor removed. Cores pull ready buckets demand-driven (RTF).
    """
    stage_bucket_costs = [list(s) for s in stage_bucket_costs]
    if any(not s for s in stage_bucket_costs):
        # an empty stage would stall its dependents silently (no completion
        # event ever opens stage s+1) — reject degenerate plans loudly
        raise ValueError("every stage needs at least one bucket cost")
    rng = np.random.default_rng(seed)
    speeds = 1.0 + rng.normal(0, node_speed_sigma, n_nodes).clip(-0.2, 0.2)
    jitter = 1.0 + rng.normal(0, input_cost_sigma, n_inputs).clip(-0.5, 0.5)
    n_stages = len(stage_bucket_costs)
    n_cores = n_nodes * cores_per_node

    ready: "collections.deque" = collections.deque()  # (input, stage, cost)
    remaining = np.zeros((n_inputs, n_stages), dtype=np.int64)
    stage_open = np.zeros(n_stages, dtype=np.int64)  # inputs not yet done (barrier)

    def enqueue(i: int, s: int) -> None:
        for c in stage_bucket_costs[s]:
            ready.append((i, s, c * jitter[i]))
        remaining[i, s] = len(stage_bucket_costs[s])

    for s in range(n_stages):
        stage_open[s] = n_inputs
    for i in range(n_inputs):
        enqueue(i, 0)

    idle: "collections.deque" = collections.deque(range(n_cores))
    running: List = []  # (end_time, tiebreak, input, stage, core)
    t = 0.0
    busy = 0.0
    tiebreak = 0

    def dispatch() -> None:
        nonlocal busy, tiebreak
        while idle and ready:
            i, s, cost = ready.popleft()
            core = idle.popleft()
            dur = cost / speeds[core // cores_per_node] + io_per_bucket
            busy += dur
            tiebreak += 1
            heapq.heappush(running, (t + dispatch_latency + dur, tiebreak, i, s, core))

    dispatch()
    while running:
        t, _, i, s, core = heapq.heappop(running)
        idle.append(core)
        remaining[i, s] -= 1
        if remaining[i, s] == 0 and s + 1 < n_stages:
            if barrier:
                stage_open[s] -= 1
                if stage_open[s] == 0:  # last input closes the global barrier
                    for j in range(n_inputs):
                        enqueue(j, s + 1)
            else:
                enqueue(i, s + 1)  # per-input dependency edge
        dispatch()

    return StreamSim(
        makespan=t,
        busy_time=busy,
        n_inputs=n_inputs,
        n_nodes=n_nodes,
        cores_per_node=cores_per_node,
    )

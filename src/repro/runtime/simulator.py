"""Discrete-event simulator of the Manager-Worker cluster at paper scale
(256 nodes × 28 cores) — drives the fig8 multi-node scalability benchmark.

Cost model: per-bucket compute times come from *measured* JAX task
wall-times composed over the bucket's merged task tree (the same model the
paper's gains rest on: reuse changes WHICH tasks run, not how fast a task
is). Per-bucket dispatch latency and per-tile I/O are charged per the RTF's
demand-driven protocol; node_speed jitter injects stragglers.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ClusterSim",
    "simulate_cluster",
    "StreamSim",
    "simulate_stream",
    "AutotuneResult",
    "autotune_stream",
]


@dataclasses.dataclass
class ClusterSim:
    makespan: float
    busy_time: float
    n_nodes: int
    cores_per_node: int

    @property
    def parallel_efficiency(self) -> float:
        return self.busy_time / (self.makespan * self.n_nodes * self.cores_per_node)


def simulate_cluster(
    bucket_costs: Sequence[float],
    *,
    n_nodes: int,
    cores_per_node: int = 28,
    dispatch_latency: float = 2e-3,
    io_per_bucket: float = 0.05,
    node_speed_sigma: float = 0.03,
    seed: int = 0,
) -> ClusterSim:
    """Demand-driven list scheduling of buckets onto node-cores.

    Each core pulls the next bucket when free (the RTF protocol). Node speed
    is jittered (shared-memory/I-O contention, the paper's §IV-D explanation
    for sub-ideal multicore speedups is modelled as a per-node slowdown).
    """
    rng = np.random.default_rng(seed)
    speeds = 1.0 + rng.normal(0, node_speed_sigma, n_nodes).clip(-0.2, 0.2)
    # executor heap: (free_time, core_id); cores indexed node-major
    n_cores = n_nodes * cores_per_node
    heap = [(0.0, i) for i in range(n_cores)]
    heapq.heapify(heap)
    busy = 0.0
    makespan = 0.0
    for cost in sorted(bucket_costs, reverse=True):  # LPT demand-driven
        t, core = heapq.heappop(heap)
        node = core // cores_per_node
        dur = cost / speeds[node] + io_per_bucket
        end = t + dispatch_latency + dur
        busy += dur
        makespan = max(makespan, end)
        heapq.heappush(heap, (end, core))
    return ClusterSim(
        makespan=makespan,
        busy_time=busy,
        n_nodes=n_nodes,
        cores_per_node=cores_per_node,
    )


@dataclasses.dataclass
class StreamSim:
    """Result of :func:`simulate_stream` — the streaming dataset executor at
    paper scale (many tiles through one multi-stage plan), including the
    hierarchical-scheduler observables (DESIGN.md §15): pump occupancy,
    steal counts and locality hit-rate."""

    makespan: float
    busy_time: float
    n_inputs: int
    n_nodes: int
    cores_per_node: int
    fanout: int = 1
    # scheduling-event seconds accumulated by the BUSIEST pump — the
    # serialization metric; occupancy near 1.0 means that pump is the
    # bottleneck, exactly what the flat Manager hits at 256 nodes.
    pump_busy: float = 0.0
    steals: int = 0
    steal_items: int = 0
    locality_hits: int = 0
    locality_misses: int = 0

    @property
    def parallel_efficiency(self) -> float:
        from repro.core.metrics import parallel_efficiency

        return parallel_efficiency(
            self.busy_time, self.makespan, self.n_nodes * self.cores_per_node
        )

    @property
    def throughput(self) -> float:
        from repro.core.metrics import throughput

        return throughput(self.n_inputs, self.makespan)

    @property
    def pump_occupancy(self) -> float:
        """Busiest pump's scheduling-work fraction of the makespan."""
        return self.pump_busy / self.makespan if self.makespan else 0.0

    @property
    def worker_idle_fraction(self) -> float:
        """Mean fraction of the makespan a core spent idle."""
        return 1.0 - self.parallel_efficiency

    @property
    def locality_hit_rate(self) -> float:
        total = self.locality_hits + self.locality_misses
        return self.locality_hits / total if total else 0.0


def simulate_stream(
    stage_bucket_costs: Sequence[Sequence[float]],
    n_inputs: int,
    *,
    n_nodes: int,
    cores_per_node: int = 28,
    dispatch_latency: float = 2e-3,
    io_per_bucket: float = 0.05,
    node_speed_sigma: float = 0.03,
    input_cost_sigma: float = 0.05,
    seed: int = 0,
    barrier: bool = False,
    fanout: int = 1,
    pump_service: float = 0.0,
    steal_latency: float = 2e-3,
    steal: bool = True,
    locality: bool = False,
    locality_io_factor: float = 0.1,
) -> StreamSim:
    """Discrete-event model of ``execute_study`` at paper scale.

    ``stage_bucket_costs[s]`` is the per-bucket compute cost list of stage
    *s* of ONE input's plan (the frozen schedules' makespans); every input
    replays the same plan with a per-input cost jitter (tile content
    varies). Dependency structure mirrors the executor: with
    ``barrier=False`` (streaming), stage *s+1* buckets of input *i* become
    ready when input *i* finishes stage *s* — inputs pipeline freely across
    stages. With ``barrier=True`` (the pre-streaming global barrier), stage
    *s+1* opens only after EVERY input finished stage *s* — the idle tail
    this executor removed. Cores pull ready buckets demand-driven (RTF).

    **Hierarchy model** (DESIGN.md §15). ``fanout`` pumps each own a
    contiguous core shard; every scheduling event — a dispatch *or* a
    completion settle — occupies the owning pump for ``pump_service``
    seconds (the measured per-event cost of the Python pump: poll, lock,
    lease bookkeeping, callback). A bucket's start is therefore delayed
    behind its pump's backlog: with one pump and thousands of cores the
    pump queue, not the workers, sets the makespan — the flat-Manager
    collapse the hierarchy fixes. An idle pump whose queue ran dry steals
    the tail half of the most loaded peer's queue, paying
    ``steal_latency`` of pump time. With ``locality=True``, follow-on
    buckets are routed to the shard (and, when one is idle, the node)
    that ran the input's previous stage; a node-local hit pays
    ``io_per_bucket × locality_io_factor`` instead of the full remote
    fetch. Defaults (``fanout=1, pump_service=0, locality=False``)
    reproduce the pre-hierarchy model exactly.
    """
    stage_bucket_costs = [list(s) for s in stage_bucket_costs]
    if any(not s for s in stage_bucket_costs):
        # an empty stage would stall its dependents silently (no completion
        # event ever opens stage s+1) — reject degenerate plans loudly
        raise ValueError("every stage needs at least one bucket cost")
    rng = np.random.default_rng(seed)
    speeds = 1.0 + rng.normal(0, node_speed_sigma, n_nodes).clip(-0.2, 0.2)
    jitter = 1.0 + rng.normal(0, input_cost_sigma, n_inputs).clip(-0.5, 0.5)
    n_stages = len(stage_bucket_costs)
    n_cores = n_nodes * cores_per_node
    fanout = max(1, min(int(fanout), n_cores))

    def shard_of_core(core: int) -> int:
        return core * fanout // n_cores

    # per-shard ready queues + idle core pools (contiguous shards)
    ready: List["collections.deque"] = [collections.deque() for _ in range(fanout)]
    idle: List["collections.deque"] = [collections.deque() for _ in range(fanout)]
    for c in range(n_cores):
        idle[shard_of_core(c)].append(c)
    pump_free = [0.0] * fanout   # time each pump is next available
    pump_busy = [0.0] * fanout   # scheduling-event seconds per pump
    # input -> (node, shard) of its most recent completed bucket — the
    # affinity map locality routing consults
    aff_node = np.full(n_inputs, -1, dtype=np.int64)
    aff_shard = np.full(n_inputs, -1, dtype=np.int64)

    remaining = np.zeros((n_inputs, n_stages), dtype=np.int64)
    stage_open = np.zeros(n_stages, dtype=np.int64)  # inputs not yet done (barrier)

    def route(i: int) -> int:
        if locality and aff_shard[i] >= 0:
            return int(aff_shard[i])
        return min(range(fanout), key=lambda g: (len(ready[g]), g))

    def enqueue(i: int, s: int) -> None:
        g = route(i)
        for c in stage_bucket_costs[s]:
            ready[g].append((i, s, c * jitter[i]))
        remaining[i, s] = len(stage_bucket_costs[s])

    for s in range(n_stages):
        stage_open[s] = n_inputs
    for i in range(n_inputs):
        enqueue(i, 0)

    running: List = []  # (end_time, tiebreak, input, stage, core)
    t = 0.0
    busy = 0.0
    tiebreak = 0
    steals = steal_items = 0
    loc_hits = loc_misses = 0

    def take_core(g: int, i: int) -> int:
        """Pick an idle core from shard g — preferring the input's
        affinity node when locality dispatch is on."""
        if locality and aff_node[i] >= 0:
            target = int(aff_node[i])
            for j, c in enumerate(idle[g]):
                if c // cores_per_node == target:
                    idle[g].rotate(-j)
                    core = idle[g].popleft()
                    idle[g].rotate(j)
                    return core
        return idle[g].popleft()

    def dispatch() -> None:
        nonlocal busy, tiebreak, steals, steal_items, loc_hits, loc_misses
        for g in range(fanout):
            while idle[g]:
                if not ready[g]:
                    if not (steal and fanout > 1):
                        break
                    victim = -1
                    for h in range(fanout):
                        if h != g and len(ready[h]) > (
                            len(ready[victim]) if victim >= 0 else 1
                        ):
                            victim = h
                    if victim < 0:
                        break
                    n = len(ready[victim]) // 2
                    chunk = [ready[victim].pop() for _ in range(n)]
                    chunk.reverse()
                    ready[g].extend(chunk)
                    pump_free[g] = max(pump_free[g], t) + steal_latency
                    pump_busy[g] += steal_latency
                    steals += 1
                    steal_items += n
                i, s, cost = ready[g].popleft()
                core = take_core(g, i)
                node = core // cores_per_node
                io = io_per_bucket
                if locality and aff_node[i] >= 0:
                    if node == aff_node[i]:
                        io = io_per_bucket * locality_io_factor
                        loc_hits += 1
                    else:
                        loc_misses += 1
                dur = cost / speeds[node] + io
                start = max(t, pump_free[g])  # pump serialization point
                pump_free[g] = start + pump_service
                pump_busy[g] += pump_service
                busy += dur
                tiebreak += 1
                heapq.heappush(
                    running,
                    (start + pump_service + dispatch_latency + dur,
                     tiebreak, i, s, core),
                )

    dispatch()
    while running:
        t, _, i, s, core = heapq.heappop(running)
        g = shard_of_core(core)
        idle[g].append(core)
        # the settle is a scheduling event too: it occupies the pump
        pump_free[g] = max(pump_free[g], t) + pump_service
        pump_busy[g] += pump_service
        aff_node[i] = core // cores_per_node
        aff_shard[i] = g
        remaining[i, s] -= 1
        if remaining[i, s] == 0 and s + 1 < n_stages:
            if barrier:
                stage_open[s] -= 1
                if stage_open[s] == 0:  # last input closes the global barrier
                    for j in range(n_inputs):
                        enqueue(j, s + 1)
            else:
                enqueue(i, s + 1)  # per-input dependency edge
        dispatch()

    return StreamSim(
        makespan=t,
        busy_time=busy,
        n_inputs=n_inputs,
        n_nodes=n_nodes,
        cores_per_node=cores_per_node,
        fanout=fanout,
        pump_busy=max(pump_busy) if pump_busy else 0.0,
        steals=steals,
        steal_items=steal_items,
        locality_hits=loc_hits,
        locality_misses=loc_misses,
    )


@dataclasses.dataclass
class AutotuneResult:
    """Outcome of :func:`autotune_stream`: the (bucket size, fanout) pair
    with the smallest simulated makespan, plus the full search table."""

    bucket_size: int
    fanout: int
    sim: StreamSim
    # (bucket_size, fanout, makespan, parallel_efficiency) per candidate
    table: List[Tuple[int, int, float, float]]


def autotune_stream(
    costs_by_bucket_size: Dict[int, Sequence[Sequence[float]]],
    n_inputs: int,
    *,
    n_nodes: int,
    fanouts: Sequence[int] = (1, 2, 4, 8, 16),
    **sim_kwargs,
) -> AutotuneResult:
    """Autotune bucket size × pump fan-out on the validated stream model.

    ``costs_by_bucket_size`` maps a candidate ``max_bucket_size`` to the
    re-planned ``stage_bucket_costs`` it produces (the caller re-plans;
    bucket size changes WHICH schedules exist, so it cannot be derived
    here). Every (bucket size, fanout) pair is simulated and the smallest
    makespan wins — the trade this searches is real: small buckets load-
    balance better but multiply scheduling events (pump-bound at high core
    counts), large buckets starve the pump less but serialise more work
    per bucket. Fan-out is clamped to the core count by the simulator."""
    if not costs_by_bucket_size:
        raise ValueError("need at least one bucket-size candidate")
    best: Optional[Tuple[int, int, StreamSim]] = None
    table: List[Tuple[int, int, float, float]] = []
    for bucket_size in sorted(costs_by_bucket_size):
        costs = costs_by_bucket_size[bucket_size]
        for f in fanouts:
            sim = simulate_stream(
                costs, n_inputs, n_nodes=n_nodes, fanout=f, **sim_kwargs
            )
            table.append(
                (bucket_size, f, sim.makespan, sim.parallel_efficiency)
            )
            if best is None or sim.makespan < best[2].makespan:
                best = (bucket_size, f, sim)
    assert best is not None
    return AutotuneResult(
        bucket_size=best[0], fanout=best[1], sim=best[2], table=table
    )

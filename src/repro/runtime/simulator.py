"""Discrete-event simulator of the Manager-Worker cluster at paper scale
(256 nodes × 28 cores) — drives the fig8 multi-node scalability benchmark.

Cost model: per-bucket compute times come from *measured* JAX task
wall-times composed over the bucket's merged task tree (the same model the
paper's gains rest on: reuse changes WHICH tasks run, not how fast a task
is). Per-bucket dispatch latency and per-tile I/O are charged per the RTF's
demand-driven protocol; node_speed jitter injects stragglers.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["ClusterSim", "simulate_cluster"]


@dataclasses.dataclass
class ClusterSim:
    makespan: float
    busy_time: float
    n_nodes: int
    cores_per_node: int

    @property
    def parallel_efficiency(self) -> float:
        return self.busy_time / (self.makespan * self.n_nodes * self.cores_per_node)


def simulate_cluster(
    bucket_costs: Sequence[float],
    *,
    n_nodes: int,
    cores_per_node: int = 28,
    dispatch_latency: float = 2e-3,
    io_per_bucket: float = 0.05,
    node_speed_sigma: float = 0.03,
    seed: int = 0,
) -> ClusterSim:
    """Demand-driven list scheduling of buckets onto node-cores.

    Each core pulls the next bucket when free (the RTF protocol). Node speed
    is jittered (shared-memory/I-O contention, the paper's §IV-D explanation
    for sub-ideal multicore speedups is modelled as a per-node slowdown).
    """
    rng = np.random.default_rng(seed)
    speeds = 1.0 + rng.normal(0, node_speed_sigma, n_nodes).clip(-0.2, 0.2)
    # executor heap: (free_time, core_id); cores indexed node-major
    n_cores = n_nodes * cores_per_node
    heap = [(0.0, i) for i in range(n_cores)]
    heapq.heapify(heap)
    busy = 0.0
    makespan = 0.0
    for cost in sorted(bucket_costs, reverse=True):  # LPT demand-driven
        t, core = heapq.heappop(heap)
        node = core // cores_per_node
        dur = cost / speeds[node] + io_per_bucket
        end = t + dispatch_latency + dur
        busy += dur
        makespan = max(makespan, end)
        heapq.heappush(heap, (end, core))
    return ClusterSim(
        makespan=makespan,
        busy_time=busy,
        n_nodes=n_nodes,
        cores_per_node=cores_per_node,
    )

"""S3-style object store + the store tier that rides on it (DESIGN.md §16).

Multi-host fleets cannot assume a shared filesystem: the SharedStore's
coordination primitives (``fcntl.flock`` per-key locks, an appendable
``manifest.jsonl``) only work when every writer mounts one directory. What
every real deployment *does* have is an object store — S3, GCS, MinIO — a
flat keyspace of immutable blobs with ``get/put/list/head`` and
*conditional* writes. This module defines that contract and plugs it in
BEHIND the existing footer-verified entry protocol, so the paper's storage
semantics survive the hop off-host unchanged:

* :class:`ObjectStore` — the minimal API (``get``/``put``/``list``/
  ``head`` plus ETag-conditional ``put_if_absent``). Two implementations
  ship: :class:`LocalFSObjectStore`, a reference implementation rooted at a
  directory whose conditional create is an atomic ``os.link`` (so N
  *processes* — or N hosts over a mounted share — get real
  create-if-absent semantics), and :class:`InMemoryObjectStore`, the
  in-process fake the tests drive (with corruption/fault hooks no real
  backend would expose).
* :class:`ObjectBackedStore` — a :class:`~repro.runtime.storage.
  HierarchicalStore` whose *disk tier* is an object store. Entries keep
  the exact ``_pack_entry`` layout (npz payload + magic/length/sha256
  footer) as object bodies under content-addressed keys
  (``entries/<sha256(key)>``), so corruption detection, quarantine-on-
  corrupt self-healing and bit-exact hydration are byte-for-byte the
  protocol of DESIGN.md §12 — only the medium changed. Cross-host write
  dedup needs no lock at all: values are pure functions of the key, so
  ``put_if_absent`` IS the coordination — the first committed object wins
  and every later writer elides its double-write (the ``dedup_writes``
  counter, same meaning as the flock path's).

Spec strings make the tier reachable from every surface that accepts a
``store_dir``: ``"obj:<root>"`` mounts an :class:`ObjectBackedStore` over
a :class:`LocalFSObjectStore` at ``<root>`` (see
:func:`repro.runtime.storage.mount_store`); a plain path keeps mounting
the flock-coordinated :class:`~repro.runtime.storage.SharedStore`. The
string crosses spawn and TCP boundaries verbatim, which is how RPC and
socket workers mount the same tier the leader did.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import pathlib
import pickle
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.storage import (
    HierarchicalStore,
    _footer_ok,
    _pack_entry,
    _serialise,
    stable_key,
)

__all__ = [
    "ObjectMeta",
    "ObjectStore",
    "LocalFSObjectStore",
    "InMemoryObjectStore",
    "ObjectBackedStore",
]


def _etag(data: bytes) -> str:
    """Content ETag — sha256 hex, the strong validator S3 calls an entity
    tag. Conditional writes compare these, never mtimes."""
    return hashlib.sha256(data).hexdigest()


def _check_key(key: str) -> str:
    if not key or key.startswith("/") or ".." in key.split("/"):
        raise ValueError(f"illegal object key {key!r}")
    return key


@dataclasses.dataclass(frozen=True)
class ObjectMeta:
    """``head`` result: existence proof + size + the content ETag."""

    size: int
    etag: str


class ObjectStore:
    """The S3-shaped contract every backing implementation satisfies.

    Keys are ``/``-separated paths in a flat namespace (no directories —
    ``list`` is a prefix scan). Objects are immutable blobs: ``put``
    replaces whole objects atomically, ``put_if_absent`` creates-if-absent
    atomically and reports the survivor's ETag — the primitive that
    replaces per-key file locks for cross-host dedup.
    """

    def get(self, key: str) -> Optional[bytes]:
        """The object's bytes, or None when absent."""
        raise NotImplementedError

    def put(self, key: str, data: bytes) -> str:
        """Store ``data`` under ``key`` (unconditional replace); returns
        the new object's ETag."""
        raise NotImplementedError

    def put_if_absent(self, key: str, data: bytes) -> Tuple[bool, str]:
        """Atomic create-if-absent. Returns ``(created, etag)`` where
        ``etag`` names the object that now exists — ours when we won the
        race, the incumbent's when we lost. Losing is not an error: for
        content-addressed pure values it means a peer already committed
        the identical entry."""
        raise NotImplementedError

    def head(self, key: str) -> Optional[ObjectMeta]:
        """Size + ETag without the body, or None when absent."""
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        """Every key under ``prefix``, sorted (deterministic across
        implementations so replays/audits are stable)."""
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        """Remove ``key``; False when it was already absent."""
        raise NotImplementedError


class LocalFSObjectStore(ObjectStore):
    """Reference implementation over a directory tree.

    Every object lands crash-safely (tmp sibling + fsync + atomic
    publish), and ``put_if_absent`` is an ``os.link`` of the fsynced tmp
    file onto the final name — link(2) fails with EEXIST atomically even
    across processes and network mounts, giving true conditional-create
    without any lock file. ETags are content sha256; ``head`` reads the
    body to compute one (a reference implementation trades that cost for
    zero metadata bookkeeping — a real backend serves ETags from its
    index).
    """

    def __init__(self, root: str) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> pathlib.Path:
        return self.root / _check_key(key)

    def _write_tmp(self, path: pathlib.Path, data: bytes) -> pathlib.Path:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=f".{path.name}.", dir=path.parent)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return pathlib.Path(tmp)

    def get(self, key: str) -> Optional[bytes]:
        try:
            return self._path(key).read_bytes()
        except OSError:
            return None

    def put(self, key: str, data: bytes) -> str:
        path = self._path(key)
        tmp = self._write_tmp(path, data)
        os.replace(tmp, path)
        return _etag(data)

    def put_if_absent(self, key: str, data: bytes) -> Tuple[bool, str]:
        path = self._path(key)
        tmp = self._write_tmp(path, data)
        try:
            os.link(tmp, path)  # atomic create-if-absent, even cross-host
        except FileExistsError:
            existing = self.get(key)
            if existing is not None:
                return False, _etag(existing)
            # raced a delete between link and get: retry as the creator
            os.replace(tmp, path)
            return True, _etag(data)
        finally:
            try:
                os.unlink(tmp)
            except OSError:
                pass
        return True, _etag(data)

    def head(self, key: str) -> Optional[ObjectMeta]:
        data = self.get(key)
        if data is None:
            return None
        return ObjectMeta(size=len(data), etag=_etag(data))

    def list(self, prefix: str = "") -> List[str]:
        out: List[str] = []
        for dirpath, _dirs, files in os.walk(self.root):
            rel = pathlib.Path(dirpath).relative_to(self.root)
            for name in files:
                if name.startswith("."):
                    continue  # in-flight tmp siblings are not objects
                key = name if rel == pathlib.Path(".") else f"{rel.as_posix()}/{name}"
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> bool:
        try:
            self._path(key).unlink()
            return True
        except OSError:
            return False


class InMemoryObjectStore(ObjectStore):
    """In-process fake for tests: a dict behind a lock, plus the fault
    hooks a real backend would never expose — ``corrupt(key)`` flips bytes
    in place (models bit-rot the footer check must catch) and
    ``fail_puts_once`` injects one transient put failure."""

    def __init__(self) -> None:
        self._objects: Dict[str, bytes] = {}  # guard: _lock
        self._lock = threading.Lock()
        self.puts = 0  # guard: _lock
        self.gets = 0  # guard: _lock
        self.conditional_losses = 0  # guard: _lock
        self.fail_puts_once = False

    def _maybe_fail(self) -> None:  # holds: _lock
        if self.fail_puts_once:
            self.fail_puts_once = False
            raise OSError("injected object-store put failure")

    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            self.gets += 1
            return self._objects.get(_check_key(key))

    def put(self, key: str, data: bytes) -> str:
        with self._lock:
            self._maybe_fail()
            self._objects[_check_key(key)] = bytes(data)
            self.puts += 1
            return _etag(data)

    def put_if_absent(self, key: str, data: bytes) -> Tuple[bool, str]:
        with self._lock:
            self._maybe_fail()
            key = _check_key(key)
            existing = self._objects.get(key)
            if existing is not None:
                self.conditional_losses += 1
                return False, _etag(existing)
            self._objects[key] = bytes(data)
            self.puts += 1
            return True, _etag(data)

    def head(self, key: str) -> Optional[ObjectMeta]:
        with self._lock:
            data = self._objects.get(_check_key(key))
        if data is None:
            return None
        return ObjectMeta(size=len(data), etag=_etag(data))

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key: str) -> bool:
        with self._lock:
            return self._objects.pop(_check_key(key), None) is not None

    def corrupt(self, key: str) -> None:
        """Flip the first byte of ``key``'s body (test hook)."""
        with self._lock:
            data = bytearray(self._objects[_check_key(key)])
            data[0] ^= 0xFF
            self._objects[key] = bytes(data)


# ---------------------------------------------------------------------------
# ObjectBackedStore: the §12 entry protocol over an ObjectStore
# ---------------------------------------------------------------------------

_ENTRY_PREFIX = "entries/"
_KEY_PREFIX = "keys/"
_QUAR_PREFIX = "quarantine/"


class ObjectBackedStore(HierarchicalStore):
    """A :class:`~repro.runtime.storage.HierarchicalStore` whose disk tier
    is an :class:`ObjectStore` — the no-shared-filesystem SharedStore.

    Object layout (all content-addressed by ``stable_key``):

    * ``entries/<sha>`` — the footer-verified entry bytes, byte-identical
      to what the filesystem tier writes to ``<sha>.npz``;
    * ``keys/<sha>`` — the human-readable key (the sidecar AND the commit
      record: ``committed_keys()`` folds this prefix, playing the
      manifest's audit role without an appendable file);
    * ``quarantine/<sha>.<ns>`` — entries that failed footer verification,
      moved aside as evidence exactly like the directory tier's
      ``quarantine/`` (the key then reads as a miss and the next write
      self-heals it).

    Writer coordination is ``put_if_absent`` instead of ``flock``: the
    first committed object is THE entry (values are pure functions of the
    key), every losing writer counts a ``dedup_writes`` and moves on. The
    crash window matches §12's: a writer killed mid-``put`` publishes
    nothing (object puts are atomic), a writer killed between the entry
    put and the key-record put leaves a servable entry that simply isn't
    listed in ``committed_keys()`` until a peer re-commits it — entries
    stay the ground truth, the key index stays advisory, exactly the
    manifest's contract.
    """

    def __init__(
        self,
        ram_bytes: int = 1 << 30,
        objstore: Optional[ObjectStore] = None,
        *,
        spec: Optional[str] = None,
        writer_id: Optional[str] = None,
    ):
        # the base class's disk directory is never written — every
        # disk-tier hook below routes to the object store instead — but
        # ``_path()`` still names entries ``<sha>.npz``, which keys them
        super().__init__(ram_bytes, disk_dir=None)
        self.objstore = objstore if objstore is not None else InMemoryObjectStore()
        self._spec = spec
        self.writer_id = writer_id or f"pid{os.getpid()}"
        self.dedup_writes = 0  # guard: _counters_lock
        self._persisted: set = set()  # guard: _counters_lock
        self._counters_lock = threading.Lock()

    @property
    def disk_dir(self) -> str:
        """The mount SPEC (``obj:<root>``) rather than a directory: what
        ``StudyState.save`` records and fleet/RPC workers remount."""
        if self._spec is not None:
            return self._spec
        root = getattr(self.objstore, "root", None)
        if root is not None:
            return f"obj:{root}"
        return f"obj+mem:{id(self.objstore):x}"

    # -- write side: the conditional create replaces the flock -----------
    def _write_disk(self, key: str, v: Any) -> None:
        sha = stable_key(key)
        with self._counters_lock:
            if sha in self._persisted:
                return  # this instance already committed it
        blob = _pack_entry(_serialise(v))
        created, _ = self.objstore.put_if_absent(_ENTRY_PREFIX + sha, blob)
        if not created:
            with self._counters_lock:
                self.dedup_writes += 1
        # commit record (advisory, like the manifest): conditional and
        # idempotent, and written by dedup LOSERS too — that re-commit is
        # what heals the crash window of a writer killed between the entry
        # put and the key-record put
        self.objstore.put_if_absent(_KEY_PREFIX + sha, key.encode())
        with self._counters_lock:
            self._persisted.add(sha)

    # -- read side: same footer verification, object quarantine ----------
    def _load_disk_unlocked(self, path: pathlib.Path) -> Tuple[str, Any]:
        sha = path.stem  # HierarchicalStore._path names entries <sha>.npz
        data = self.objstore.get(_ENTRY_PREFIX + sha)
        if data is None:
            return "missing", None
        payload = _footer_ok(data)
        if payload is None:
            self._quarantine_object(sha, data)
            return "corrupt", None
        try:
            with np.load(io.BytesIO(payload)) as z:
                if "__pickled__" in z:
                    return "ok", pickle.loads(z["__pickled__"].tobytes())
                if "__value__" in z:
                    return "ok", z["__value__"]
                return "ok", {k: z[k] for k in z.files}
        except Exception:  # noqa: BLE001 — parse failure is corruption
            self._quarantine_object(sha, data)
            return "corrupt", None

    def _quarantine_object(self, sha: str, data: bytes) -> None:
        """Move the bad object aside (never discard evidence) and delete
        the entry so the key reads as a miss until a writer self-heals it.
        The quarantining instance forgets its own commit so IT can be that
        writer."""
        try:
            self.objstore.put(f"{_QUAR_PREFIX}{sha}.{time.time_ns()}", data)
            self.objstore.delete(_ENTRY_PREFIX + sha)
            self.objstore.delete(_KEY_PREFIX + sha)
        except OSError:  # pragma: no cover - quarantine is best-effort
            pass
        with self._counters_lock:
            self._persisted.discard(sha)

    def _disk_entry_ok(self, path: pathlib.Path) -> bool:
        # optimistic presence probe (a byte-exact check would turn every
        # contains() into a full GET); get() verifies the footer in full
        return self.objstore.head(_ENTRY_PREFIX + path.stem) is not None

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
        sha = stable_key(key)
        self.objstore.delete(_ENTRY_PREFIX + sha)
        self.objstore.delete(_KEY_PREFIX + sha)
        with self._counters_lock:
            self._persisted.discard(sha)

    # -- audit view (the manifest's role) --------------------------------
    def committed_keys(self) -> set:
        out = set()
        for obj_key in self.objstore.list(_KEY_PREFIX):
            body = self.objstore.get(obj_key)
            if body is not None:
                out.add(body.decode(errors="replace"))
        return out

    def manifest_records(self) -> Dict[str, Dict[str, Any]]:
        """Manifest-shaped view for callers that audit commit records: one
        row per committed key (the object tier keeps no per-write history,
        so ``seq``/``ts``/``writer`` are absent by design)."""
        records: Dict[str, Dict[str, Any]] = {}
        for obj_key in self.objstore.list(_KEY_PREFIX):
            body = self.objstore.get(obj_key)
            if body is None:
                continue
            key = body.decode(errors="replace")
            sha = obj_key[len(_KEY_PREFIX):]
            meta = self.objstore.head(_ENTRY_PREFIX + sha)
            records[key] = {
                "key": key,
                "sha": sha,
                "len": meta.size if meta else None,
            }
        return records

"""Transport-agnostic Worker backends — the Manager's dispatch boundary
(DESIGN.md §13–§14).

The Manager is a pure scheduler/bookkeeper: it owns the queue, the lease
table, retry/backup/heartbeat policy and result memoisation, and talks to
its Workers exclusively through the :class:`WorkerBackend` protocol::

    start(n) / offer(lease) / poll_completions(timeout) / heartbeat_view()
    / shutdown()

with :class:`Lease` / :class:`Completion` dataclasses as the only currency.
Everything the paper's multi-node deployment needs from the boundary is in
those five calls: demand signalling (``heartbeat_view`` exposes free
slots), at-least-once dispatch (``offer`` may be re-driven after an
expiry), and completion delivery decoupled from scheduling. Two conforming
implementations ship here:

* :class:`ThreadBackend` — the historical behavior: Worker threads in this
  process executing ``Lease.fn`` closures directly. The default, so every
  existing ``Manager()`` caller keeps working unchanged.
* :class:`ProcessRpcBackend` — N ``spawn`` worker *processes* running
  :func:`_rpc_worker_main`, speaking a length-prefixed pickle control plane
  over ``multiprocessing.Connection`` pipes. Control messages carry only
  keys, attempt numbers and small picklable task *specs*. Worker processes
  rebuild their execution context (workflow, inputs) from a spawn-picklable
  ``build`` callable — the same pattern the fleet runner uses — and rebuild
  each StudyPlan deterministically from the plan's ``recipe``, so no
  unpicklable closure ever needs to cross a process boundary.

The process backend's fast path (DESIGN.md §14) is four independently
flag-gated mechanisms, all on by default:

* **batched frames** (``batch_frames``) — the Manager pump hands the
  backend a *batch* of ready leases per tick (``offer_batch``), the
  backend coalesces each worker's share into one ``lease_batch`` frame,
  and workers return ``comp_batch`` frames under a ``max_batch`` /
  ``max_delay_ms`` window: one pickle round trip per batch instead of per
  task, and each worker holds a small queue (``slots_per_worker``) so it
  never idles between frames.
* **warm plans** (``warm_plans``) — workers key rebuilt StudyPlans by
  *recipe content*, not the per-call ``plan_id``, so re-installing an
  identical study (a benchmark loop, an adaptive round over the same
  space) is a plan-cache hit; the ``install_study`` broadcast prewarms the
  cache before the first lease, and hit/miss counters ride heartbeats into
  the backend's ``stats()``. (jit caches warm for free: compiled kernels
  are process-global and keyed by trace shape, not by plan.)
* **shared-memory handoff** (``shm_results``) — array-bearing results
  cross the boundary as one ``multiprocessing.shared_memory`` segment
  referenced by name+offsets+dtypes in the completion frame instead of
  pickle→npz→load through the store, with a structural fallback (object
  payloads, oversize values) to the inline/store path.
* **async commit** (``async_commit``) — workers ack completions without a
  synchronous disk persist; the leader stages each hydrated value in an
  :class:`~repro.runtime.storage.AsyncCommitQueue` whose background
  flusher drains into the store through the existing atomic
  footer-verified protocol. ``barrier()`` (invoked by ``Manager.drain``
  and ``StudyState.save``) is the durability point. Workers that need an
  upstream result another worker produced fetch it from the leader's
  staging tier over the control plane (``fetch``/``fetched`` frames).

Results therefore cross the boundary by shared-memory descriptor, inline
value, or store key — never as ambient pickled state; a crash between ack
and flush costs nothing (the lease-retry path recomputes the pure task).

The frame format is deliberately transport-portable: ``<8-byte LE length>
<pickle payload>`` — ``multiprocessing.Connection`` adds its own framing
today, but the explicit prefix means the same codec drives a raw socket
when workers move to other hosts (the ROADMAP follow-on).
"""

from __future__ import annotations

import collections
import dataclasses
import os
import pathlib
import pickle
import queue
import struct
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Lease",
    "Completion",
    "WorkerStatus",
    "WorkerBackend",
    "ThreadBackend",
    "ProcessRpcBackend",
    "RemoteTaskError",
    "TransportError",
    "make_backend",
    "process_flag_kwargs",
    "shm_encode",
    "shm_decode",
]


class TransportError(RuntimeError):
    """A structural failure of the dispatch boundary itself (torn frame,
    spec missing for a cross-process lease) — distinct from a task failing."""


class RemoteTaskError(RuntimeError):
    """A task failed on the far side of the boundary; carries the remote
    traceback text (the original exception object cannot cross the wire)."""


@dataclasses.dataclass
class Lease:
    """One attempt of one key, handed to a backend for execution.

    ``fn`` is the in-process closure (never serialised; ignored by remote
    backends); ``spec`` is the small picklable task description remote
    backends ship instead. A backend consumes whichever representation it
    supports — :class:`ThreadBackend` prefers ``fn``, falling back to the
    portable ``("call", callable, args, kwargs)`` spec form so one WorkItem
    can conform on every backend.
    """

    key: str
    attempt: int
    fn: Optional[Callable[[], Any]] = None
    spec: Optional[Tuple] = None

    @property
    def lease_id(self) -> str:
        return f"{self.key}#{self.attempt}"


@dataclasses.dataclass
class Completion:
    """Terminal report of one lease: a value (hydrated by the backend —
    possibly from the shared store) or a failure. ``exc`` carries the
    original exception object for in-process backends; remote backends can
    only ship ``error`` text, which the Manager wraps in
    :class:`RemoteTaskError`."""

    key: str
    attempt: int
    ok: bool
    value: Any = None
    exc: Optional[BaseException] = None
    error: Optional[str] = None
    store_key: Optional[str] = None
    worker_id: int = -1
    duration: float = 0.0

    @property
    def lease_id(self) -> str:
        return f"{self.key}#{self.attempt}"


@dataclasses.dataclass(frozen=True)
class WorkerStatus:
    """One worker's row in ``heartbeat_view()``: liveness, the monotonic
    timestamp of its last sign of life, and the lease ids it currently
    holds. A dead worker keeps reporting its orphaned leases so the Manager
    can re-enqueue them (idempotently — it pops each from its lease table
    exactly once)."""

    alive: bool
    last_seen: float
    inflight: Tuple[str, ...] = ()


try:  # Protocol is typing-only; keep the module importable everywhere
    from typing import Protocol, runtime_checkable

    @runtime_checkable
    class WorkerBackend(Protocol):
        """The Manager↔Worker contract. Implementations own worker
        lifecycle and execution; the Manager owns every scheduling
        decision.

        Beyond the five methods, two class flags complete the contract:
        ``supports_specs`` (True ⇒ leases are shipped by picklable spec,
        closures never cross — the executor then also requires an
        ``install_study(**study)`` method to broadcast plan recipes before
        any bucket lease references them) and
        ``heartbeats_prove_liveness`` (True ⇒ a fresh ``last_seen`` proves
        a worker's leases live mid-task, sparing them age-based expiry).

        Further methods are optional; the Manager discovers them by
        ``getattr``: ``offer_batch(leases, worker_ids=None) -> rejected``
        (batched dispatch; paired with a ``slots_per_worker`` attribute so
        the pump sizes demand as queue depth, not just free workers;
        ``worker_ids`` restricts a batch to a shard for the hierarchical
        scheduler's sub-manager pumps), ``offer_to(lease, worker_id) ->
        bool`` (locality-targeted single-worker offer, DESIGN.md §15) and
        ``barrier(timeout=None) -> bool`` (durability point for backends
        that acknowledge completions ahead of their disk commit;
        ``Manager.drain`` invokes it when present).
        """

        name: str
        supports_specs: bool
        heartbeats_prove_liveness: bool

        def start(self, n_workers: int) -> None:
            """Bring up the worker pool (idempotent per session; a backend
            may be restarted after ``shutdown``)."""

        def offer(self, lease: Lease) -> bool:
            """Hand a lease to a free worker. Returns False when no worker
            can take it right now (the Manager re-queues the item)."""

        def poll_completions(self, timeout: float) -> List["Completion"]:
            """Block up to ``timeout`` seconds for completions; drain and
            return everything available (possibly empty)."""

        def heartbeat_view(self) -> Dict[int, WorkerStatus]:
            """Per-worker liveness + inflight leases; the basis of the
            Manager's demand, straggler and dead-worker decisions."""

        def shutdown(self) -> None:
            """Retire the pool; outstanding leases may be abandoned."""

except ImportError:  # pragma: no cover - pre-3.8 fallback
    WorkerBackend = object  # type: ignore[misc,assignment]


def run_call_spec(spec: Tuple) -> Any:
    """Execute the portable ``("call", fn, args, kwargs)`` spec form — the
    backend-independent task representation the conformance suite drives
    both backends with."""
    kind = spec[0]
    if kind != "call":
        raise TransportError(f"unsupported lease spec {kind!r} for direct call")
    _, fn, args, kwargs = spec
    return fn(*args, **(kwargs or {}))


def make_backend(spec: Any) -> "WorkerBackend":
    """Resolve a backend spec: ``None``/``"thread"`` → a fresh
    :class:`ThreadBackend`; a :class:`WorkerBackend` instance passes
    through; a zero-arg callable is invoked (factory form). ``"process"``
    (with or without a ``[...]`` flag suffix — see
    :func:`process_flag_kwargs`) cannot be built here — a
    :class:`ProcessRpcBackend` needs a ``build`` for its workers, so the
    caller must construct it."""
    if spec is None or spec == "thread":
        return ThreadBackend()
    if isinstance(spec, str) and spec.startswith("socket"):
        # unlike "process", a socket backend IS constructible by name: the
        # leader only listens — workers bring their own build context when
        # they dial in (or the spec's spawn mode launches loopback workers)
        from repro.runtime.net import SocketBackend, socket_flag_kwargs

        return SocketBackend(**socket_flag_kwargs(spec))
    if isinstance(spec, str):
        raise ValueError(
            f"backend spec {spec!r} is not constructible from a name alone; "
            "pass a ProcessRpcBackend(build=...) instance for process workers"
        )
    if callable(spec) and not hasattr(spec, "offer"):
        return spec()
    return spec


_PROCESS_FLAG_NAMES = {
    "batch": "batch_frames",
    "warm": "warm_plans",
    "shm": "shm_results",
    "async": "async_commit",
}
_PROCESS_TUNABLES = {
    "max_batch": int,
    "max_delay_ms": float,
    "shm_max_bytes": int,
}


def process_flag_kwargs(spec: str) -> Dict[str, Any]:
    """Parse a ``"process[...]"`` backend spec's flag suffix into
    :class:`ProcessRpcBackend` keyword arguments (DESIGN.md §14).

    Grammar: comma-separated tokens inside the brackets, applied left to
    right over the constructor defaults (every mechanism ON). ``batch`` /
    ``warm`` / ``shm`` / ``async`` enable one mechanism, a ``-`` prefix
    disables it, ``all`` / ``none`` set all four at once, and
    ``key=value`` sets a tunable (``max_batch``, ``max_delay_ms``,
    ``shm_max_bytes``). Examples::

        "process"                   -> {}                  (all defaults)
        "process[-async]"           -> async_commit=False
        "process[none,batch]"       -> only batched frames on
        "process[none]"             -> the pre-optimization wire behavior
        "process[max_batch=4]"      -> tuned batching window
    """
    spec = spec.strip()
    if not spec.startswith("process"):
        raise ValueError(f"not a process backend spec: {spec!r}")
    rest = spec[len("process"):]
    if not rest:
        return {}
    if not (rest.startswith("[") and rest.endswith("]")):
        raise ValueError(f"malformed process backend spec: {spec!r}")
    kwargs: Dict[str, Any] = {}
    for token in rest[1:-1].split(","):
        token = token.strip()
        if not token:
            continue
        if "=" in token:
            k, v = (s.strip() for s in token.split("=", 1))
            if k not in _PROCESS_TUNABLES:
                raise ValueError(f"unknown process backend tunable {k!r}")
            kwargs[k] = _PROCESS_TUNABLES[k](v)
            continue
        enable = not token.startswith("-")
        name = token.lstrip("+-")
        if name == "all" or name == "none":
            on = (name == "all") == enable
            for attr in _PROCESS_FLAG_NAMES.values():
                kwargs[attr] = on
        elif name in _PROCESS_FLAG_NAMES:
            kwargs[_PROCESS_FLAG_NAMES[name]] = enable
        else:
            raise ValueError(f"unknown process backend flag {name!r}")
    return kwargs


# ---------------------------------------------------------------------------
# ThreadBackend — the historical in-process Worker pool, behind the API
# ---------------------------------------------------------------------------

_STOP = object()


class ThreadBackend:
    """Worker threads in this process. Leases execute their ``fn`` closure
    (or the portable ``("call", ...)`` spec when no closure is attached);
    values stay on the heap — nothing is serialised. One slot per worker:
    the Manager sees demand as workers with an empty inflight tuple."""

    name = "thread"
    supports_specs = False
    # a thread cannot sign life while inside a task fn, so its heartbeats
    # prove nothing mid-task — the Manager keeps age-based expiry
    heartbeats_prove_liveness = False

    def __init__(self) -> None:
        self._threads: List[threading.Thread] = []
        self._inboxes: List["queue.Queue"] = []
        self._inflight: List[set] = []  # guard: _lock
        self._completions: "queue.Queue[Completion]" = queue.Queue()
        self._lock = threading.Lock()

    def start(self, n_workers: int) -> None:
        if self._threads:
            raise RuntimeError("ThreadBackend already started")
        n = max(1, n_workers)
        self._completions = queue.Queue()
        self._inboxes = [queue.Queue() for _ in range(n)]
        self._inflight = [set() for _ in range(n)]  # analysis: ok[locks] init phase, workers start below
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def offer(self, lease: Lease) -> bool:
        with self._lock:
            for wid, t in enumerate(self._threads):
                if t.is_alive() and not self._inflight[wid]:
                    self._inflight[wid].add(lease.lease_id)
                    break
            else:
                return False
        self._inboxes[wid].put(lease)
        return True

    def offer_to(self, lease: Lease, worker_id: int) -> bool:
        """Targeted offer (hierarchical scheduling, DESIGN.md §15): hand
        the lease to ONE specific worker — the one the affinity map says
        already holds the longest reuse-tree prefix. False if that worker
        is dead or busy; the caller keeps the item queued."""
        with self._lock:
            if not (0 <= worker_id < len(self._threads)):
                return False
            t = self._threads[worker_id]
            if not t.is_alive() or self._inflight[worker_id]:
                return False
            self._inflight[worker_id].add(lease.lease_id)
        self._inboxes[worker_id].put(lease)
        return True

    def poll_completions(self, timeout: float) -> List[Completion]:
        out: List[Completion] = []
        try:
            out.append(self._completions.get(timeout=max(0.0, timeout)))
        except queue.Empty:
            return out
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def heartbeat_view(self) -> Dict[int, WorkerStatus]:
        now = time.monotonic()
        with self._lock:
            return {
                wid: WorkerStatus(
                    alive=t.is_alive(),
                    last_seen=now,
                    inflight=tuple(self._inflight[wid]),
                )
                for wid, t in enumerate(self._threads)
            }

    def shutdown(self) -> None:
        for inbox in self._inboxes:
            inbox.put(_STOP)
        for t in self._threads:
            t.join()
        self._threads = []
        self._inboxes = []
        self._inflight = []  # analysis: ok[locks] teardown, workers joined above

    def _worker(self, wid: int) -> None:
        inbox = self._inboxes[wid]
        while True:
            lease = inbox.get()
            if lease is _STOP:
                return
            t0 = time.monotonic()
            try:
                if lease.fn is not None:
                    value = lease.fn()
                else:
                    value = run_call_spec(lease.spec)
            except Exception as e:  # noqa: BLE001 — the Manager owns retry
                comp = Completion(
                    key=lease.key, attempt=lease.attempt, ok=False, exc=e,
                    error=repr(e), worker_id=wid,
                    duration=time.monotonic() - t0,
                )
            else:
                comp = Completion(
                    key=lease.key, attempt=lease.attempt, ok=True, value=value,
                    worker_id=wid, duration=time.monotonic() - t0,
                )
            with self._lock:
                self._inflight[wid].discard(lease.lease_id)
            self._completions.put(comp)


# ---------------------------------------------------------------------------
# Wire codec: length-prefixed pickle frames
# ---------------------------------------------------------------------------

_FRAME_HEADER = struct.Struct("<Q")


def _send_frame(conn, lock: threading.Lock, obj: Any) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    frame = _FRAME_HEADER.pack(len(payload)) + payload
    with lock:
        # analysis: ok[blocking] this IS the frame-send serialization lock:
        # its whole job is to hold across the write so concurrent senders
        # cannot interleave torn frames on one connection; it guards no
        # other state and is never nested inside another lock
        conn.send_bytes(frame)


def _recv_frame(conn) -> Any:
    frame = conn.recv_bytes()
    if len(frame) < _FRAME_HEADER.size:
        raise TransportError("short frame")
    (length,) = _FRAME_HEADER.unpack(frame[: _FRAME_HEADER.size])
    if length != len(frame) - _FRAME_HEADER.size:
        raise TransportError(
            f"torn frame: header says {length}, got {len(frame) - _FRAME_HEADER.size}"
        )
    return pickle.loads(frame[_FRAME_HEADER.size:])


def _result_store_key(session: str, work_key: str, plan_id: Optional[str] = None) -> str:
    """Store key a worker commits a lease's result under. Keyed by the WORK
    key, not the lease id: racing attempts of one key compute the same pure
    value, so the SharedStore's per-key lock elides the double-write — but
    scoped by the backend **session nonce** (and, for bucket leases, the
    plan id) so a restarted backend or a second plan sharing one session
    can never be served a previous lifetime's entry as if it were its own.
    (Cross-round/cross-worker reuse does not live here: it flows through
    the workers' task-level ResultCache keys, which are deliberately
    session-independent.)"""
    if plan_id is not None:
        return f"rpc:{session}:{plan_id}:{work_key}"
    return f"rpc:{session}:{work_key}"


# ---------------------------------------------------------------------------
# Shared-memory result codec (the `shm_results` handoff path)
# ---------------------------------------------------------------------------

_SHM_ALIGN = 64  # cache-line align each array so reads never split lines


class _NotShmEncodable(Exception):
    """Internal: the value contains something only pickle can carry."""


def _shm_attach(name: str):
    """Attach to an existing segment WITHOUT registering it with the
    resource_tracker. The tracker's ledger must balance exactly one
    register (the creator's, implicit in ``SharedMemory(create=True)`` —
    the crash backstop: if every process dies, the tracker unlinks the
    leftovers) against exactly one unregister (implicit in whichever
    process calls ``unlink()``). A plain attach ALSO registers on
    Python < 3.13, which would double-count and make the tracker log
    KeyErrors at exit — so register is swapped for a no-op across the
    attach call. Safe here because every attach in this module happens on
    a single thread per process (the worker main loop / the leader pump)."""
    from multiprocessing import resource_tracker, shared_memory

    orig = resource_tracker.register
    resource_tracker.register = lambda *a, **k: None  # type: ignore[assignment]
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        resource_tracker.register = orig  # type: ignore[assignment]


def _shm_template(value: Any, arrays: List[np.ndarray]) -> Tuple:
    """Flatten ``value`` into a picklable template tree + a flat list of
    contiguous arrays (appended to ``arrays``). Raises
    :class:`_NotShmEncodable` for anything outside the structural subset:
    None/bool/int/float/str/bytes scalars, list/tuple/dict containers
    (primitive keys), and array-likes with non-object, round-trippable
    dtypes."""
    if isinstance(value, np.ndarray):
        a = value
    elif value is None or isinstance(value, (bool, int, float, complex, str, bytes)):
        # note: np.float64 IS a float subclass — it rides the template
        # verbatim (pickled exactly), which round-trips bit-identically
        return ("s", value)
    elif hasattr(value, "__array__"):
        a = np.asarray(value)  # jax arrays, np scalars — matches the npz path
    elif isinstance(value, dict):
        items = []
        for k, v in value.items():
            if not (k is None or isinstance(k, (bool, int, float, str, bytes, tuple))):
                raise _NotShmEncodable
            items.append((k, _shm_template(v, arrays)))
        return ("d", items)
    elif isinstance(value, tuple):
        return ("t", [_shm_template(v, arrays) for v in value])
    elif isinstance(value, list):
        return ("l", [_shm_template(v, arrays) for v in value])
    else:
        raise _NotShmEncodable
    if a.dtype.hasobject or np.dtype(a.dtype.str) != a.dtype:
        raise _NotShmEncodable  # object/structured dtypes: pickle's job
    c = np.ascontiguousarray(a)
    if c.shape != a.shape:
        c = c.reshape(a.shape)  # ascontiguousarray promotes 0-d to (1,)
    arrays.append(c)
    return ("a", len(arrays) - 1)


def _shm_rebuild(node: Tuple, arrays: List[np.ndarray]) -> Any:
    tag = node[0]
    if tag == "s":
        return node[1]
    if tag == "a":
        return arrays[node[1]]
    if tag == "d":
        return {k: _shm_rebuild(v, arrays) for k, v in node[1]}
    if tag == "t":
        return tuple(_shm_rebuild(v, arrays) for v in node[1])
    if tag == "l":
        return [_shm_rebuild(v, arrays) for v in node[1]]
    raise TransportError(f"corrupt shm template tag {tag!r}")


def shm_encode(value: Any, name: str, *, max_bytes: int) -> Optional[Dict[str, Any]]:
    """Copy ``value``'s arrays into ONE shared-memory segment ``name`` and
    return the wire descriptor (template tree + per-array offset/shape/
    dtype), or None when the value is not shm-eligible (no arrays, object
    payloads, total bytes over ``max_bytes``, or segment creation failed) —
    the caller falls back to the inline/store path. Ownership passes to the
    receiver: ``shm_decode`` unlinks after copying, the backend's shutdown
    sweep catches segments nobody decoded, and the creator's
    resource_tracker registration is the crash backstop (see
    :func:`_shm_attach` for the ledger discipline)."""
    arrays: List[np.ndarray] = []
    try:
        tree = _shm_template(value, arrays)
    except _NotShmEncodable:
        return None
    if not arrays:
        return None  # pure scalars/containers: the frame itself is cheaper
    offsets: List[int] = []
    total = 0
    for a in arrays:
        total = (total + _SHM_ALIGN - 1) & ~(_SHM_ALIGN - 1)
        offsets.append(total)
        total += a.nbytes
    if total == 0 or total > max_bytes:
        return None
    try:
        from multiprocessing import shared_memory

        seg = shared_memory.SharedMemory(name=name, create=True, size=total)
    except Exception:  # noqa: BLE001 — ENOSPC/EEXIST etc: fall back
        return None
    try:
        for a, off in zip(arrays, offsets):
            if a.nbytes == 0:
                continue
            dest = np.frombuffer(seg.buf, dtype=a.dtype, count=a.size, offset=off)
            dest[:] = a.reshape(-1)
            del dest
        return {
            "shm": name,
            "size": total,
            "tree": tree,
            "arrays": [
                (off, tuple(a.shape), a.dtype.str)
                for a, off in zip(arrays, offsets)
            ],
        }
    except Exception:  # noqa: BLE001 — never let the codec kill a worker
        try:
            seg.unlink()
        except Exception:  # noqa: BLE001
            pass
        return None
    finally:
        try:
            seg.close()
        except Exception:  # noqa: BLE001
            pass


def shm_decode(desc: Dict[str, Any], *, unlink: bool = True) -> Any:
    """Rebuild the value from a :func:`shm_encode` descriptor: attach the
    segment, copy every array out (the result owns its memory), and unlink
    the segment (default — the handoff is one-shot). Raises if the segment
    is gone, which the backend turns into a lease failure → retry."""
    seg = _shm_attach(desc["shm"])
    try:
        arrays: List[np.ndarray] = []
        for off, shape, dtype in desc["arrays"]:
            dt = np.dtype(dtype)
            count = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if count == 0:
                arrays.append(np.empty(shape, dtype=dt))
                continue
            view = np.frombuffer(seg.buf, dtype=dt, count=count, offset=off)
            arrays.append(view.reshape(shape).copy())
            del view
        return _shm_rebuild(desc["tree"], arrays)
    finally:
        try:
            seg.close()
        except Exception:  # noqa: BLE001
            pass
        if unlink:
            try:
                seg.unlink()
            except Exception:  # noqa: BLE001 — already gone is fine
                pass


def _shm_unlink_by_name(name: str) -> None:
    """Best-effort unlink of a segment nobody will ever decode."""
    try:
        seg = _shm_attach(name)
    except Exception:  # noqa: BLE001 — already gone
        return
    try:
        seg.close()
        seg.unlink()
    except Exception:  # noqa: BLE001
        pass


# ---------------------------------------------------------------------------
# The worker process main loop
# ---------------------------------------------------------------------------

_PLAN_META_MAX = 16  # plan_id → study metadata rows kept per worker
_PLAN_CACHE_MAX = 8  # built plans kept per worker (recipe-content keyed)
_FETCH_TIMEOUT = 30.0  # upstream fetch-from-leader wait before failing


def _recipe_key(recipe: Dict[str, Any]) -> str:
    """Content key of a plan recipe. Recipes are pure primitives (tuples of
    ``(name, value)`` ParamSets, numbers, strings — planner contract), so
    ``repr`` is deterministic across processes and sessions; two installs
    of structurally identical studies share one built plan."""
    return repr(sorted((k, repr(v)) for k, v in recipe.items()))


class _RpcWorker:
    """One spawn worker's whole life: build the execution context, mount
    the SharedStore, then serve lease/lease_batch frames until told to
    stop. A failing ``build`` is parked and surfaced as a failure on every
    lease (the fleet-runner pattern: a raising child would just die
    silently). A daemon heartbeat thread keeps signing life — and shipping
    the worker's counters — even while a task runs, so the leader can tell
    "busy on a long bucket" from "dead"."""

    def __init__(
        self,
        conn,
        worker_id: int,
        session: str,
        build: Optional[Callable[..., Dict[str, Any]]],
        build_kwargs: Optional[Dict[str, Any]],
        store_dir: str,
        store_ram_bytes: int,
        cache_bytes: int,
        heartbeat_interval: float,
        options: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.conn = conn
        self.wid = worker_id
        self.session = session
        self.heartbeat_interval = heartbeat_interval
        opts = dict(options or {})
        self.opt_batch = bool(opts.get("batch", False))
        self.opt_warm = bool(opts.get("warm", False))
        self.opt_shm = bool(opts.get("shm", False))
        self.opt_async = bool(opts.get("async", False))
        self.max_batch = max(1, int(opts.get("max_batch", 16)))
        self.max_delay_ms = float(opts.get("max_delay_ms", 2.0))
        self.shm_max_bytes = int(opts.get("shm_max_bytes", 64 << 20))
        self._send_lock = threading.Lock()
        self._pending: "collections.deque[Dict[str, Any]]" = collections.deque()
        self._comp_buf: List[Dict[str, Any]] = []
        self._comp_t0 = 0.0
        self._fetched: Dict[str, Dict[str, Any]] = {}
        self._stop = False
        self._shm_seq = 0
        # single-writer counters: only the serve thread increments; the
        # heartbeat thread snapshots racily (stale ints are fine). Every
        # key is preset here so no increment ever RESIZES the dict under
        # the heartbeat thread's iteration — including "reconnects",
        # which run_worker bumps on a dict transplanted from the previous
        # connection's worker while its heartbeat thread may still be
        # draining.
        self.counters: Dict[str, int] = {
            "leases_run": 0,
            "plan_builds": 0,
            "plan_hits": 0,
            "shm_sends": 0,
            "inline_sends": 0,
            "store_sends": 0,
            "none_sends": 0,
            "comp_frames": 0,
            "comp_batched": 0,
            "fetches": 0,
            "reconnects": 0,
        }
        self.workflow = None
        self.inputs: List[Any] = []
        self.store = None
        self.cache = None
        self.ctx_error: Optional[str] = None
        self._plan_meta: "collections.OrderedDict[str, Dict[str, Any]]" = (
            collections.OrderedDict()
        )
        self._plan_cache: "collections.OrderedDict[str, Dict[str, Any]]" = (
            collections.OrderedDict()
        )
        try:
            spec = build(**(build_kwargs or {})) if build is not None else {}
            from repro.runtime.storage import mount_store

            # store_dir is a SPEC: a plain directory mounts the flocked
            # SharedStore, "obj:<root>" the object-store tier (§16) — the
            # same string the leader mounted, shipped verbatim
            self.store = mount_store(
                store_dir, store_ram_bytes, writer_id=f"rpcw{worker_id}"
            )
            from repro.engine.executor import ResultCache

            self.cache = ResultCache(cache_bytes, spill_store=self.store)
            self.workflow = spec.get("workflow")
            self.inputs = list(spec.get("inputs") or ())
        except BaseException:  # noqa: BLE001 — park and report per-lease
            self.ctx_error = traceback.format_exc()

    # -- wire helpers ---------------------------------------------------
    def _send(self, obj: Dict[str, Any]) -> None:
        _send_frame(self.conn, self._send_lock, obj)

    def _dispatch(self, msg: Dict[str, Any]) -> None:
        kind = msg.get("t")
        if kind == "stop":
            self._stop = True
        elif kind == "lease":
            self._pending.append(msg)
        elif kind == "lease_batch":
            self._pending.extend(msg["leases"])
        elif kind == "study":
            self._install(msg)
        elif kind == "fetched":
            self._fetched[msg["key"]] = msg

    def _pump_recv(self, timeout: float) -> bool:
        """Drain every frame the pipe has ready (blocking up to ``timeout``
        for the first); False means the leader hung up."""
        try:
            if not self.conn.poll(timeout):
                return True
            while True:
                self._dispatch(_recv_frame(self.conn))
                if not self.conn.poll():
                    return True
        except (EOFError, OSError):
            return False

    # -- study install / plan cache -------------------------------------
    def _install(self, msg: Dict[str, Any]) -> None:
        if self.ctx_error is not None:
            return
        try:
            recipe = msg["recipe"]
            rk = _recipe_key(recipe)
            warm_hit = self.opt_warm and rk in self._plan_cache
            # publish point: push the previous study's cached task outputs
            # through to the store's disk tier so peers — and a resumed
            # study over this store_dir — rehydrate instead of recomputing
            # (the fleet workers' per-round flush, same rule). A warm
            # re-install of an identical recipe skips it — the previous
            # install of this very study already published, and the
            # session-exit flush remains the backstop — so a benchmark
            # loop's timed window is not billed for fsyncing history.
            if self.cache is not None and not warm_hit:
                self.cache.flush()
            self._plan_meta[msg["plan_id"]] = {
                "recipe": recipe,
                "recipe_key": rk,
                "key_prefix": msg["key_prefix"],
                "input_keys": list(msg["input_keys"]),
                "cache_enabled": bool(msg["cache_enabled"]),
            }
            while len(self._plan_meta) > _PLAN_META_MAX:
                self._plan_meta.popitem(last=False)
            # prewarm: build (or re-hit) the plan NOW, on the broadcast,
            # so the first lease of the study pays nothing
            if warm_hit:
                self._plan_cache.move_to_end(rk)
                self.counters["plan_hits"] += 1
            else:
                self._plan_cache[rk] = self._build_plan(recipe)
                self.counters["plan_builds"] += 1
                while len(self._plan_cache) > _PLAN_CACHE_MAX:
                    self._plan_cache.popitem(last=False)
        except BaseException:  # noqa: BLE001
            self.ctx_error = traceback.format_exc()

    def _build_plan(self, recipe: Dict[str, Any]) -> Dict[str, Any]:
        """Rebuild a StudyPlan from its recipe against this worker's
        workflow. Planning is deterministic (sorted group keys, no RNG), so
        every worker and the leader hold structurally identical plans —
        which is what lets a lease name a bucket by ``(plan_id, input,
        stage, bucket)`` alone. The ``rid_maps`` index (run_id → bucket
        position per stage) makes upstream routing O(1) per lease."""
        from repro.engine.planner import plan_study
        from repro.engine.types import MemoryBudget

        if self.workflow is None:
            raise TransportError(
                "lease needs a workflow but the backend's build() returned none"
            )
        plan = plan_study(
            self.workflow,
            recipe["param_sets"],
            memory=MemoryBudget(
                bytes=recipe["memory_bytes"], cache_bytes=recipe["cache_bytes"]
            ),
            policy=recipe["policy"],
            max_bucket_size=recipe["max_bucket_size"],
            active_paths=recipe["active_paths"],
            workers=recipe["workers"],
        )
        rid_maps = [
            {rid: j for j, b in enumerate(sp.buckets) for rid in b.run_ids}
            for sp in plan.stages
        ]
        return {"plan": plan, "rid_maps": rid_maps}

    def _plan_for(self, plan_id: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        meta = self._plan_meta.get(plan_id)
        if meta is None:
            raise TransportError(f"unknown plan {plan_id!r} (study not installed)")
        entry = self._plan_cache.get(meta["recipe_key"])
        if entry is not None:
            self._plan_cache.move_to_end(meta["recipe_key"])
            self.counters["plan_hits"] += 1
            return meta, entry
        # evicted (or install raced an eviction): rebuild on demand
        entry = self._build_plan(meta["recipe"])
        self.counters["plan_builds"] += 1
        self._plan_cache[meta["recipe_key"]] = entry
        while len(self._plan_cache) > _PLAN_CACHE_MAX:
            self._plan_cache.popitem(last=False)
        return meta, entry

    # -- lease execution -------------------------------------------------
    def _run_lease(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        t0 = time.monotonic()
        base = {"t": "comp", "wid": self.wid, "key": msg["key"],
                "attempt": msg["attempt"]}
        if self.ctx_error is not None:
            return {**base, "ok": False,
                    "error": f"worker context failed to build:\n{self.ctx_error}"}
        try:
            reply = self._execute(msg["key"], msg["spec"])
            self.counters["leases_run"] += 1
            return {**base, "ok": True,
                    "duration": time.monotonic() - t0, **reply}
        except BaseException:  # noqa: BLE001 — report, don't die
            return {**base, "ok": False, "error": traceback.format_exc(),
                    "duration": time.monotonic() - t0}

    def _execute(self, work_key: str, spec: Tuple) -> Dict[str, Any]:
        """Run one lease spec and pick the result's route across the
        boundary: shm descriptor, inline value, or store key — per the
        backend flags (see the module docstring's handoff matrix)."""
        kind = spec[0]
        plan_scope: Optional[str] = None
        if kind == "call":
            value = run_call_spec(spec)
            meta: Dict[str, Any] = {"wrap": "raw"}
        elif kind == "bucket":
            _, plan_id, input_idx, si, bi = spec
            pm, entry = self._plan_for(plan_id)
            plan_scope = plan_id
            plan = entry["plan"]
            bucket = plan.stages[si].buckets[bi]
            prefix = pm["key_prefix"]
            if si == 0:
                src = self.inputs[input_idx]
            else:
                prev = plan.stages[si - 1]
                rid0 = bucket.run_ids[0]
                bj = entry["rid_maps"][si - 1][rid0]
                up_key = _result_store_key(
                    self.session,
                    f"{prefix}in{input_idx}:{prev.index}:{prev.stage.name}:{bj}",
                    plan_id,
                )
                src = self._resolve_upstream(up_key)[rid0]
            from repro.engine.executor import execute_bucket

            value, executed, hits = execute_bucket(
                bucket,
                src,
                self.cache if pm["cache_enabled"] else None,
                scope=("input", pm["input_keys"][input_idx]) + bucket.cache_scope,
            )
            meta = {"wrap": "bucket", "executed": executed, "hits": hits}
        else:
            raise TransportError(f"unknown lease spec kind {kind!r}")
        if value is None:
            # a legitimate None result: the store cannot represent it (a
            # get returning None means "missing"), so it rides the
            # completion as an explicit marker instead of a store key
            meta["none"] = True
            self.counters["none_sends"] += 1
            return meta
        store_key = _result_store_key(self.session, work_key, plan_scope)
        # RAM tier always: same-worker downstream buckets resolve locally
        self.store.put(store_key, value)
        meta["store_key"] = store_key
        if not self.opt_async:
            # the original durability contract: on disk BEFORE the ack
            self.store.persist(store_key)
            meta["committed"] = True
        if self.opt_shm:
            desc = self._shm_ship(value)
            if desc is not None:
                meta["shm"] = desc
                self.counters["shm_sends"] += 1
                return meta
        if self.opt_async:
            # leader stages it for the background flusher; the frame is
            # the handoff
            meta["inline"] = True
            meta["value"] = value
            self.counters["inline_sends"] += 1
        else:
            self.counters["store_sends"] += 1
        return meta

    def _shm_ship(self, value: Any) -> Optional[Dict[str, Any]]:
        self._shm_seq += 1
        name = f"rtf_{self.session}_{self.wid}_{self._shm_seq}"
        return shm_encode(value, name, max_bytes=self.shm_max_bytes)

    def _resolve_upstream(self, up_key: str) -> Any:
        value = self.store.get(up_key)
        if value is not None:
            return value
        if self.opt_async:
            # async mode: the value may only exist in the leader's staging
            # tier (acked but not yet flushed) — fetch it over the wire
            value = self._fetch(up_key)
            if value is not None:
                return value
        raise TransportError(
            f"upstream result {up_key!r} not resolvable from the store"
        )

    def _fetch(self, key: str) -> Optional[Any]:
        self.counters["fetches"] += 1
        self._send({"t": "fetch", "wid": self.wid, "key": key})
        deadline = time.monotonic() + _FETCH_TIMEOUT
        while time.monotonic() < deadline:
            msg = self._fetched.pop(key, None)
            if msg is not None:
                if not msg.get("found"):
                    return None
                value = msg["value"]
                # cache locally: sibling buckets of this stage resolve free
                self.store.put(key, value)
                return value
            if self._stop:
                return None
            try:
                if self.conn.poll(0.05):
                    self._dispatch(_recv_frame(self.conn))
            except (EOFError, OSError):
                return None
        raise TransportError(f"fetch of upstream {key!r} timed out")

    # -- completion shipping ---------------------------------------------
    def _unlink_comp_shm(self, comp: Dict[str, Any]) -> None:
        desc = comp.get("shm")
        if desc:
            _shm_unlink_by_name(desc["shm"])

    def _to_store_route(self, comp: Dict[str, Any]) -> Dict[str, Any]:
        """Demote an unpicklable inline completion to the store route:
        persist now, strip the payload."""
        comp = dict(comp)
        value = comp.pop("value", None)
        comp.pop("inline", None)
        try:
            if comp.get("store_key") and value is not None:
                self.store.persist(comp["store_key"])
                comp["committed"] = True
            return comp
        except BaseException:  # noqa: BLE001
            return {**{k: comp[k] for k in ("t", "wid", "key", "attempt")},
                    "ok": False, "error": traceback.format_exc()}

    def _flush_comps(self, buf: List[Dict[str, Any]]) -> bool:
        """Ship buffered completions: one ``comp_batch`` frame when
        batching, individual ``comp`` frames otherwise. Unpicklable inline
        values demote to the store route; a dead pipe unlinks any shm
        segments the leader will never decode. False = leader gone."""
        if not buf:
            return True
        try:
            if self.opt_batch:
                self._send({"t": "comp_batch", "wid": self.wid, "comps": buf})
                self.counters["comp_frames"] += 1
                self.counters["comp_batched"] += len(buf)
            else:
                for comp in buf:
                    self._send(comp)
                    self.counters["comp_frames"] += 1
            return True
        except (pickle.PicklingError, TypeError, AttributeError):
            ok = True
            for comp in buf:
                try:
                    self._send(comp)
                    self.counters["comp_frames"] += 1
                except (pickle.PicklingError, TypeError, AttributeError):
                    try:
                        self._send(self._to_store_route(comp))
                        self.counters["comp_frames"] += 1
                    except (OSError, ValueError, BrokenPipeError):
                        self._unlink_comp_shm(comp)
                        ok = False
                except (OSError, ValueError, BrokenPipeError):
                    self._unlink_comp_shm(comp)
                    ok = False
            return ok
        except (OSError, ValueError, BrokenPipeError):
            for comp in buf:
                self._unlink_comp_shm(comp)
            return False

    def _buffer_comp(self, reply: Dict[str, Any]) -> bool:
        if not self.opt_batch:
            return self._flush_comps([reply])
        if not self._comp_buf:
            self._comp_t0 = time.monotonic()
        self._comp_buf.append(reply)
        return True

    def _flush_due(self) -> bool:
        if not self._comp_buf:
            return False
        if len(self._comp_buf) >= self.max_batch:
            return True
        if not self._pending:  # nothing left to coalesce with
            return True
        return (time.monotonic() - self._comp_t0) * 1000.0 >= self.max_delay_ms

    # -- main loop --------------------------------------------------------
    def _stats_snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.counters)
        try:
            if self.cache is not None:
                out["cache"] = self.cache.counters()
            if self.store is not None:
                out["store"] = self.store.counters()
        except BaseException:  # noqa: BLE001 — stats must never kill hb
            pass
        return out

    def serve(self) -> None:
        hb_stop = threading.Event()

        def _heartbeats() -> None:
            while not hb_stop.wait(self.heartbeat_interval):
                try:
                    self._send({"t": "hb", "wid": self.wid,
                                "stats": self._stats_snapshot()})
                except (OSError, ValueError, BrokenPipeError):
                    return
                except BaseException:  # noqa: BLE001 — pickling stats &c.
                    pass

        threading.Thread(target=_heartbeats, daemon=True).start()
        try:
            self._send({"t": "hello", "wid": self.wid, "pid": os.getpid()})
            while True:
                idle = not self._pending and not self._comp_buf
                if not self._pump_recv(0.2 if idle else 0.0):
                    break  # leader hung up
                if self._stop:
                    self._flush_comps(self._comp_buf)
                    self._comp_buf = []
                    break  # queued leases are abandoned; retry re-drives
                if self._pending:
                    if not self._buffer_comp(self._run_lease(self._pending.popleft())):
                        break
                if self._flush_due():
                    buf, self._comp_buf = self._comp_buf, []
                    if not self._flush_comps(buf):
                        break
        finally:
            hb_stop.set()
            if self._comp_buf:
                self._flush_comps(self._comp_buf)
                self._comp_buf = []
            try:
                # durability barrier at session end: without it every
                # cached task output this worker never evicted would die
                # with the process, silently voiding zero-recompute resume
                if self.cache is not None:
                    self.cache.flush()
            except BaseException:  # noqa: BLE001 — shutdown must not raise
                pass
            try:
                self.conn.close()
            except OSError:
                pass


def _rpc_worker_main(
    conn,
    worker_id: int,
    session: str,
    build: Optional[Callable[..., Dict[str, Any]]],
    build_kwargs: Optional[Dict[str, Any]],
    store_dir: str,
    store_ram_bytes: int,
    cache_bytes: int,
    heartbeat_interval: float,
    options: Optional[Dict[str, Any]] = None,
) -> None:
    """Entry point of one spawn worker (see :class:`_RpcWorker`)."""
    _RpcWorker(
        conn, worker_id, session, build, build_kwargs, store_dir,
        store_ram_bytes, cache_bytes, heartbeat_interval, options,
    ).serve()


# ---------------------------------------------------------------------------
# ProcessRpcBackend — spawn workers behind the pickle control plane
# ---------------------------------------------------------------------------


def stop_processes(procs, *, grace: float = 5.0) -> None:
    """Bounded worker-process teardown, shared by the process and socket
    backends: a cooperative join window of ``grace`` seconds for the whole
    pool, then ``terminate()`` (SIGTERM) for laggards, then ``kill()``
    (SIGKILL) for anything that ignores SIGTERM — a stuck worker (wedged in
    an uninterruptible task, masking signals) can delay teardown by at most
    ``grace + ~3s``, never hang it."""
    deadline = time.monotonic() + max(0.0, grace)
    for proc in procs:
        proc.join(timeout=max(0.0, deadline - time.monotonic()))
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2.0)
        if proc.is_alive():  # ignored SIGTERM: escalate
            try:
                proc.kill()
            except (OSError, AttributeError):
                pass
            proc.join(timeout=1.0)


class _WorkerHandle:
    __slots__ = ("wid", "proc", "conn", "alive", "last_seen", "inflight", "pid")

    def __init__(self, wid, proc, conn):
        self.wid = wid
        self.proc = proc
        self.conn = conn
        self.alive = True
        self.last_seen = time.monotonic()
        self.inflight: Dict[str, Lease] = {}
        self.pid: Optional[int] = None


_MISSING = object()


class ProcessRpcBackend:
    """N ``spawn`` worker processes serving leases over a length-prefixed
    pickle control plane, with the four flag-gated fast-path mechanisms of
    DESIGN.md §14 (batched frames, warm plans, shared-memory handoff,
    async commit) — see the module docstring for the full matrix. All four
    default ON; ``process_flag_kwargs`` parses the ``"process[...]"``
    string syntax into these constructor flags.

    ``build`` is a spawn-picklable callable (module-level; kwargs picklable)
    returning ``{"workflow": ..., "inputs": [...]}`` — each worker calls it
    once to construct its own process-local execution context, exactly like
    the fleet runner's ``build``. Backends that only serve portable
    ``("call", fn, args, kwargs)`` specs may pass ``build=None``.
    """

    name = "process"
    supports_specs = True
    # workers heartbeat from a side thread even mid-task, so a fresh
    # heartbeat PROVES the lease live: the Manager spares such leases from
    # age-based expiry (long buckets get backup clones, not revocations)
    heartbeats_prove_liveness = True

    def __init__(
        self,
        build: Optional[Callable[..., Dict[str, Any]]] = None,
        build_kwargs: Optional[Dict[str, Any]] = None,
        *,
        store_dir: Optional[str] = None,
        store_ram_bytes: int = 256 << 20,
        cache_bytes: Optional[int] = None,
        mp_context: str = "spawn",
        heartbeat_interval: float = 0.25,
        batch_frames: bool = True,
        warm_plans: bool = True,
        shm_results: bool = True,
        async_commit: bool = True,
        max_batch: int = 16,
        max_delay_ms: float = 2.0,
        shm_max_bytes: int = 64 << 20,
        shutdown_grace: float = 5.0,
    ) -> None:
        from repro.engine.types import DEFAULT_CACHE_BYTES

        self.build = build
        self.build_kwargs = dict(build_kwargs or {})
        self._owns_store_dir = store_dir is None
        if store_dir is None:
            import tempfile

            store_dir = tempfile.mkdtemp(prefix="rtf_rpc_")
        self.store_dir = store_dir
        self.store_ram_bytes = int(store_ram_bytes)
        self.cache_bytes = int(cache_bytes or DEFAULT_CACHE_BYTES)
        self.mp_context = mp_context
        self.heartbeat_interval = float(heartbeat_interval)
        self.batch_frames = bool(batch_frames)
        self.warm_plans = bool(warm_plans)
        self.shm_results = bool(shm_results)
        self.async_commit = bool(async_commit)
        self.max_batch = max(1, int(max_batch))
        self.max_delay_ms = float(max_delay_ms)
        self.shm_max_bytes = int(shm_max_bytes)
        self.shutdown_grace = float(shutdown_grace)
        self._handles: List[_WorkerHandle] = []
        self._studies: List[Dict[str, Any]] = []  # replayed on (re)start
        self._store = None  # leader-side mount, lazy
        self._flusher = None  # AsyncCommitQueue when async_commit
        self._live_shm: set = set()  # segments named in undecoded frames
        self._worker_stats: Dict[int, Dict[str, Any]] = {}  # guard: _state_lock
        self._counters: Dict[str, int] = {  # guard: _state_lock
            "lease_frames": 0,
            "lease_batches": 0,
            "comp_batches": 0,
            "fetch_serves": 0,
            "shm_recv": 0,
        }
        # _lock serializes frame SENDS (it is the lock _send_frame takes
        # around conn.send_bytes); _state_lock guards leader-side mutable
        # state. Keeping them separate means no counter bump ever waits on
        # socket I/O — and no socket I/O ever runs under the state lock.
        self._lock = threading.Lock()
        self._state_lock = threading.Lock()
        # Session nonce scoping every result store key: minted per start(),
        # so a restarted backend (or another leader over one store_dir) can
        # never read a previous lifetime's result as its own.
        self._session = ""

    # -- leader-side store mount (result hydration) ---------------------
    @property
    def store(self):
        if self._store is None:
            from repro.runtime.storage import mount_store

            self._store = mount_store(
                self.store_dir, self.store_ram_bytes, writer_id="rpc-leader"
            )
        return self._store

    @property
    def slots_per_worker(self) -> int:
        """Queue depth the Manager pump may keep per worker: with batched
        frames a worker holds a small backlog so it never idles between
        round trips; without, the historical one-lease-per-worker."""
        return self.max_batch if self.batch_frames else 1

    def worker_pids(self) -> List[Optional[int]]:
        """Spawned worker process ids (test/ops hook — e.g. fault injection
        by SIGKILL)."""
        return [h.proc.pid for h in self._handles]

    # -- WorkerBackend protocol -----------------------------------------
    def start(self, n_workers: int) -> None:
        if self._handles:
            raise RuntimeError("ProcessRpcBackend already started")
        import multiprocessing
        import uuid

        self._session = uuid.uuid4().hex[:12]
        self._worker_stats = {}  # analysis: ok[locks] init phase, workers spawn below
        if self.async_commit:
            from repro.runtime.storage import AsyncCommitQueue

            self._flusher = AsyncCommitQueue(self.store)
        options = {
            "batch": self.batch_frames,
            "warm": self.warm_plans,
            "shm": self.shm_results,
            "async": self.async_commit,
            "max_batch": self.max_batch,
            "max_delay_ms": self.max_delay_ms,
            "shm_max_bytes": self.shm_max_bytes,
        }
        mp = multiprocessing.get_context(self.mp_context)
        handles = []
        for wid in range(max(1, n_workers)):
            parent, child = mp.Pipe(duplex=True)
            proc = mp.Process(
                target=_rpc_worker_main,
                args=(
                    child, wid, self._session, self.build, self.build_kwargs,
                    self.store_dir, self.store_ram_bytes, self.cache_bytes,
                    self.heartbeat_interval, options,
                ),
                daemon=True,
            )
            proc.start()
            child.close()
            handles.append(_WorkerHandle(wid, proc, parent))
        self._handles = handles
        for study in self._studies:  # restart: re-install session context
            self._broadcast({"t": "study", **study})

    def install_study(self, **study: Any) -> None:
        """Broadcast a study context (plan recipe + key prefix + input keys)
        to every worker; pipes are ordered, so any lease sent afterwards
        finds the plan installed — and with ``warm_plans`` the broadcast is
        the prewarm: workers build (or recipe-hit) the plan on receipt,
        before the first lease arrives."""
        self._studies.append(dict(study))
        if len(self._studies) > 8:
            self._studies = self._studies[-8:]
        self._broadcast({"t": "study", **study})

    def _broadcast(self, msg: Dict[str, Any]) -> None:
        for h in self._handles:
            if not h.alive:
                continue
            try:
                _send_frame(h.conn, self._lock, msg)
            except (OSError, ValueError, BrokenPipeError):
                h.alive = False

    def offer(self, lease: Lease) -> bool:
        return not self.offer_batch([lease])

    def offer_batch(
        self, leases: List[Lease], worker_ids=None
    ) -> List[Lease]:
        """Distribute a batch of leases across workers with spare queue
        depth — one ``lease_batch`` frame per worker (when batching) —
        and return the leases no worker could take (the Manager unleases
        them). Least-loaded workers are filled first, round-robin, so a
        burst spreads instead of piling onto worker 0.

        ``worker_ids`` restricts the batch to a shard of the pool — the
        hierarchical scheduler's sub-manager pumps each own a disjoint
        shard, so their concurrent ``offer_batch`` calls touch disjoint
        worker handles (frame sends stay serialised by the send lock)."""
        for lease in leases:
            if lease.spec is None:
                raise TransportError(
                    f"lease {lease.key!r} has no picklable spec: the process "
                    "backend cannot ship closures across the boundary"
                )
        slots = self.slots_per_worker
        # inflight maps are written here (sub-pump threads) and popped by
        # the leader pump's hydration: capacity math runs under the state
        # lock so neither side sees a map mid-mutation
        with self._state_lock:
            ws = [
                h for h in self._handles
                if h.alive and h.proc.is_alive() and len(h.inflight) < slots
                and (worker_ids is None or h.wid in worker_ids)
            ]
            ws.sort(key=lambda h: len(h.inflight))
            caps = {h.wid: slots - len(h.inflight) for h in ws}
        if not ws:
            return list(leases)
        assigned: Dict[int, List[Lease]] = {h.wid: [] for h in ws}
        rejected: List[Lease] = []
        i = 0
        for lease in leases:
            for _ in range(len(ws)):
                h = ws[i % len(ws)]
                i += 1
                if caps[h.wid] > 0:
                    assigned[h.wid].append(lease)
                    caps[h.wid] -= 1
                    break
            else:
                rejected.append(lease)
        for h in ws:
            batch = assigned[h.wid]
            if not batch:
                continue
            frames = 1 if (self.batch_frames and len(batch) > 1) else len(batch)
            try:
                if self.batch_frames and len(batch) > 1:
                    _send_frame(
                        h.conn, self._lock,
                        {"t": "lease_batch",
                         "leases": [
                             {"key": l.key, "attempt": l.attempt, "spec": l.spec}
                             for l in batch
                         ]},
                    )
                else:
                    for l in batch:
                        _send_frame(
                            h.conn, self._lock,
                            {"t": "lease", "key": l.key, "attempt": l.attempt,
                             "spec": l.spec},
                        )
            except (OSError, ValueError, BrokenPipeError):
                h.alive = False
                rejected.extend(batch)
                continue
            with self._state_lock:
                self._counters["lease_frames"] += frames
                if self.batch_frames and len(batch) > 1:
                    self._counters["lease_batches"] += 1
                for l in batch:
                    h.inflight[l.lease_id] = l
        return rejected

    def poll_completions(self, timeout: float) -> List[Completion]:
        import multiprocessing.connection as mpc

        live = [h for h in self._handles if h.alive]
        if not live:
            time.sleep(min(max(timeout, 0.0), 0.05))
            return []
        ready = mpc.wait([h.conn for h in live], timeout=max(0.0, timeout))
        by_conn = {h.conn: h for h in live}
        out: List[Completion] = []
        for conn in ready:
            h = by_conn[conn]
            try:
                while True:
                    msg = _recv_frame(conn)
                    h.last_seen = time.monotonic()
                    kind = msg.get("t")
                    if kind == "comp":
                        out.append(self._hydrate(h, msg))
                    elif kind == "comp_batch":
                        with self._state_lock:
                            self._counters["comp_batches"] += 1
                        for m in msg["comps"]:
                            out.append(self._hydrate(h, m))
                    elif kind == "fetch":
                        self._serve_fetch(h, msg["key"])
                    elif kind == "hb":
                        stats = msg.get("stats")
                        if stats:
                            with self._state_lock:
                                self._worker_stats[h.wid] = stats
                    elif kind == "hello":
                        h.pid = msg.get("pid")
                    if not conn.poll():
                        break
            except (EOFError, OSError):
                h.alive = False
        return out

    def _serve_fetch(self, h: _WorkerHandle, key: str) -> None:
        """Answer a worker's upstream fetch from the staging tier (acked
        but not yet durable) or the store — the async-commit counterpart of
        cross-worker resolution through the disk tier."""
        value = self._flusher.peek(key) if self._flusher is not None else None
        if value is None:
            value = self.store.get(key)
        with self._state_lock:
            self._counters["fetch_serves"] += 1
        try:
            _send_frame(
                h.conn, self._lock,
                {"t": "fetched", "key": key, "found": value is not None,
                 "value": value},
            )
        except (OSError, ValueError, BrokenPipeError):
            h.alive = False

    def _hydrate(self, h: _WorkerHandle, msg: Dict[str, Any]) -> Completion:
        """Turn a wire completion into a Manager-facing one: resolve the
        value by whichever route it took (shm segment, inline payload, or
        store key), stage not-yet-durable values for the background
        flusher, and re-wrap bucket results into the executor's
        ``(outputs, executed, hits)`` shape."""
        with self._state_lock:
            h.inflight.pop(f"{msg['key']}#{msg['attempt']}", None)
        if not msg.get("ok"):
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=False,
                error=msg.get("error") or "remote task failed",
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        if msg.get("none"):  # an explicit None result (never stored)
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=True, value=None,
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        store_key = msg.get("store_key")
        value = _MISSING
        desc = msg.get("shm")
        if desc is not None:
            name = desc["shm"]
            self._live_shm.add(name)
            try:
                value = shm_decode(desc)
                with self._state_lock:
                    self._counters["shm_recv"] += 1
            except BaseException:  # noqa: BLE001 — fall back to the store
                value = _MISSING
            finally:
                self._live_shm.discard(name)
        elif msg.get("inline"):
            value = msg["value"]
        if value is _MISSING:
            value = self.store.get(store_key)
            if value is None and self._flusher is not None:
                value = self._flusher.peek(store_key)
            if value is None:
                return Completion(
                    key=msg["key"], attempt=msg["attempt"], ok=False,
                    error=f"result {store_key!r} missing from the store",
                    worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
                )
        if self._flusher is not None and not msg.get("committed"):
            # stage the RAW value (workers fetch/rehydrate the unwrapped
            # form); the flusher makes it durable in the background
            self._flusher.stage(store_key, value)
        if msg.get("wrap") == "bucket":
            value = (value, int(msg["executed"]), int(msg["hits"]))
        return Completion(
            key=msg["key"], attempt=msg["attempt"], ok=True, value=value,
            store_key=store_key, worker_id=h.wid,
            duration=float(msg.get("duration", 0.0)),
        )

    def heartbeat_view(self) -> Dict[int, WorkerStatus]:
        view = {}
        for h in self._handles:
            alive = h.alive and h.proc.is_alive()
            if not alive:
                h.alive = False
            # snapshot under the state lock: a sub-pump inserting into this
            # map mid-tuple() would raise "dict changed size during iteration"
            with self._state_lock:
                inflight = tuple(h.inflight)
            view[h.wid] = WorkerStatus(
                alive=alive, last_seen=h.last_seen, inflight=inflight
            )
        return view

    def barrier(self, timeout: Optional[float] = None) -> bool:
        """Durability point: block until every staged completion is in the
        store's disk tier (no-op → True when async commit is off).
        ``Manager.drain`` and ``StudyState.save`` call this."""
        if self._flusher is None:
            return True
        return self._flusher.barrier(timeout)

    def stats(self) -> Dict[str, Any]:
        """Leader counters + flag settings + an across-the-pool aggregate
        of the workers' heartbeat-shipped counters (plan cache hits/builds,
        handoff route counts, task-cache and store tiers)."""
        with self._state_lock:
            per_worker = [dict(s) for s in self._worker_stats.values()]
            leader = dict(self._counters)
        worker_agg: Dict[str, Any] = {}
        for stats in per_worker:
            _merge_int_tree(worker_agg, stats)
        out: Dict[str, Any] = {
            "backend": self.name,
            "workers": len(self._handles),
            "flags": {
                "batch_frames": self.batch_frames,
                "warm_plans": self.warm_plans,
                "shm_results": self.shm_results,
                "async_commit": self.async_commit,
            },
            "leader": leader,
            "worker": worker_agg,
        }
        if self._flusher is not None:
            out["flusher"] = {
                "staged": self._flusher.staged,
                "committed": self._flusher.committed,
                "errors": self._flusher.errors,
                "staged_peak": self._flusher.staged_peak,
                "pending": self._flusher.pending(),
            }
        return out

    def shutdown(self) -> None:
        """Retire the pool: flush the staging tier (bounded — a wedged
        store write cannot hang teardown), stop workers with a bounded
        join (terminate → kill escalation for hung ones, so
        ``Manager.close()`` can never hang a fleet teardown), then sweep
        this session's transient state — store entries AND any leftover
        shared-memory segments, so repeated runs can't leak ``/dev/shm``."""
        if self._flusher is not None:
            # staged-but-unflushed completions reach disk before the
            # flusher retires; a poisoned entry is dropped, a wedged one
            # abandoned at the deadline — neither hangs
            try:
                self._flusher.close(flush=True, timeout=self.shutdown_grace * 2)
            except BaseException:  # noqa: BLE001
                pass
            self._flusher = None
        for h in self._handles:
            if h.alive:
                try:
                    _send_frame(h.conn, self._lock, {"t": "stop"})
                except (OSError, ValueError, BrokenPipeError):
                    pass
        stop_processes([h.proc for h in self._handles], grace=self.shutdown_grace)
        for h in self._handles:
            try:
                h.conn.close()
            except OSError:
                pass
        self._handles = []
        self._purge_session_entries()
        self._sweep_shm()

    def _purge_session_entries(self) -> None:
        """Best-effort removal of THIS session's ``rpc:<session>:…`` result
        entries from the store. They are transient transport payloads — the
        session nonce makes them unreachable to any future session, so on a
        caller-owned persistent ``store_dir`` (an adaptive study's reuse
        pool) they would otherwise accumulate as dead weight forever. The
        durable cross-round reuse pool (the workers' task-level cache keys)
        is untouched. Entries a kill orphans are leaked until the directory
        is retired — the manifest still records them for audit."""
        if not self._session:
            return
        prefix = f"rpc:{self._session}:"
        try:
            for key in self.store.committed_keys():
                if key.startswith(prefix):
                    self.store.delete(key)
        except OSError:  # pragma: no cover - purge is best-effort
            pass

    def _sweep_shm(self) -> None:
        """Unlink every shared-memory segment this session may have left
        behind: tracked in-frame names first, then a ``/dev/shm`` scan for
        the session's deterministic ``rtf_<session>_…`` prefix (covers
        segments a killed worker created but never reported)."""
        if not self._session:
            return
        names = set(self._live_shm)
        self._live_shm = set()
        prefix = f"rtf_{self._session}_"
        shm_root = pathlib.Path("/dev/shm")
        try:
            if shm_root.is_dir():
                names.update(
                    p.name for p in shm_root.iterdir()
                    if p.name.startswith(prefix)
                )
        except OSError:  # pragma: no cover - scan is best-effort
            pass
        for name in names:
            _shm_unlink_by_name(name)

    def cleanup(self) -> None:
        """Remove the backend's store directory IF this backend created it
        (default tempdir mode) and no workers are running. ``shutdown``
        deliberately leaves the store readable — callers often inspect
        committed results after a session retires — so owners of throwaway
        backends (the app-level ``backend="process"`` paths call this) must
        cleanup explicitly; a caller-supplied ``store_dir`` is never
        touched (it is the caller's reuse pool)."""
        if not self._owns_store_dir or self._handles:
            return
        import shutil

        self._store = None
        shutil.rmtree(self.store_dir, ignore_errors=True)


def _merge_int_tree(dst: Dict[str, Any], src: Dict[str, Any]) -> None:
    """Sum ``src``'s numeric leaves into ``dst`` (nested dicts recurse) —
    how per-worker counter snapshots aggregate into pool stats."""
    for k, v in src.items():
        if isinstance(v, dict):
            _merge_int_tree(dst.setdefault(k, {}), v)
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            dst[k] = dst.get(k, 0) + v

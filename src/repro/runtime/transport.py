"""Transport-agnostic Worker backends — the Manager's dispatch boundary
(DESIGN.md §13).

The Manager is a pure scheduler/bookkeeper: it owns the queue, the lease
table, retry/backup/heartbeat policy and result memoisation, and talks to
its Workers exclusively through the :class:`WorkerBackend` protocol::

    start(n) / offer(lease) / poll_completions(timeout) / heartbeat_view()
    / shutdown()

with :class:`Lease` / :class:`Completion` dataclasses as the only currency.
Everything the paper's multi-node deployment needs from the boundary is in
those five calls: demand signalling (``heartbeat_view`` exposes free
slots), at-least-once dispatch (``offer`` may be re-driven after an
expiry), and completion delivery decoupled from scheduling. Two conforming
implementations ship here:

* :class:`ThreadBackend` — the historical behavior: Worker threads in this
  process executing ``Lease.fn`` closures directly. The default, so every
  existing ``Manager()`` caller keeps working unchanged.
* :class:`ProcessRpcBackend` — N ``spawn`` worker *processes* running
  :func:`_rpc_worker_main`, speaking a length-prefixed pickle control plane
  over ``multiprocessing.Connection`` pipes. Control messages carry only
  keys, attempt numbers and small picklable task *specs*; task **results
  never cross the wire** — workers commit them to a shared
  :class:`~repro.runtime.storage.SharedStore` directory and the completion
  message carries the store key (the results-by-store-reference rule).
  Worker processes rebuild their execution context (workflow, inputs) from
  a spawn-picklable ``build`` callable — the same pattern the fleet runner
  uses — and rebuild each StudyPlan deterministically from the plan's
  ``recipe``, so no unpicklable closure ever needs to cross a process
  boundary.

The frame format is deliberately transport-portable: ``<8-byte LE length>
<pickle payload>`` — ``multiprocessing.Connection`` adds its own framing
today, but the explicit prefix means the same codec drives a raw socket
when workers move to other hosts (the ROADMAP follow-on).
"""

from __future__ import annotations

import collections
import dataclasses
import os
import pickle
import queue
import struct
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Lease",
    "Completion",
    "WorkerStatus",
    "WorkerBackend",
    "ThreadBackend",
    "ProcessRpcBackend",
    "RemoteTaskError",
    "TransportError",
    "make_backend",
]


class TransportError(RuntimeError):
    """A structural failure of the dispatch boundary itself (torn frame,
    spec missing for a cross-process lease) — distinct from a task failing."""


class RemoteTaskError(RuntimeError):
    """A task failed on the far side of the boundary; carries the remote
    traceback text (the original exception object cannot cross the wire)."""


@dataclasses.dataclass
class Lease:
    """One attempt of one key, handed to a backend for execution.

    ``fn`` is the in-process closure (never serialised; ignored by remote
    backends); ``spec`` is the small picklable task description remote
    backends ship instead. A backend consumes whichever representation it
    supports — :class:`ThreadBackend` prefers ``fn``, falling back to the
    portable ``("call", callable, args, kwargs)`` spec form so one WorkItem
    can conform on every backend.
    """

    key: str
    attempt: int
    fn: Optional[Callable[[], Any]] = None
    spec: Optional[Tuple] = None

    @property
    def lease_id(self) -> str:
        return f"{self.key}#{self.attempt}"


@dataclasses.dataclass
class Completion:
    """Terminal report of one lease: a value (hydrated by the backend —
    possibly from the shared store) or a failure. ``exc`` carries the
    original exception object for in-process backends; remote backends can
    only ship ``error`` text, which the Manager wraps in
    :class:`RemoteTaskError`."""

    key: str
    attempt: int
    ok: bool
    value: Any = None
    exc: Optional[BaseException] = None
    error: Optional[str] = None
    store_key: Optional[str] = None
    worker_id: int = -1
    duration: float = 0.0

    @property
    def lease_id(self) -> str:
        return f"{self.key}#{self.attempt}"


@dataclasses.dataclass(frozen=True)
class WorkerStatus:
    """One worker's row in ``heartbeat_view()``: liveness, the monotonic
    timestamp of its last sign of life, and the lease ids it currently
    holds. A dead worker keeps reporting its orphaned leases so the Manager
    can re-enqueue them (idempotently — it pops each from its lease table
    exactly once)."""

    alive: bool
    last_seen: float
    inflight: Tuple[str, ...] = ()


try:  # Protocol is typing-only; keep the module importable everywhere
    from typing import Protocol, runtime_checkable

    @runtime_checkable
    class WorkerBackend(Protocol):
        """The Manager↔Worker contract. Implementations own worker
        lifecycle and execution; the Manager owns every scheduling
        decision.

        Beyond the five methods, two class flags complete the contract:
        ``supports_specs`` (True ⇒ leases are shipped by picklable spec,
        closures never cross — the executor then also requires an
        ``install_study(**study)`` method to broadcast plan recipes before
        any bucket lease references them) and
        ``heartbeats_prove_liveness`` (True ⇒ a fresh ``last_seen`` proves
        a worker's leases live mid-task, sparing them age-based expiry).
        """

        name: str
        supports_specs: bool
        heartbeats_prove_liveness: bool

        def start(self, n_workers: int) -> None:
            """Bring up the worker pool (idempotent per session; a backend
            may be restarted after ``shutdown``)."""

        def offer(self, lease: Lease) -> bool:
            """Hand a lease to a free worker. Returns False when no worker
            can take it right now (the Manager re-queues the item)."""

        def poll_completions(self, timeout: float) -> List["Completion"]:
            """Block up to ``timeout`` seconds for completions; drain and
            return everything available (possibly empty)."""

        def heartbeat_view(self) -> Dict[int, WorkerStatus]:
            """Per-worker liveness + inflight leases; the basis of the
            Manager's demand, straggler and dead-worker decisions."""

        def shutdown(self) -> None:
            """Retire the pool; outstanding leases may be abandoned."""

except ImportError:  # pragma: no cover - pre-3.8 fallback
    WorkerBackend = object  # type: ignore[misc,assignment]


def run_call_spec(spec: Tuple) -> Any:
    """Execute the portable ``("call", fn, args, kwargs)`` spec form — the
    backend-independent task representation the conformance suite drives
    both backends with."""
    kind = spec[0]
    if kind != "call":
        raise TransportError(f"unsupported lease spec {kind!r} for direct call")
    _, fn, args, kwargs = spec
    return fn(*args, **(kwargs or {}))


def make_backend(spec: Any) -> "WorkerBackend":
    """Resolve a backend spec: ``None``/``"thread"`` → a fresh
    :class:`ThreadBackend`; a :class:`WorkerBackend` instance passes
    through; a zero-arg callable is invoked (factory form). ``"process"``
    cannot be built here — a :class:`ProcessRpcBackend` needs a ``build``
    for its workers, so the caller must construct it."""
    if spec is None or spec == "thread":
        return ThreadBackend()
    if isinstance(spec, str):
        raise ValueError(
            f"backend spec {spec!r} is not constructible from a name alone; "
            "pass a ProcessRpcBackend(build=...) instance for process workers"
        )
    if callable(spec) and not hasattr(spec, "offer"):
        return spec()
    return spec


# ---------------------------------------------------------------------------
# ThreadBackend — the historical in-process Worker pool, behind the API
# ---------------------------------------------------------------------------

_STOP = object()


class ThreadBackend:
    """Worker threads in this process. Leases execute their ``fn`` closure
    (or the portable ``("call", ...)`` spec when no closure is attached);
    values stay on the heap — nothing is serialised. One slot per worker:
    the Manager sees demand as workers with an empty inflight tuple."""

    name = "thread"
    supports_specs = False
    # a thread cannot sign life while inside a task fn, so its heartbeats
    # prove nothing mid-task — the Manager keeps age-based expiry
    heartbeats_prove_liveness = False

    def __init__(self) -> None:
        self._threads: List[threading.Thread] = []
        self._inboxes: List["queue.Queue"] = []
        self._inflight: List[set] = []
        self._completions: "queue.Queue[Completion]" = queue.Queue()
        self._lock = threading.Lock()

    def start(self, n_workers: int) -> None:
        if self._threads:
            raise RuntimeError("ThreadBackend already started")
        n = max(1, n_workers)
        self._completions = queue.Queue()
        self._inboxes = [queue.Queue() for _ in range(n)]
        self._inflight = [set() for _ in range(n)]
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def offer(self, lease: Lease) -> bool:
        with self._lock:
            for wid, t in enumerate(self._threads):
                if t.is_alive() and not self._inflight[wid]:
                    self._inflight[wid].add(lease.lease_id)
                    break
            else:
                return False
        self._inboxes[wid].put(lease)
        return True

    def poll_completions(self, timeout: float) -> List[Completion]:
        out: List[Completion] = []
        try:
            out.append(self._completions.get(timeout=max(0.0, timeout)))
        except queue.Empty:
            return out
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def heartbeat_view(self) -> Dict[int, WorkerStatus]:
        now = time.monotonic()
        with self._lock:
            return {
                wid: WorkerStatus(
                    alive=t.is_alive(),
                    last_seen=now,
                    inflight=tuple(self._inflight[wid]),
                )
                for wid, t in enumerate(self._threads)
            }

    def shutdown(self) -> None:
        for inbox in self._inboxes:
            inbox.put(_STOP)
        for t in self._threads:
            t.join()
        self._threads = []
        self._inboxes = []
        self._inflight = []

    def _worker(self, wid: int) -> None:
        inbox = self._inboxes[wid]
        while True:
            lease = inbox.get()
            if lease is _STOP:
                return
            t0 = time.monotonic()
            try:
                if lease.fn is not None:
                    value = lease.fn()
                else:
                    value = run_call_spec(lease.spec)
            except Exception as e:  # noqa: BLE001 — the Manager owns retry
                comp = Completion(
                    key=lease.key, attempt=lease.attempt, ok=False, exc=e,
                    error=repr(e), worker_id=wid,
                    duration=time.monotonic() - t0,
                )
            else:
                comp = Completion(
                    key=lease.key, attempt=lease.attempt, ok=True, value=value,
                    worker_id=wid, duration=time.monotonic() - t0,
                )
            with self._lock:
                self._inflight[wid].discard(lease.lease_id)
            self._completions.put(comp)


# ---------------------------------------------------------------------------
# Wire codec: length-prefixed pickle frames
# ---------------------------------------------------------------------------

_FRAME_HEADER = struct.Struct("<Q")


def _send_frame(conn, lock: threading.Lock, obj: Any) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    frame = _FRAME_HEADER.pack(len(payload)) + payload
    with lock:
        conn.send_bytes(frame)


def _recv_frame(conn) -> Any:
    frame = conn.recv_bytes()
    if len(frame) < _FRAME_HEADER.size:
        raise TransportError("short frame")
    (length,) = _FRAME_HEADER.unpack(frame[: _FRAME_HEADER.size])
    if length != len(frame) - _FRAME_HEADER.size:
        raise TransportError(
            f"torn frame: header says {length}, got {len(frame) - _FRAME_HEADER.size}"
        )
    return pickle.loads(frame[_FRAME_HEADER.size:])


def _result_store_key(session: str, work_key: str, plan_id: Optional[str] = None) -> str:
    """Store key a worker commits a lease's result under. Keyed by the WORK
    key, not the lease id: racing attempts of one key compute the same pure
    value, so the SharedStore's per-key lock elides the double-write — but
    scoped by the backend **session nonce** (and, for bucket leases, the
    plan id) so a restarted backend or a second plan sharing one session
    can never be served a previous lifetime's entry as if it were its own.
    (Cross-round/cross-worker reuse does not live here: it flows through
    the workers' task-level ResultCache keys, which are deliberately
    session-independent.)"""
    if plan_id is not None:
        return f"rpc:{session}:{plan_id}:{work_key}"
    return f"rpc:{session}:{work_key}"


# ---------------------------------------------------------------------------
# The worker process main loop
# ---------------------------------------------------------------------------


def _rpc_worker_main(
    conn,
    worker_id: int,
    session: str,
    build: Optional[Callable[..., Dict[str, Any]]],
    build_kwargs: Optional[Dict[str, Any]],
    store_dir: str,
    store_ram_bytes: int,
    cache_bytes: int,
    heartbeat_interval: float,
) -> None:
    """Entry point of one spawn worker: build the execution context, mount
    the SharedStore, then serve leases until told to stop. A failing
    ``build`` is parked and surfaced as a failure on every lease (the
    fleet-runner pattern: a raising child would just die silently).

    A daemon heartbeat thread keeps signing life even while a task runs, so
    the leader can tell "busy on a long bucket" from "dead" — something the
    in-process thread backend structurally cannot."""
    from repro.runtime.storage import SharedStore

    send_lock = threading.Lock()
    ctx: Dict[str, Any] = {}
    ctx_error: Optional[str] = None
    store = None
    cache = None
    try:
        spec = build(**(build_kwargs or {})) if build is not None else {}
        store = SharedStore(
            store_ram_bytes, disk_dir=store_dir, writer_id=f"rpcw{worker_id}"
        )
        from repro.engine.executor import ResultCache

        cache = ResultCache(cache_bytes, spill_store=store)
        ctx = {
            "workflow": spec.get("workflow"),
            "inputs": list(spec.get("inputs") or ()),
            # StudyPlans rebuilt from recipes, keyed by plan_id (bounded)
            "plans": collections.OrderedDict(),
        }
    except BaseException:  # noqa: BLE001 — park and report per-lease
        ctx_error = traceback.format_exc()

    stop = threading.Event()

    def _heartbeats() -> None:
        while not stop.wait(heartbeat_interval):
            try:
                _send_frame(conn, send_lock, {"t": "hb", "wid": worker_id})
            except (OSError, ValueError, BrokenPipeError):
                return

    threading.Thread(target=_heartbeats, daemon=True).start()
    try:
        _send_frame(conn, send_lock, {"t": "hello", "wid": worker_id, "pid": os.getpid()})
        while True:
            try:
                msg = _recv_frame(conn)
            except (EOFError, OSError):
                break
            kind = msg.get("t")
            if kind == "stop":
                break
            if kind == "study":
                if ctx_error is None:
                    try:
                        # publish point: push the previous study's cached
                        # task outputs through to the store's disk tier so
                        # peers — and a resumed study over this store_dir —
                        # rehydrate instead of recomputing (the fleet
                        # workers' per-round flush, same rule)
                        if cache is not None:
                            cache.flush()
                        _install_study(ctx, msg)
                    except BaseException:  # noqa: BLE001
                        ctx_error = traceback.format_exc()
                continue
            if kind != "lease":
                continue
            t0 = time.monotonic()
            if ctx_error is not None:
                reply = {
                    "t": "comp", "wid": worker_id, "key": msg["key"],
                    "attempt": msg["attempt"], "ok": False,
                    "error": f"worker context failed to build:\n{ctx_error}",
                }
            else:
                try:
                    store_key, meta = _execute_lease_spec(
                        ctx, store, cache, session, msg["key"], msg["spec"]
                    )
                    reply = {
                        "t": "comp", "wid": worker_id, "key": msg["key"],
                        "attempt": msg["attempt"], "ok": True,
                        "store_key": store_key,
                        "duration": time.monotonic() - t0, **meta,
                    }
                except BaseException:  # noqa: BLE001 — report, don't die
                    reply = {
                        "t": "comp", "wid": worker_id, "key": msg["key"],
                        "attempt": msg["attempt"], "ok": False,
                        "error": traceback.format_exc(),
                        "duration": time.monotonic() - t0,
                    }
            try:
                _send_frame(conn, send_lock, reply)
            except (OSError, ValueError, BrokenPipeError):
                break
    finally:
        stop.set()
        try:
            # durability barrier at session end: without it every cached
            # task output this worker never evicted would die with the
            # process, silently voiding zero-recompute resume
            if cache is not None:
                cache.flush()
        except BaseException:  # noqa: BLE001 — shutdown must not hang/raise
            pass
        try:
            conn.close()
        except OSError:
            pass


def _install_study(ctx: Dict[str, Any], msg: Dict[str, Any]) -> None:
    """Rebuild a StudyPlan from its recipe against this worker's workflow.
    Planning is deterministic (sorted group keys, no RNG), so every worker
    and the leader hold structurally identical plans — which is what lets a
    lease name a bucket by ``(plan_id, input, stage, bucket)`` alone."""
    from repro.engine.planner import plan_study
    from repro.engine.types import MemoryBudget

    wf = ctx.get("workflow")
    if wf is None:
        raise TransportError(
            "lease needs a workflow but the backend's build() returned none"
        )
    recipe = msg["recipe"]
    plan = plan_study(
        wf,
        recipe["param_sets"],
        memory=MemoryBudget(
            bytes=recipe["memory_bytes"], cache_bytes=recipe["cache_bytes"]
        ),
        policy=recipe["policy"],
        max_bucket_size=recipe["max_bucket_size"],
        active_paths=recipe["active_paths"],
        workers=recipe["workers"],
    )
    plans = ctx["plans"]
    plans[msg["plan_id"]] = {
        "plan": plan,
        "key_prefix": msg["key_prefix"],
        "input_keys": list(msg["input_keys"]),
        "cache_enabled": bool(msg["cache_enabled"]),
    }
    while len(plans) > 8:  # adaptive studies install one plan per round
        plans.popitem(last=False)


def _execute_lease_spec(
    ctx: Dict[str, Any], store, cache, session: str, work_key: str, spec: Tuple
) -> Tuple[str, Dict[str, Any]]:
    """Run one lease spec and commit its result to the shared store's DISK
    tier (peers and the leader resolve it by key — the only way a result
    ever leaves this process). Returns ``(store_key, completion metadata)``.
    """
    kind = spec[0]
    plan_scope: Optional[str] = None
    if kind == "call":
        value = run_call_spec(spec)
        meta: Dict[str, Any] = {"wrap": "raw"}
    elif kind == "bucket":
        _, plan_id, input_idx, si, bi = spec
        entry = ctx["plans"].get(plan_id)
        if entry is None:
            raise TransportError(f"unknown plan {plan_id!r} (study not installed)")
        plan_scope = plan_id
        plan = entry["plan"]
        stage_plan = plan.stages[si]
        bucket = stage_plan.buckets[bi]
        prefix = entry["key_prefix"]
        if si == 0:
            src = ctx["inputs"][input_idx]
        else:
            prev = plan.stages[si - 1]
            rid0 = bucket.run_ids[0]
            bj = next(
                j for j, b in enumerate(prev.buckets) if rid0 in set(b.run_ids)
            )
            up_key = _result_store_key(
                session,
                f"{prefix}in{input_idx}:{prev.index}:{prev.stage.name}:{bj}",
                plan_id,
            )
            upstream = store.get(up_key)
            if upstream is None:
                raise TransportError(
                    f"upstream result {up_key!r} not resolvable from the store"
                )
            src = upstream[rid0]
        from repro.engine.executor import execute_bucket

        ikey = entry["input_keys"][input_idx]
        value, executed, hits = execute_bucket(
            bucket,
            src,
            cache if entry["cache_enabled"] else None,
            scope=("input", ikey) + bucket.cache_scope,
        )
        meta = {"wrap": "bucket", "executed": executed, "hits": hits}
    else:
        raise TransportError(f"unknown lease spec kind {kind!r}")
    if value is None:
        # a legitimate None result: the store cannot represent it (a get
        # returning None means "missing"), so it rides the completion as an
        # explicit marker instead of a store key — still no payload bytes
        # on the wire
        meta["none"] = True
        return None, meta
    store_key = _result_store_key(session, work_key, plan_scope)
    store.put(store_key, value)
    store.persist(store_key)  # must reach disk BEFORE the completion is sent
    return store_key, meta


# ---------------------------------------------------------------------------
# ProcessRpcBackend — spawn workers behind the pickle control plane
# ---------------------------------------------------------------------------


class _WorkerHandle:
    __slots__ = ("wid", "proc", "conn", "alive", "last_seen", "inflight", "pid")

    def __init__(self, wid, proc, conn):
        self.wid = wid
        self.proc = proc
        self.conn = conn
        self.alive = True
        self.last_seen = time.monotonic()
        self.inflight: Dict[str, Lease] = {}
        self.pid: Optional[int] = None


class ProcessRpcBackend:
    """N ``spawn`` worker processes serving leases over a length-prefixed
    pickle control plane; results cross the boundary only as
    :class:`~repro.runtime.SharedStore` keys (see the module docstring).

    ``build`` is a spawn-picklable callable (module-level; kwargs picklable)
    returning ``{"workflow": ..., "inputs": [...]}`` — each worker calls it
    once to construct its own process-local execution context, exactly like
    the fleet runner's ``build``. Backends that only serve portable
    ``("call", fn, args, kwargs)`` specs may pass ``build=None``.
    """

    name = "process"
    supports_specs = True
    # workers heartbeat from a side thread even mid-task, so a fresh
    # heartbeat PROVES the lease live: the Manager spares such leases from
    # age-based expiry (long buckets get backup clones, not revocations)
    heartbeats_prove_liveness = True

    def __init__(
        self,
        build: Optional[Callable[..., Dict[str, Any]]] = None,
        build_kwargs: Optional[Dict[str, Any]] = None,
        *,
        store_dir: Optional[str] = None,
        store_ram_bytes: int = 256 << 20,
        cache_bytes: Optional[int] = None,
        mp_context: str = "spawn",
        heartbeat_interval: float = 0.25,
    ) -> None:
        from repro.engine.types import DEFAULT_CACHE_BYTES

        self.build = build
        self.build_kwargs = dict(build_kwargs or {})
        self._owns_store_dir = store_dir is None
        if store_dir is None:
            import tempfile

            store_dir = tempfile.mkdtemp(prefix="rtf_rpc_")
        self.store_dir = store_dir
        self.store_ram_bytes = int(store_ram_bytes)
        self.cache_bytes = int(cache_bytes or DEFAULT_CACHE_BYTES)
        self.mp_context = mp_context
        self.heartbeat_interval = float(heartbeat_interval)
        self._handles: List[_WorkerHandle] = []
        self._studies: List[Dict[str, Any]] = []  # replayed on (re)start
        self._store = None  # leader-side mount, lazy
        self._lock = threading.Lock()
        # Session nonce scoping every result store key: minted per start(),
        # so a restarted backend (or another leader over one store_dir) can
        # never read a previous lifetime's result as its own.
        self._session = ""

    # -- leader-side store mount (result hydration) ---------------------
    @property
    def store(self):
        if self._store is None:
            from repro.runtime.storage import SharedStore

            self._store = SharedStore(
                self.store_ram_bytes, disk_dir=self.store_dir, writer_id="rpc-leader"
            )
        return self._store

    def worker_pids(self) -> List[Optional[int]]:
        """Spawned worker process ids (test/ops hook — e.g. fault injection
        by SIGKILL)."""
        return [h.proc.pid for h in self._handles]

    # -- WorkerBackend protocol -----------------------------------------
    def start(self, n_workers: int) -> None:
        if self._handles:
            raise RuntimeError("ProcessRpcBackend already started")
        import multiprocessing
        import uuid

        self._session = uuid.uuid4().hex[:12]
        mp = multiprocessing.get_context(self.mp_context)
        handles = []
        for wid in range(max(1, n_workers)):
            parent, child = mp.Pipe(duplex=True)
            proc = mp.Process(
                target=_rpc_worker_main,
                args=(
                    child, wid, self._session, self.build, self.build_kwargs,
                    self.store_dir, self.store_ram_bytes, self.cache_bytes,
                    self.heartbeat_interval,
                ),
                daemon=True,
            )
            proc.start()
            child.close()
            handles.append(_WorkerHandle(wid, proc, parent))
        self._handles = handles
        for study in self._studies:  # restart: re-install session context
            self._broadcast({"t": "study", **study})

    def install_study(self, **study: Any) -> None:
        """Broadcast a study context (plan recipe + key prefix + input keys)
        to every worker; pipes are ordered, so any lease sent afterwards
        finds the plan installed."""
        self._studies.append(dict(study))
        if len(self._studies) > 8:
            self._studies = self._studies[-8:]
        self._broadcast({"t": "study", **study})

    def _broadcast(self, msg: Dict[str, Any]) -> None:
        for h in self._handles:
            if not h.alive:
                continue
            try:
                _send_frame(h.conn, self._lock, msg)
            except (OSError, ValueError, BrokenPipeError):
                h.alive = False

    def offer(self, lease: Lease) -> bool:
        if lease.spec is None:
            raise TransportError(
                f"lease {lease.key!r} has no picklable spec: the process "
                "backend cannot ship closures across the boundary"
            )
        target = None
        for h in self._handles:
            if h.alive and h.proc.is_alive() and not h.inflight:
                target = h
                break
        if target is None:
            return False
        try:
            _send_frame(
                target.conn, self._lock,
                {"t": "lease", "key": lease.key, "attempt": lease.attempt,
                 "spec": lease.spec},
            )
        except (OSError, ValueError, BrokenPipeError):
            target.alive = False
            return False
        target.inflight[lease.lease_id] = lease
        return True

    def poll_completions(self, timeout: float) -> List[Completion]:
        import multiprocessing.connection as mpc

        live = [h for h in self._handles if h.alive]
        if not live:
            time.sleep(min(max(timeout, 0.0), 0.05))
            return []
        ready = mpc.wait([h.conn for h in live], timeout=max(0.0, timeout))
        by_conn = {h.conn: h for h in live}
        out: List[Completion] = []
        for conn in ready:
            h = by_conn[conn]
            try:
                while True:
                    msg = _recv_frame(conn)
                    h.last_seen = time.monotonic()
                    if msg.get("t") == "comp":
                        out.append(self._hydrate(h, msg))
                    elif msg.get("t") == "hello":
                        h.pid = msg.get("pid")
                    if not conn.poll():
                        break
            except (EOFError, OSError):
                h.alive = False
        return out

    def _hydrate(self, h: _WorkerHandle, msg: Dict[str, Any]) -> Completion:
        """Turn a wire completion into a Manager-facing one: resolve the
        result by its store key (the only representation that crossed the
        boundary) and re-wrap bucket results into the executor's
        ``(outputs, executed, hits)`` shape."""
        h.inflight.pop(f"{msg['key']}#{msg['attempt']}", None)
        if not msg.get("ok"):
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=False,
                error=msg.get("error") or "remote task failed",
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        if msg.get("none"):  # an explicit None result (never stored)
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=True, value=None,
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        value = self.store.get(msg["store_key"])
        if value is None:
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=False,
                error=f"result {msg['store_key']!r} missing from the store",
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        if msg.get("wrap") == "bucket":
            value = (value, int(msg["executed"]), int(msg["hits"]))
        return Completion(
            key=msg["key"], attempt=msg["attempt"], ok=True, value=value,
            store_key=msg["store_key"], worker_id=h.wid,
            duration=float(msg.get("duration", 0.0)),
        )

    def heartbeat_view(self) -> Dict[int, WorkerStatus]:
        view = {}
        for h in self._handles:
            alive = h.alive and h.proc.is_alive()
            if not alive:
                h.alive = False
            view[h.wid] = WorkerStatus(
                alive=alive, last_seen=h.last_seen, inflight=tuple(h.inflight)
            )
        return view

    def shutdown(self) -> None:
        for h in self._handles:
            if h.alive:
                try:
                    _send_frame(h.conn, self._lock, {"t": "stop"})
                except (OSError, ValueError, BrokenPipeError):
                    pass
        deadline = time.monotonic() + 5.0
        for h in self._handles:
            h.proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if h.proc.is_alive():
                h.proc.terminate()
                h.proc.join(timeout=2.0)
            try:
                h.conn.close()
            except OSError:
                pass
        self._handles = []
        self._purge_session_entries()

    def _purge_session_entries(self) -> None:
        """Best-effort removal of THIS session's ``rpc:<session>:…`` result
        entries from the store. They are transient transport payloads — the
        session nonce makes them unreachable to any future session, so on a
        caller-owned persistent ``store_dir`` (an adaptive study's reuse
        pool) they would otherwise accumulate as dead weight forever. The
        durable cross-round reuse pool (the workers' task-level cache keys)
        is untouched. Entries a kill orphans are leaked until the directory
        is retired — the manifest still records them for audit."""
        if not self._session:
            return
        prefix = f"rpc:{self._session}:"
        try:
            for key in self.store.committed_keys():
                if key.startswith(prefix):
                    self.store.delete(key)
        except OSError:  # pragma: no cover - purge is best-effort
            pass

    def cleanup(self) -> None:
        """Remove the backend's store directory IF this backend created it
        (default tempdir mode) and no workers are running. ``shutdown``
        deliberately leaves the store readable — callers often inspect
        committed results after a session retires — so owners of throwaway
        backends (the app-level ``backend="process"`` paths call this) must
        cleanup explicitly; a caller-supplied ``store_dir`` is never
        touched (it is the caller's reuse pool)."""
        if not self._owns_store_dir or self._handles:
            return
        import shutil

        self._store = None
        shutil.rmtree(self.store_dir, ignore_errors=True)

"""Raw-socket control plane: the WorkerBackend that leaves the host
(DESIGN.md §16).

The frame codec was transport-portable from day one — ``<8-byte LE length>
<pickle payload>`` (see ``runtime.transport``) — and this module is the
promised payoff: the SAME frames (``study``/``lease``/``lease_batch``/
``comp``/``comp_batch``/``hb``/``fetch``/``fetched``/``stop``) driven over
TCP instead of ``multiprocessing`` pipes, so every §14 fast path (batched
frames, warm plan caches, async commit + leader fetch) survives the hop
off-host unchanged. What sockets add over pipes is a *membership* problem,
solved by three new frame kinds that exist only at connection setup:

* ``register`` — a worker dials the leader and introduces itself:
  protocol version, requested worker id (None on first contact, its
  assigned id on reconnect), pid, and a capability map;
* ``welcome`` — the leader accepts: assigned worker id, session nonce,
  the §14 option flags, the store SPEC to mount (a plain directory for a
  shared filesystem, ``obj:<root>`` for the object tier — workers need no
  shared working directory beyond that store root), and the heartbeat
  interval. Everything a worker needs to serve leases rides this one
  frame, so remote hosts join a fleet knowing only an address;
* ``reject`` — a protocol-version mismatch is refused at the handshake,
  before any lease could cross a wire the two sides parse differently.

**Reconnect-with-backoff.** A worker that loses its TCP connection keeps
its execution context (workflow, store mount, plan caches, task cache) and
re-dials with exponential backoff, re-registering under the SAME worker
id. Its in-flight leases were abandoned with the connection: the leader
marks the id dead on the broken socket and keeps reporting the orphaned
lease ids through ``heartbeat_view`` (as a tombstone row once the id
re-registers), so the Manager's existing dead-worker expiry re-enqueues
them — the recovery path is byte-for-byte the SIGKILL path, which is the
point: a network partition and a dead host are indistinguishable to the
scheduler, and both already work.

**Worker entrypoint.** ``python -m repro.runtime.net worker --connect
HOST:PORT [--build module:callable]`` joins any listening leader from any
host (``examples/sa_worker.py`` wraps it with the pathology build). The
leader's default mode spawns its workers locally as processes that connect
back over loopback TCP — the same code path end to end, which is what the
conformance suite and ``benchmarks/net.py`` pin down.
"""

from __future__ import annotations

import os
import queue
import select
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.runtime.transport import (
    Completion,
    Lease,
    TransportError,
    WorkerStatus,
    _recv_frame,
    _RpcWorker,
    _send_frame,
    stop_processes,
)

__all__ = [
    "PROTOCOL_VERSION",
    "SocketBackend",
    "SocketConn",
    "parse_address",
    "run_worker",
    "socket_flag_kwargs",
]

PROTOCOL_VERSION = 1

_FRAME_HEADER = struct.Struct("<Q")
_MAX_FRAME = 1 << 32  # sanity bound: a torn/foreign header must not OOM us
_HANDSHAKE_TIMEOUT = 10.0


def parse_address(addr: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` (the only address syntax the
    control plane speaks; port 0 asks the OS for an ephemeral one)."""
    host, sep, port = addr.rpartition(":")
    if not sep or not host:
        raise ValueError(f"address must be 'host:port', got {addr!r}")
    return host, int(port)


class SocketConn:
    """A TCP socket behind the ``multiprocessing.Connection`` surface the
    frame codec already drives (``send_bytes``/``recv_bytes``/``poll``/
    ``close``) — which is what lets :class:`~repro.runtime.transport.
    _RpcWorker` serve leases over a socket UNCHANGED. ``recv_bytes``
    returns header+payload exactly as a pipe delivery would, so
    ``_recv_frame``'s torn-frame validation applies to both transports."""

    def __init__(self, sock: socket.socket) -> None:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)  # blocking; poll() provides the timeouts
        self._sock = sock

    def fileno(self) -> int:
        return self._sock.fileno()

    def _recv_exact(self, n: int) -> bytes:
        chunks: List[bytes] = []
        got = 0
        while got < n:
            chunk = self._sock.recv(n - got)
            if not chunk:
                raise EOFError("peer closed the connection")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def recv_bytes(self) -> bytes:
        header = self._recv_exact(_FRAME_HEADER.size)
        (length,) = _FRAME_HEADER.unpack(header)
        if length > _MAX_FRAME:
            raise TransportError(f"frame length {length} over the wire bound")
        return header + self._recv_exact(length)

    def send_bytes(self, frame: bytes) -> None:
        self._sock.sendall(frame)

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            ready, _, _ = select.select([self._sock], [], [], max(0.0, timeout))
        except (OSError, ValueError):
            raise EOFError("connection closed while polling")
        return bool(ready)

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Spec grammar: "socket[host:port,flags...]"
# ---------------------------------------------------------------------------

_SOCKET_FLAG_NAMES = {
    "batch": "batch_frames",
    "warm": "warm_plans",
    "async": "async_commit",
}
_SOCKET_TUNABLES = {
    "max_batch": int,
    "max_delay_ms": float,
    "register_timeout": float,
    "store": str,
}


def socket_flag_kwargs(spec: str) -> Dict[str, Any]:
    """Parse a ``"socket[...]"`` backend spec into :class:`SocketBackend`
    keyword arguments — the same grammar as ``process_flag_kwargs`` plus an
    address. The first bare ``host:port`` token is the bind address; flag
    tokens toggle the §14 mechanisms that survive sockets (``batch`` /
    ``warm`` / ``async``; ``shm`` is rejected — shared memory does not
    cross hosts); ``external`` switches off local worker spawning (workers
    join by dialing the address, ``start(n)`` blocks until n registered);
    ``key=value`` sets a tunable (``max_batch``, ``max_delay_ms``,
    ``register_timeout``, ``store=<spec>``). Examples::

        "socket"                          -> loopback, spawn local workers
        "socket[127.0.0.1:7077]"          -> bind a fixed port
        "socket[0.0.0.0:7077,external]"   -> listen for remote workers
        "socket[store=obj:/data/sa]"      -> fleet over the object tier
    """
    spec = spec.strip()
    if not spec.startswith("socket"):
        raise ValueError(f"not a socket backend spec: {spec!r}")
    rest = spec[len("socket"):]
    if not rest:
        return {}
    if not (rest.startswith("[") and rest.endswith("]")):
        raise ValueError(f"malformed socket backend spec: {spec!r}")
    kwargs: Dict[str, Any] = {}
    for token in rest[1:-1].split(","):
        token = token.strip()
        if not token:
            continue
        if "=" in token:
            k, v = (s.strip() for s in token.split("=", 1))
            if k not in _SOCKET_TUNABLES:
                raise ValueError(f"unknown socket backend tunable {k!r}")
            kwargs[k] = _SOCKET_TUNABLES[k](v)
            continue
        if ":" in token:
            kwargs["bind"] = token
            continue
        enable = not token.startswith("-")
        name = token.lstrip("+-")
        if name == "external":
            kwargs["spawn_workers"] = not enable
        elif name == "all" or name == "none":
            on = (name == "all") == enable
            for attr in _SOCKET_FLAG_NAMES.values():
                kwargs[attr] = on
        elif name in _SOCKET_FLAG_NAMES:
            kwargs[_SOCKET_FLAG_NAMES[name]] = enable
        elif name == "shm":
            raise ValueError(
                "shm is not a socket backend flag: shared-memory handoff "
                "does not cross hosts"
            )
        else:
            raise ValueError(f"unknown socket backend flag {name!r}")
    return kwargs


# ---------------------------------------------------------------------------
# Worker side: dial, register, serve, reconnect
# ---------------------------------------------------------------------------


def _backoff_delays(base: float, cap: float):
    delay = base
    while True:
        yield delay
        delay = min(cap, delay * 2)


def run_worker(
    address: str,
    *,
    build: Optional[Callable[..., Dict[str, Any]]] = None,
    build_kwargs: Optional[Dict[str, Any]] = None,
    worker_id: Optional[int] = None,
    store: Optional[str] = None,
    store_ram_bytes: int = 256 << 20,
    cache_bytes: Optional[int] = None,
    max_dial_failures: int = 30,
    backoff: float = 0.2,
    backoff_max: float = 5.0,
) -> int:
    """One socket worker's whole life: dial the leader, register (under
    ``worker_id`` when reconnecting), build the execution context ONCE,
    then serve lease frames until a clean ``stop``. A lost connection
    triggers reconnect-with-backoff under the same assigned id — the
    context (workflow, store mount, plan caches, task cache) survives the
    reconnect; only the in-flight leases are abandoned, and those the
    leader re-enqueues through the heartbeat path. Returns the worker id
    it served under (useful to callers persisting identity across runs).

    ``store`` overrides the welcome frame's store spec (operators mounting
    the object root at a host-specific path); by default the worker mounts
    exactly what the leader names.
    """
    from repro.engine.types import DEFAULT_CACHE_BYTES

    # frame-consumer: welcome,reject via reply
    host, port = parse_address(address)
    wid = worker_id
    ctx: Optional[_RpcWorker] = None
    delays = _backoff_delays(backoff, backoff_max)
    dial_failures = 0
    while True:
        try:
            sock = socket.create_connection((host, port), timeout=_HANDSHAKE_TIMEOUT)
        except OSError:
            dial_failures += 1
            if dial_failures >= max_dial_failures:
                raise TransportError(
                    f"leader at {address} unreachable after "
                    f"{dial_failures} attempts"
                )
            time.sleep(next(delays))
            continue
        conn = SocketConn(sock)
        lock = threading.Lock()
        try:
            _send_frame(conn, lock, {
                "t": "register",
                "proto": PROTOCOL_VERSION,
                "wid": wid,
                "pid": os.getpid(),
                "caps": {"specs": True, "batch": True, "reconnect": True},
            })
            if not conn.poll(_HANDSHAKE_TIMEOUT):
                raise EOFError("handshake timed out")
            reply = _recv_frame(conn)
        except (EOFError, OSError):
            conn.close()
            time.sleep(next(delays))
            continue
        if reply.get("t") == "reject":
            conn.close()
            raise TransportError(
                f"leader rejected registration: {reply.get('reason')!r}"
            )
        if reply.get("t") != "welcome":
            conn.close()
            time.sleep(next(delays))
            continue
        wid = int(reply["wid"])
        worker = _RpcWorker(
            conn,
            wid,
            reply["session"],
            build if ctx is None else None,  # build exactly once
            build_kwargs,
            store or reply["store"],
            int(reply.get("store_ram_bytes", store_ram_bytes)),
            int(reply.get("cache_bytes", cache_bytes or DEFAULT_CACHE_BYTES)),
            float(reply.get("hb", 0.25)),
            reply.get("options"),
        )
        if ctx is not None:
            # reconnect: transplant the built context — workflow, inputs,
            # store mount (its RAM tier still holds upstream results),
            # task cache, plan caches, counters — into the new connection's
            # serving loop; only the wire is new
            worker.workflow = ctx.workflow
            worker.inputs = ctx.inputs
            worker.store = ctx.store
            worker.cache = ctx.cache
            worker.ctx_error = ctx.ctx_error
            worker._plan_meta = ctx._plan_meta
            worker._plan_cache = ctx._plan_cache
            worker.counters = ctx.counters
            worker.counters["reconnects"] = worker.counters.get("reconnects", 0) + 1
        ctx = worker
        delays = _backoff_delays(backoff, backoff_max)  # connected: reset
        dial_failures = 0
        worker.serve()  # until stop frame or connection loss
        if worker._stop:
            return wid  # clean retirement
        time.sleep(next(delays))


def _socket_worker_main(
    address: str,
    build: Optional[Callable[..., Dict[str, Any]]],
    build_kwargs: Optional[Dict[str, Any]],
    store_ram_bytes: int,
    cache_bytes: Optional[int],
) -> None:
    """Spawn entrypoint for the leader's local (loopback-TCP) workers."""
    try:
        run_worker(
            address,
            build=build,
            build_kwargs=build_kwargs,
            store_ram_bytes=store_ram_bytes,
            cache_bytes=cache_bytes,
        )
    except TransportError:
        pass  # leader gone / rejected: the process just retires


# ---------------------------------------------------------------------------
# Leader side: SocketBackend
# ---------------------------------------------------------------------------


class _SocketHandle:
    __slots__ = (
        "wid", "conn", "send_lock", "alive", "last_seen", "inflight",
        "pid", "caps", "generation", "proc",
    )

    def __init__(self, wid: int) -> None:
        self.wid = wid
        self.conn: Optional[SocketConn] = None
        self.send_lock = threading.Lock()
        self.alive = False
        self.last_seen = time.monotonic()
        self.inflight: Dict[str, Lease] = {}
        self.pid: Optional[int] = None
        self.caps: Dict[str, Any] = {}
        self.generation = 0
        self.proc = None  # spawn mode only; remote workers have no proc


class SocketBackend:
    """Spec-capable :class:`WorkerBackend` over a TCP control plane — the
    multi-host counterpart of :class:`ProcessRpcBackend` (same frames, same
    store-key result discipline, same §14 fast paths minus shared memory,
    which cannot cross hosts).

    The leader listens on ``bind`` (``host:port``; port 0 → ephemeral, the
    bound address is ``self.address``). Two membership modes:

    * **spawn mode** (default): ``start(n)`` launches n local worker
      processes that connect back over loopback TCP — same wire end to
      end, zero deployment ceremony; the conformance suite runs here;
    * **external mode** (``spawn_workers=False``, spec flag ``external``):
      ``start(n)`` only listens, blocking until n remote workers have
      dialed in (``python -m repro.runtime.net worker --connect ...``).
      Workers may keep joining after start — a late registration is
      welcomed, receives every installed study, and starts taking leases.

    Worker ids are leader-assigned at registration and sticky: a
    reconnecting worker presents its id and resumes under it. The broken
    connection's in-flight leases are surfaced to the Manager as a DEAD
    tombstone row in ``heartbeat_view`` until their re-enqueue is observed
    — never attributed to the live, reconnected row, so the prove-liveness
    heartbeat policy can't accidentally shelter abandoned work.
    """

    name = "socket"
    supports_specs = True
    heartbeats_prove_liveness = True

    def __init__(
        self,
        bind: str = "127.0.0.1:0",
        *,
        build: Optional[Callable[..., Dict[str, Any]]] = None,
        build_kwargs: Optional[Dict[str, Any]] = None,
        store: Optional[str] = None,
        store_ram_bytes: int = 256 << 20,
        cache_bytes: Optional[int] = None,
        spawn_workers: bool = True,
        mp_context: str = "spawn",
        heartbeat_interval: float = 0.25,
        batch_frames: bool = True,
        warm_plans: bool = True,
        async_commit: bool = True,
        max_batch: int = 16,
        max_delay_ms: float = 2.0,
        register_timeout: float = 60.0,
        shutdown_grace: float = 5.0,
    ) -> None:
        from repro.engine.types import DEFAULT_CACHE_BYTES

        self.bind = bind
        self.build = build
        self.build_kwargs = dict(build_kwargs or {})
        self._owns_store_dir = store is None
        if store is None:
            import tempfile

            store = tempfile.mkdtemp(prefix="rtf_sock_")
        self.store_spec = store
        self.store_ram_bytes = int(store_ram_bytes)
        self.cache_bytes = int(cache_bytes or DEFAULT_CACHE_BYTES)
        self.spawn_workers = bool(spawn_workers)
        self.mp_context = mp_context
        self.heartbeat_interval = float(heartbeat_interval)
        self.batch_frames = bool(batch_frames)
        self.warm_plans = bool(warm_plans)
        self.async_commit = bool(async_commit)
        self.max_batch = max(1, int(max_batch))
        self.max_delay_ms = float(max_delay_ms)
        self.register_timeout = float(register_timeout)
        self.shutdown_grace = float(shutdown_grace)
        self.address: Optional[str] = None  # bound host:port after start()
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        # _handles/_tombstones and every per-handle inflight map are guarded
        # by _lock: reader threads (handshake, death/tombstoning) and the
        # pump (offers, hydration) race over them.
        self._handles: Dict[int, _SocketHandle] = {}  # guard: _lock
        self._tombstones: "Dict[int, Tuple[float, Tuple[str, ...]]]" = {}  # guard: _lock
        self._next_wid = 0  # guard: _lock
        self._next_tomb = -1  # guard: _lock
        self._studies: List[Dict[str, Any]] = []  # guard: _lock
        self._store = None
        self._flusher = None
        self._rx: "queue.Queue[Tuple[_SocketHandle, Dict[str, Any]]]" = queue.Queue()
        self._lock = threading.Lock()
        self._registered = threading.Condition(self._lock)
        self._closing = False  # guard: _lock
        self._session = ""
        self._procs: List[Any] = []
        self._worker_stats: Dict[int, Dict[str, Any]] = {}  # guard: _lock
        self._counters: Dict[str, int] = {  # guard: _lock
            "lease_frames": 0,
            "lease_batches": 0,
            "comp_batches": 0,
            "fetch_serves": 0,
            "registrations": 0,
            "reconnects": 0,
            "rejects": 0,
            "disconnects": 0,
        }

    # -- leader-side store mount ----------------------------------------
    @property
    def store(self):
        if self._store is None:
            from repro.runtime.storage import mount_store

            self._store = mount_store(
                self.store_spec, self.store_ram_bytes, writer_id="sock-leader"
            )
        return self._store

    @property
    def slots_per_worker(self) -> int:
        return self.max_batch if self.batch_frames else 1

    def worker_pids(self) -> List[Optional[int]]:
        with self._lock:
            return [h.pid for h in self._handles.values()]

    def _options(self) -> Dict[str, Any]:
        return {
            "batch": self.batch_frames,
            "warm": self.warm_plans,
            "shm": False,  # shared memory does not cross hosts
            "async": self.async_commit,
            "max_batch": self.max_batch,
            "max_delay_ms": self.max_delay_ms,
        }

    # -- WorkerBackend protocol -----------------------------------------
    def start(self, n_workers: int) -> None:
        if self._listener is not None:
            raise RuntimeError("SocketBackend already started")
        import uuid

        n = max(1, n_workers)
        self._session = uuid.uuid4().hex[:12]
        # init-phase reset: the accept thread (and so every reader) starts
        # a few lines below; no concurrent access is possible yet
        self._closing = False  # analysis: ok[locks] init phase
        self._worker_stats = {}  # analysis: ok[locks] init phase
        self._handles = {}  # analysis: ok[locks] init phase
        self._tombstones = {}  # analysis: ok[locks] init phase
        self._next_wid = 0  # analysis: ok[locks] init phase
        self._rx = queue.Queue()
        if self.async_commit:
            from repro.runtime.storage import AsyncCommitQueue

            self._flusher = AsyncCommitQueue(self.store)
        host, port = parse_address(self.bind)
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((host, port))
        listener.listen(128)
        self._listener = listener
        self.address = f"{host}:{listener.getsockname()[1]}"
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="rtf-sock-accept", daemon=True
        )
        self._accept_thread.start()
        if self.spawn_workers:
            import multiprocessing

            mp = multiprocessing.get_context(self.mp_context)
            self._procs = []
            for _ in range(n):
                proc = mp.Process(
                    target=_socket_worker_main,
                    args=(
                        self.address, self.build, self.build_kwargs,
                        self.store_ram_bytes, self.cache_bytes,
                    ),
                    daemon=True,
                )
                proc.start()
                self._procs.append(proc)
        else:
            self._procs = []
        deadline = time.monotonic() + self.register_timeout
        with self._registered:
            while len(self._handles) < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TransportError(
                        f"only {len(self._handles)}/{n} workers registered "
                        f"within {self.register_timeout:.0f}s at {self.address}"
                    )
                self._registered.wait(min(left, 0.2))
        if self.spawn_workers:
            # attribute spawned procs to their registered handles (by pid)
            # so shutdown can escalate on exactly the right process
            with self._lock:
                by_pid = {p.pid: p for p in self._procs}
                for h in self._handles.values():
                    h.proc = by_pid.get(h.pid)

    # -- accept / handshake ----------------------------------------------
    def _accept_loop(self) -> None:
        # analysis: ok[locks] lock-free poll of the shutdown flag: a stale
        # read costs one extra accept() round, and closing the listener
        # unblocks accept() with OSError anyway
        while not self._closing:
            try:
                sock, _peer = self._listener.accept()
            except OSError:
                return  # listener closed: shutdown
            threading.Thread(
                target=self._handshake, args=(sock,), daemon=True
            ).start()

    def _handshake(self, sock: socket.socket) -> None:
        # frame-consumer: register via msg
        conn = SocketConn(sock)
        try:
            if not conn.poll(_HANDSHAKE_TIMEOUT):
                conn.close()
                return
            msg = _recv_frame(conn)
        except (EOFError, OSError, TransportError):
            conn.close()
            return
        if msg.get("t") != "register":
            conn.close()
            return
        if msg.get("proto") != PROTOCOL_VERSION:
            # version skew is refused BEFORE any lease can cross a wire the
            # two sides would parse differently
            with self._lock:
                self._counters["rejects"] += 1
            try:
                _send_frame(conn, threading.Lock(), {
                    "t": "reject",
                    "proto": PROTOCOL_VERSION,
                    "reason": (
                        f"protocol version mismatch: leader speaks "
                        f"{PROTOCOL_VERSION}, worker sent {msg.get('proto')!r}"
                    ),
                })
            except (OSError, ValueError, BrokenPipeError):
                pass
            conn.close()
            return
        requested = msg.get("wid")
        with self._registered:
            if self._closing:
                conn.close()
                return
            if isinstance(requested, int) and requested in self._handles:
                h = self._handles[requested]  # reconnect under the same id
                if h.conn is not None:
                    try:
                        h.conn.close()
                    except OSError:
                        pass
                self._tombstone_locked(h)
                self._counters["reconnects"] += 1
            else:
                h = _SocketHandle(self._next_wid)
                self._next_wid += 1
                self._handles[h.wid] = h
            h.conn = conn
            h.alive = True
            h.generation += 1
            h.last_seen = time.monotonic()
            h.pid = msg.get("pid")
            h.caps = dict(msg.get("caps") or {})
            generation = h.generation
            self._counters["registrations"] += 1
            studies = list(self._studies)
            self._registered.notify_all()
        try:
            _send_frame(conn, h.send_lock, {
                "t": "welcome",
                "proto": PROTOCOL_VERSION,
                "wid": h.wid,
                "session": self._session,
                "store": self.store_spec,
                "store_ram_bytes": self.store_ram_bytes,
                "cache_bytes": self.cache_bytes,
                "hb": self.heartbeat_interval,
                "options": self._options(),
            })
            # replay installed studies so a late joiner / reconnector can
            # serve any lease the Manager re-drives at it
            for study in studies:
                _send_frame(conn, h.send_lock, {"t": "study", **study})
        except (OSError, ValueError, BrokenPipeError):
            self._mark_dead(h, generation)
            return
        threading.Thread(
            target=self._reader_loop, args=(h, conn, generation),
            name=f"rtf-sock-r{h.wid}", daemon=True,
        ).start()

    def _tombstone_locked(self, h: _SocketHandle) -> None:
        """Park a broken connection's in-flight lease ids on a synthetic
        dead worker row (caller holds the lock). ``heartbeat_view`` reports
        tombstones as dead workers holding those leases, which is exactly
        the shape the Manager's dead-worker expiry already consumes — and
        because the row is never the reconnected (live) id, fresh
        heartbeats can't shelter the abandoned leases from re-enqueue."""
        if h.inflight:
            self._tombstones[self._next_tomb] = (
                time.monotonic(), tuple(h.inflight)
            )
            self._next_tomb -= 1
            h.inflight = {}
        while len(self._tombstones) > 64:  # drop the oldest; long observed
            oldest = min(self._tombstones, key=lambda k: self._tombstones[k][0])
            del self._tombstones[oldest]

    def _mark_dead(self, h: _SocketHandle, generation: int) -> None:
        with self._lock:
            if h.generation != generation:
                return  # a reconnect already superseded this connection
            if h.alive:
                h.alive = False
                self._counters["disconnects"] += 1
            self._tombstone_locked(h)
        if h.conn is not None:
            try:
                h.conn.close()
            except OSError:
                pass

    # -- per-connection reader -------------------------------------------
    def _reader_loop(self, h: _SocketHandle, conn: SocketConn, generation: int) -> None:
        try:
            while True:
                msg = _recv_frame(conn)
                h.last_seen = time.monotonic()
                kind = msg.get("t")
                if kind == "hb":
                    stats = msg.get("stats")
                    if stats:
                        with self._lock:
                            self._worker_stats[h.wid] = stats
                elif kind == "fetch":
                    self._serve_fetch(h, msg["key"])
                elif kind == "hello":
                    h.pid = msg.get("pid")
                else:
                    self._rx.put((h, msg))
        except (EOFError, OSError, TransportError):
            self._mark_dead(h, generation)

    def _serve_fetch(self, h: _SocketHandle, key: str) -> None:
        value = self._flusher.peek(key) if self._flusher is not None else None
        if value is None:
            value = self.store.get(key)
        with self._lock:
            self._counters["fetch_serves"] += 1
        try:
            _send_frame(h.conn, h.send_lock, {
                "t": "fetched", "key": key, "found": value is not None,
                "value": value,
            })
        except (OSError, ValueError, BrokenPipeError):
            pass  # the reader thread will observe the death

    # -- study broadcast --------------------------------------------------
    def install_study(self, **study: Any) -> None:
        with self._lock:
            self._studies.append(dict(study))
            if len(self._studies) > 8:
                self._studies = self._studies[-8:]
            targets = [h for h in self._handles.values() if h.alive]
        msg = {"t": "study", **study}
        for h in targets:
            try:
                _send_frame(h.conn, h.send_lock, msg)
            except (OSError, ValueError, BrokenPipeError):
                pass  # reader marks it dead; reconnect replays the study

    # -- dispatch ----------------------------------------------------------
    def offer(self, lease: Lease) -> bool:
        return not self.offer_batch([lease])

    def offer_batch(self, leases: List[Lease], worker_ids=None) -> List[Lease]:
        for lease in leases:
            if lease.spec is None:
                raise TransportError(
                    f"lease {lease.key!r} has no picklable spec: the socket "
                    "backend cannot ship closures across hosts"
                )
        slots = self.slots_per_worker
        # capacity math runs under the lock (reader threads tombstone and
        # reset inflight maps concurrently); the sends must NOT — they are
        # socket I/O serialized only by each handle's send_lock
        with self._lock:
            ws = [
                h for h in self._handles.values()
                if h.alive and len(h.inflight) < slots
                and (worker_ids is None or h.wid in worker_ids)
            ]
            ws.sort(key=lambda h: len(h.inflight))
            caps = {h.wid: slots - len(h.inflight) for h in ws}
        if not ws:
            return list(leases)
        assigned: Dict[int, List[Lease]] = {h.wid: [] for h in ws}
        rejected: List[Lease] = []
        i = 0
        for lease in leases:
            for _ in range(len(ws)):
                h = ws[i % len(ws)]
                i += 1
                if caps[h.wid] > 0:
                    assigned[h.wid].append(lease)
                    caps[h.wid] -= 1
                    break
            else:
                rejected.append(lease)
        for h in ws:
            batch = assigned[h.wid]
            if not batch:
                continue
            frames = 1 if (self.batch_frames and len(batch) > 1) else len(batch)
            try:
                if self.batch_frames and len(batch) > 1:
                    _send_frame(
                        h.conn, h.send_lock,
                        {"t": "lease_batch",
                         "leases": [
                             {"key": l.key, "attempt": l.attempt, "spec": l.spec}
                             for l in batch
                         ]},
                    )
                else:
                    for l in batch:
                        _send_frame(
                            h.conn, h.send_lock,
                            {"t": "lease", "key": l.key, "attempt": l.attempt,
                             "spec": l.spec},
                        )
            except (OSError, ValueError, BrokenPipeError):
                rejected.extend(batch)
                continue
            with self._lock:
                self._counters["lease_frames"] += frames
                if self.batch_frames and len(batch) > 1:
                    self._counters["lease_batches"] += 1
                if not h.alive:
                    # the worker died mid-send: its reader thread already
                    # tombstoned (and may have reset) h.inflight — recording
                    # these leases now would strand them invisibly, outside
                    # both the tombstone row and the live handle's view
                    rejected.extend(batch)
                    continue
                for l in batch:
                    h.inflight[l.lease_id] = l
        return rejected

    def offer_to(self, lease: Lease, worker_id: int) -> bool:
        return not self.offer_batch([lease], worker_ids={worker_id})

    # -- completion intake -------------------------------------------------
    def poll_completions(self, timeout: float) -> List[Completion]:
        out: List[Completion] = []
        try:
            h, msg = self._rx.get(timeout=max(0.0, timeout))
        except queue.Empty:
            return out
        while True:
            kind = msg.get("t")
            if kind == "comp":
                out.append(self._hydrate(h, msg))
            elif kind == "comp_batch":
                with self._lock:
                    self._counters["comp_batches"] += 1
                for m in msg["comps"]:
                    out.append(self._hydrate(h, m))
            try:
                h, msg = self._rx.get_nowait()
            except queue.Empty:
                return out

    def _hydrate(self, h: _SocketHandle, msg: Dict[str, Any]) -> Completion:
        """Wire completion → Manager completion: identical to the process
        backend's hydration minus the shared-memory route (results cross
        hosts as store keys, inline staged values, or explicit None)."""
        with self._lock:
            h.inflight.pop(f"{msg['key']}#{msg['attempt']}", None)
        if not msg.get("ok"):
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=False,
                error=msg.get("error") or "remote task failed",
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        if msg.get("none"):
            return Completion(
                key=msg["key"], attempt=msg["attempt"], ok=True, value=None,
                worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
            )
        store_key = msg.get("store_key")
        if msg.get("inline"):
            value = msg["value"]
        else:
            value = self.store.get(store_key)
            if value is None and self._flusher is not None:
                value = self._flusher.peek(store_key)
            if value is None:
                return Completion(
                    key=msg["key"], attempt=msg["attempt"], ok=False,
                    error=f"result {store_key!r} missing from the store",
                    worker_id=h.wid, duration=float(msg.get("duration", 0.0)),
                )
        if self._flusher is not None and not msg.get("committed"):
            self._flusher.stage(store_key, value)
        if msg.get("wrap") == "bucket":
            value = (value, int(msg["executed"]), int(msg["hits"]))
        return Completion(
            key=msg["key"], attempt=msg["attempt"], ok=True, value=value,
            store_key=store_key, worker_id=h.wid,
            duration=float(msg.get("duration", 0.0)),
        )

    # -- liveness ----------------------------------------------------------
    def heartbeat_view(self) -> Dict[int, WorkerStatus]:
        view: Dict[int, WorkerStatus] = {}
        with self._lock:
            for h in self._handles.values():
                view[h.wid] = WorkerStatus(
                    alive=h.alive, last_seen=h.last_seen,
                    inflight=tuple(h.inflight),
                )
            for tid, (t_dead, leases) in self._tombstones.items():
                view[tid] = WorkerStatus(
                    alive=False, last_seen=t_dead, inflight=leases
                )
        return view

    def barrier(self, timeout: Optional[float] = None) -> bool:
        if self._flusher is None:
            return True
        return self._flusher.barrier(timeout)

    def stats(self) -> Dict[str, Any]:
        from repro.runtime.transport import _merge_int_tree

        with self._lock:
            per_worker = [dict(s) for s in self._worker_stats.values()]
            n_workers = len(self._handles)
            leader = dict(self._counters)
        worker_agg: Dict[str, Any] = {}
        for stats in per_worker:
            _merge_int_tree(worker_agg, stats)
        out: Dict[str, Any] = {
            "backend": self.name,
            "address": self.address,
            "workers": n_workers,
            "flags": {
                "batch_frames": self.batch_frames,
                "warm_plans": self.warm_plans,
                "async_commit": self.async_commit,
            },
            "leader": leader,
            "worker": worker_agg,
        }
        if self._flusher is not None:
            out["flusher"] = {
                "staged": self._flusher.staged,
                "committed": self._flusher.committed,
                "errors": self._flusher.errors,
                "staged_peak": self._flusher.staged_peak,
                "pending": self._flusher.pending(),
            }
        return out

    # -- fault-injection / ops hooks ---------------------------------------
    def disconnect(self, worker_id: int) -> bool:
        """Force-close a worker's connection WITHOUT stopping its process —
        a modelled network partition (test/ops hook). The worker observes
        EOF and re-dials with backoff under its id; its in-flight leases
        ride a tombstone row into the Manager's re-enqueue path."""
        with self._lock:
            h = self._handles.get(worker_id)
            if h is None or h.conn is None:
                return False
            conn, generation = h.conn, h.generation
        conn.close()  # the reader thread unblocks and marks it dead
        self._mark_dead(h, generation)
        return True

    # -- teardown ----------------------------------------------------------
    def shutdown(self) -> None:
        """Retire the fleet: bounded staging flush, ``stop`` frames to
        every live worker, close the listener (no new registrations), stop
        spawned local processes with the bounded terminate→kill escalation,
        then purge this session's transient store entries. Remote workers
        that miss the stop frame observe the closed socket and — finding
        the leader gone for good — exhaust their dial retries and retire."""
        if self._flusher is not None:
            try:
                self._flusher.close(flush=True, timeout=self.shutdown_grace * 2)
            except BaseException:  # noqa: BLE001
                pass
            self._flusher = None
        with self._lock:
            self._closing = True
            handles = list(self._handles.values())
        for h in handles:
            if h.alive and h.conn is not None:
                try:
                    _send_frame(h.conn, h.send_lock, {"t": "stop"})
                except (OSError, ValueError, BrokenPipeError):
                    pass
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        stop_processes(self._procs, grace=self.shutdown_grace)
        self._procs = []
        for h in handles:
            if h.conn is not None:
                try:
                    h.conn.close()
                except OSError:
                    pass
        with self._lock:
            self._handles = {}
            self._tombstones = {}
        self.address = None
        self._purge_session_entries()

    def _purge_session_entries(self) -> None:
        if not self._session:
            return
        prefix = f"rpc:{self._session}:"
        try:
            for key in self.store.committed_keys():
                if key.startswith(prefix):
                    self.store.delete(key)
        except OSError:  # pragma: no cover - purge is best-effort
            pass

    def cleanup(self) -> None:
        """Drop the backend-owned throwaway store (tempdir mode only; a
        caller-named store spec is the caller's reuse pool)."""
        with self._lock:
            has_handles = bool(self._handles)
        if not self._owns_store_dir or has_handles:
            return
        import shutil

        self._store = None
        shutil.rmtree(self.store_spec, ignore_errors=True)


# ---------------------------------------------------------------------------
# CLI: `python -m repro.runtime.net worker --connect HOST:PORT`
# ---------------------------------------------------------------------------


def _resolve_build(spec: Optional[str]) -> Optional[Callable[..., Dict[str, Any]]]:
    """``"module:callable"`` → the callable (the worker's execution-context
    factory; must be importable on the worker host)."""
    if spec is None:
        return None
    mod_name, sep, attr = spec.partition(":")
    if not sep or not attr:
        raise ValueError(f"--build must be 'module:callable', got {spec!r}")
    import importlib

    obj = importlib.import_module(mod_name)
    for part in attr.split("."):
        obj = getattr(obj, part)
    if not callable(obj):
        raise ValueError(f"{spec!r} does not name a callable")
    return obj


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="repro.runtime.net",
        description="Socket-fleet tools (DESIGN.md §16)",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    w = sub.add_parser("worker", help="join a listening leader by address")
    w.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="the leader's control-plane address")
    w.add_argument("--build", default=None, metavar="MODULE:CALLABLE",
                   help="execution-context factory (importable here); "
                        "omit for fleets serving only portable call specs")
    w.add_argument("--kwargs", default=None, metavar="JSON",
                   help="JSON object of keyword arguments for --build")
    w.add_argument("--id", type=int, default=None,
                   help="re-register under a previously assigned worker id")
    w.add_argument("--store", default=None,
                   help="override the welcome frame's store spec (plain "
                        "directory or obj:<root>) for host-specific mounts")
    w.add_argument("--ram-bytes", type=int, default=256 << 20)
    w.add_argument("--cache-bytes", type=int, default=None)
    args = ap.parse_args(argv)
    if args.cmd == "worker":
        build_kwargs = json.loads(args.kwargs) if args.kwargs else None
        try:
            wid = run_worker(
                args.connect,
                build=_resolve_build(args.build),
                build_kwargs=build_kwargs,
                worker_id=args.id,
                store=args.store,
                store_ram_bytes=args.ram_bytes,
                cache_bytes=args.cache_bytes,
            )
        except TransportError as e:
            print(f"worker retired: {e}")
            return 1
        except KeyboardInterrupt:
            return 130
        print(f"worker {wid} retired cleanly")
        return 0
    return 2  # pragma: no cover - argparse enforces the subcommand


if __name__ == "__main__":
    raise SystemExit(main())

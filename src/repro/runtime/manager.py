"""Manager-Worker demand-driven runtime (paper §II: RTF execution model),
with the fault-tolerance features a 1000-node deployment needs:

* demand-driven dispatch — Workers pull the next bucket when free (natural
  load balancing, same as the paper's 92%-efficiency runs);
* heartbeats + retry — a bucket whose Worker misses its heartbeat deadline
  is re-enqueued (at-least-once; results are idempotent because tasks are
  pure functions of (input, params));
* straggler mitigation — when the queue is empty and a bucket has been
  running longer than ``straggler_factor`` × the median bucket time, a
  backup copy is launched on an idle Worker; first completion wins (the
  classic demand-driven tail-cloning trick);
* elastic scaling — Workers can join/leave between buckets; the Manager
  only tracks outstanding leases.

Workers here are threads driving real JAX execution (the container is one
node); across real nodes the same Manager logic fronts an RPC boundary —
the scheduling semantics are identical, which is what the fig8 benchmark
models at 256 nodes.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["WorkItem", "Manager", "run_study_distributed"]


@dataclasses.dataclass
class WorkItem:
    key: str
    fn: Callable[[], Any]
    attempts: int = 0
    started_at: Optional[float] = None
    worker: Optional[int] = None


class Manager:
    def __init__(
        self,
        *,
        max_attempts: int = 3,
        heartbeat_timeout: float = 60.0,
        straggler_factor: float = 3.0,
        enable_backup_tasks: bool = True,
    ):
        self._queue: "queue.Queue[WorkItem]" = queue.Queue()
        self._results: Dict[str, Any] = {}
        self._running: Dict[str, WorkItem] = {}
        self._attempt_seq: Dict[str, int] = {}  # highest attempt # issued per key
        self._durations: List[float] = []
        self._lock = threading.Lock()
        self.max_attempts = max_attempts
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.enable_backup_tasks = enable_backup_tasks
        self.retries = 0
        self.backups_launched = 0

    def submit(self, item: WorkItem) -> None:
        self._queue.put(item)

    # ------------------------------------------------------------------
    def _next(self, worker_id: int) -> Optional[WorkItem]:
        # Dequeue and lease registration are atomic under one lock: a peer
        # observing (queue empty, no leases) under that lock can therefore
        # conclude the system is idle — there is no window where an item has
        # left the queue but is not yet visible in ``_running``. Items whose
        # key already has a result (a raced retry/backup) are dropped here,
        # before any lease exists, so they can never leak one.
        with self._lock:
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    item = self._maybe_backup_locked()
                    if item is None:
                        return None
                    break
                if item.key not in self._results:
                    break
            item.started_at = time.monotonic()
            item.worker = worker_id
            # attempt numbers are issued centrally so concurrent attempts of
            # one key (original + backup) always hold distinct leases
            item.attempts = self._attempt_seq.get(item.key, 0) + 1
            self._attempt_seq[item.key] = item.attempts
            self._running[f"{item.key}#{item.attempts}"] = item
        return item

    def _maybe_backup_locked(self) -> Optional[WorkItem]:
        """Clone the longest-running bucket if it looks like a straggler.
        Caller holds ``self._lock``. At most one backup of a key is in
        flight at a time: while original + clone both run, the key holds two
        leases and is skipped."""
        if not self.enable_backup_tasks:
            return None
        if not self._running or len(self._durations) < 2:
            return None
        median = sorted(self._durations)[len(self._durations) // 2]
        now = time.monotonic()
        candidates = [
            it
            for it in self._running.values()
            if it.key not in self._results
            and sum(1 for other in self._running.values() if other.key == it.key) < 2
            and self._attempt_seq.get(it.key, 0) < self.max_attempts
        ]
        if not candidates:
            return None
        worst = max(candidates, key=lambda it: now - (it.started_at or now))
        age = now - (worst.started_at or now)
        if age > self.straggler_factor * max(median, 1e-3):
            self.backups_launched += 1
            return WorkItem(key=worst.key, fn=worst.fn)
        return None

    def _complete(self, item: WorkItem, result: Any) -> None:
        with self._lock:
            self._running.pop(f"{item.key}#{item.attempts}", None)
            if item.key not in self._results:  # first completion wins
                self._results[item.key] = result
                if item.started_at is not None:
                    self._durations.append(time.monotonic() - item.started_at)

    def _fail(self, item: WorkItem, err: Exception) -> None:
        # Lease release and re-enqueue happen under one lock so peers never
        # observe (queue empty, no leases) while a retry is still in flight.
        with self._lock:
            self._running.pop(f"{item.key}#{item.attempts}", None)
            if item.attempts < self.max_attempts:
                self.retries += 1
                # attempt numbers are issued by _next at lease time
                self._queue.put(WorkItem(key=item.key, fn=item.fn))
            else:
                self._results[item.key] = err

    # ------------------------------------------------------------------
    def run(self, n_workers: int, *, expected: int) -> Dict[str, Any]:
        """Run until ``expected`` distinct results exist."""

        def worker(worker_id: int) -> None:
            while True:
                with self._lock:
                    if len(self._results) >= expected:
                        return
                item = self._next(worker_id)
                if item is None:
                    # Re-check emptiness and leases under ONE lock
                    # acquisition: because _next/_fail keep dequeue and
                    # lease state atomic, (empty queue, no leases) here
                    # proves no work exists or can reappear.
                    with self._lock:
                        done = len(self._results) >= expected
                        idle = self._queue.empty() and not self._running
                    if done or idle:
                        return
                    time.sleep(0.005)
                    continue
                if item.key in self._results:
                    with self._lock:  # bucket completed after we leased: release
                        self._running.pop(f"{item.key}#{item.attempts}", None)
                    continue
                try:
                    self._complete(item, item.fn())
                except Exception as e:  # noqa: BLE001 — retry path
                    self._fail(item, e)

        threads = [
            threading.Thread(target=worker, args=(i,), daemon=True)
            for i in range(n_workers)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return dict(self._results)


def run_study_distributed(
    buckets: List[Any],
    execute_bucket: Callable[[Any], Dict[int, Any]],
    *,
    n_workers: int = 2,
    manager: Optional[Manager] = None,
) -> Dict[int, Any]:
    """Execute merged-stage buckets across Workers; returns run_id -> output."""
    mgr = manager or Manager()
    for i, b in enumerate(buckets):
        mgr.submit(WorkItem(key=f"bucket{i}", fn=lambda b=b: execute_bucket(b)))
    per_bucket = mgr.run(n_workers, expected=len(buckets))
    out: Dict[int, Any] = {}
    for v in per_bucket.values():
        if isinstance(v, Exception):
            raise v
        out.update(v)
    return out

"""Manager — the demand-driven scheduler of the runtime (paper §II: RTF
execution model), with the fault-tolerance features a 1000-node deployment
needs:

* demand-driven dispatch — Workers receive the next bucket when free
  (natural load balancing, same as the paper's 92%-efficiency runs);
* heartbeats + retry — a bucket whose Worker misses its heartbeat deadline
  is re-enqueued (at-least-once; results are idempotent because tasks are
  pure functions of (input, params)); the deadline adapts to observed
  bucket times so a long-running bucket (e.g. a first-time jit compile) is
  not mistaken for a dead Worker, and a lease whose Worker is *provably*
  dead (a killed worker process) is re-enqueued immediately;
* straggler mitigation — when the queue is empty and a bucket has been
  running longer than ``straggler_factor`` × the median bucket time, a
  backup copy is launched on an idle Worker; first completion wins (the
  classic demand-driven tail-cloning trick);
* elastic scaling — Workers can join/leave between buckets; the Manager
  only tracks outstanding leases.

Since DESIGN.md §13 the Manager is a **pure scheduler/bookkeeper**: it owns
the queue, lease table, retry/backup policy and result memoisation, and
executes nothing itself. Execution happens behind the
:class:`~repro.runtime.transport.WorkerBackend` protocol — ``Manager()``
defaults to a :class:`~repro.runtime.transport.ThreadBackend` (the
historical in-process Worker pool), and ``Manager(backend=
ProcessRpcBackend(...))`` drives real worker processes through the same
scheduling semantics, results crossing the boundary only as SharedStore
keys. A single pump thread drives the loop: poll completions → settle/fail
→ expire dead/stale leases → offer leases to free workers.

Sessions are **long-lived** (DESIGN.md §10): ``start`` spawns the Worker
pool once, ``submit`` is legal while Workers are running (including from a
completion callback), ``drain`` blocks until every submitted item has a
result, and ``close`` retires the pool — idempotent, callable from any
thread, and safe to race with ``drain`` (an explicit guarded state
transition, not thread-join ordering). The one-shot ``run`` wrapper keeps
the original batch semantics on top of the same machinery. Per-item
completion callbacks fire exactly once per key — on the *first* completion,
under the same lock that records the result — so a raced straggler backup
can never double-report; the callback body runs outside the lock so it may
re-enter ``submit`` (how the streaming executor chains per-input stage
edges).

**Hierarchical scheduling** (DESIGN.md §15): at paper scale (256 nodes ×
28 cores) a single pump thread is the global serialization point, so
``Manager(hierarchy=...)`` splits dispatch across a manager-of-managers:
the leader pump keeps completions, expiry, liveness and settlement (the
bookkeeping that makes settlement exactly-once stays centralised — one
lock, one attempt sequence, first-completion-wins), and delegates
contiguous lease blocks to N *sub-manager pumps*, each owning a shard of
the WorkerBackend pool. Routing is locality-aware — work is sent to the
sub-manager/worker already holding the longest reuse-tree prefix, tracked
in a per-worker affinity map fed by Completion records — and idle pumps
steal the tail half of the most loaded peer's queue. Items move between
queues only under the Manager lock and leases are still minted centrally,
so a stolen item can never settle twice. ``hierarchy=None`` (the default)
keeps the flat single-pump Manager byte-for-byte.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.runtime.fairshare import FairQueue, TaskCancelled
from repro.runtime.hierarchy import (
    HierarchySpec,
    best_affinity,
    parse_hierarchy,
    path_lcp,
)
from repro.runtime.transport import (
    Completion,
    Lease,
    RemoteTaskError,
    WorkerStatus,
    make_backend,
)

__all__ = ["WorkItem", "Manager", "TaskCancelled", "run_study_distributed"]

# How many queue-head items a sub-pump scans for the best affinity match
# before falling back to FIFO — bounds locality search per dispatch.
_AFFINITY_WINDOW = 8

# How long the pump blocks per completion poll; bounds the latency of
# straggler/heartbeat detection while the system is idle.
_IDLE_TICK = 0.02
# Parked-pump wake cadence: an idle pool still owes the backend a slow
# heartbeat-frame drain (worker stats ride heartbeats, and a straggler
# lease orphaned by cancel/resubmit completes late and must be consumed)
# — so the park is a timed wait, ~25x sparser than the busy-poll tick.
_PARK_TICK = 0.5

# A worker heartbeat younger than this proves its leases live (only for
# backends whose heartbeats keep flowing mid-task); staler workers fall
# back to age-based expiry, so a wedged-but-running process still recovers.
_LIVENESS_FRESH = 5.0

# Session states — the explicit close()/drain() transition guard.
_NEW, _RUNNING, _CLOSING, _CLOSED = "new", "running", "closing", "closed"


@dataclasses.dataclass
class WorkItem:
    key: str
    fn: Optional[Callable[[], Any]] = None
    attempts: int = 0
    started_at: Optional[float] = None
    # Called exactly once, as fn's first completion (or permanent failure,
    # with the Exception as the value) is recorded. Runs on the Manager's
    # pump thread, outside the Manager lock.
    callback: Optional[Callable[[str, Any], None]] = None
    # Picklable task description for backends that cross a process
    # boundary (transport.Lease ships it; fn never leaves this process).
    spec: Optional[tuple] = None
    # Attempt number this key's CURRENT lifecycle started from. Nonzero
    # only after a forgotten key is resubmitted while a prior lifecycle's
    # lease still ran: attempt numbers stay monotonic per key (so lease
    # ids never collide across lifecycles) and the retry budget is
    # measured from this base instead of zero.
    attempt_base: int = 0
    # Reuse-tree prefix of this item (e.g. (input_key, stage, group)): the
    # hierarchical scheduler routes it toward the sub-manager/worker whose
    # affinity shares the longest common prefix. None opts out of locality.
    path: Optional[tuple] = None
    # Fair-share class (DESIGN.md §18): the dispatch queue deficit-round-
    # robins across tenants, so one tenant's backlog cannot starve another.
    # "" is the shared default class (single-study sessions stay pure FIFO).
    tenant: str = ""
    # Within-tenant dispatch priority: higher first, FIFO within a level.
    priority: int = 0
    # Content-addressed sharing (the service's cross-tenant reuse): a shared
    # submission of a key that is already pending SUBSCRIBES its callback to
    # the in-flight lifecycle instead of enqueueing a duplicate execution,
    # and a shared submission of a settled key is served the memoised value
    # immediately. Requires keys derived from task CONTENT, so identical
    # keys always denote identical pure work.
    shared: bool = False


class _SubPump:
    """One sub-manager pump: a dispatch thread owning a shard of the
    worker pool and a local queue of UNLEASED WorkItems. All queue
    mutation happens under the owning Manager's lock; leases are minted
    by the Manager's central bookkeeping at offer time."""

    __slots__ = (
        "idx", "worker_ids", "queue", "dispatched", "steals",
        "stolen_items", "busy_seconds", "parked_seconds", "parked_since",
        "thread", "dead",
    )

    def __init__(self, idx: int, worker_ids) -> None:
        self.idx = idx
        self.worker_ids = frozenset(worker_ids)
        self.queue: "collections.deque[WorkItem]" = collections.deque()
        self.dispatched = 0
        self.steals = 0        # times this pump stole a block
        self.stolen_items = 0  # items it acquired by stealing
        self.busy_seconds = 0.0
        self.parked_seconds = 0.0  # time parked on the Manager condvar
        # park-in-progress start time, so stats taken MID-park still see
        # the elapsed idle (folded into parked_seconds when the park ends)
        self.parked_since: Optional[float] = None
        self.thread: Optional[threading.Thread] = None
        self.dead = False


class Manager:
    # Total Worker-pool sessions ever started in this process; the
    # differential suite uses deltas of this to prove execute_study spins up
    # ONE session per study instead of one per stage×input.
    sessions_started = 0

    def __init__(
        self,
        *,
        backend: Any = None,
        max_attempts: int = 3,
        heartbeat_timeout: float = 60.0,
        straggler_factor: float = 3.0,
        enable_backup_tasks: bool = True,
        hierarchy: Any = None,
    ):
        self._backend = make_backend(backend)
        self.hierarchy: HierarchySpec = parse_hierarchy(hierarchy)
        self._hier: HierarchySpec = self.hierarchy  # resolved at start()
        self._subs: List[_SubPump] = []
        self._sub_stop = threading.Event()
        self._sub_error: Optional[BaseException] = None  # guard: _lock
        # Block-delegation cursor: the sub currently receiving the leader's
        # contiguous block, and how many items remain in that block.
        self._block_sub: Optional[_SubPump] = None  # guard: _lock
        self._block_left = 0  # guard: _lock
        # worker_id -> reuse-tree path of its last successful completion:
        # the affinity map behind locality-aware dispatch.
        self._affinity: Dict[int, tuple] = {}  # guard: _lock
        # worker_id -> attempt-seconds it has executed (all attempts, both
        # outcomes) — the per-worker occupancy the benchmark reports.
        self._worker_busy: Dict[int, float] = {}  # guard: _lock
        self._n_workers = 0  # guard: _lock
        self._pump_busy = 0.0  # guard: _lock — leader-pump seconds spent doing work
        # Idle-pool accounting (DESIGN.md §18): seconds the leader pump has
        # spent parked on the condition variable with zero pending work, and
        # the start of an in-progress park — scheduler_stats subtracts this
        # from wall time so idle fractions stay honest across the many-job
        # lifetime of a long-lived service session.
        self._pump_parked = 0.0  # guard: _lock
        self._parked_since: Optional[float] = None  # guard: _lock
        self._session_t0: Optional[float] = None  # guard: _lock
        self._session_t1: Optional[float] = None  # guard: _lock
        self.steals = 0  # guard: _lock
        self.steal_items = 0  # guard: _lock
        self.locality_hits = 0  # guard: _lock
        self.locality_misses = 0  # guard: _lock
        self._queue: FairQueue = FairQueue()  # guard: _lock
        self._results: Dict[str, Any] = {}  # guard: _lock
        self._running: Dict[str, WorkItem] = {}  # guard: _lock
        self._attempt_seq: Dict[str, int] = {}  # guard: _lock — highest attempt # issued per key
        # key -> callbacks subscribed to its first completion. A list, not a
        # single slot: shared (content-addressed) submissions subscribe many
        # jobs to one lifecycle; every callback fires exactly once.
        self._callbacks: Dict[str, List[Callable[[str, Any], None]]] = {}  # guard: _lock
        self._pending: set = set()  # guard: _lock — keys submitted, no result yet
        # Keys forgotten while still holding a lease: their bookkeeping is
        # kept for first-completion-wins dedup and released when the last
        # lease settles (drained in _settle), so a long-lived fleet session
        # stays bounded even when forget() races in-flight attempts.
        self._deferred_forget: set = set()  # guard: _lock
        # Lease ids stranded by a key's resubmission (a new lifecycle began
        # while the old lifecycle's attempt still ran): their completions
        # must not settle the new lifecycle, so they are dropped on arrival.
        self._orphaned: set = set()  # guard: _lock
        # Recent-window of winning-attempt durations for the straggler /
        # heartbeat heuristics: bounded so a session spanning thousands of
        # inputs never grows the median computation, with the sorted median
        # cached between appends (the pump polls it every tick).
        self._durations: "collections.deque[float]" = collections.deque(maxlen=512)  # guard: _lock
        self._median_cache: Optional[float] = None  # guard: _lock
        self._busy_total = 0.0  # guard: _lock — lifetime sum (the efficiency numerator)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pump_thread: Optional[threading.Thread] = None
        self._state = _NEW  # guard: _lock
        self.max_attempts = max_attempts
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.enable_backup_tasks = enable_backup_tasks
        self.retries = 0  # guard: _lock
        self.backups_launched = 0  # guard: _lock
        self.heartbeat_expiries = 0  # guard: _lock
        self.cancelled = 0  # guard: _lock — keys revoked via cancel()
        # Leases handed to each backend (keyed by backend name) over this
        # Manager's lifetime — the per-backend dispatch accounting surfaced
        # by study summaries.
        self.dispatch_counts: Dict[str, int] = {}  # guard: _lock
        # Leases minted per fair-share tenant — the service/benchmark proof
        # that deficit-round-robin actually shares the dispatch path.
        self.tenant_dispatch: Dict[str, int] = {}  # guard: _lock

    @property
    def backend(self):
        """The WorkerBackend this session dispatches through."""
        return self._backend

    @property
    def backend_name(self) -> str:
        return getattr(self._backend, "name", type(self._backend).__name__)

    @property
    def is_running(self) -> bool:
        """True between ``start`` and the completion of ``close`` — i.e.
        the session can still execute work."""
        # analysis: ok[locks] deliberately lock-free status probe; _state is
        # a small int and a stale answer is as good as one a tick later
        return self._state in (_RUNNING, _CLOSING)

    @property
    def busy_seconds(self) -> float:
        """Sum of winning-attempt wall-times — the useful-work numerator of
        the parallel-efficiency accounting."""
        with self._lock:
            return self._busy_total

    def scheduler_stats(self) -> Dict[str, Any]:
        """Snapshot of the scheduler's shape and health: hierarchy mode and
        fanout, work-stealing and locality counters, pump occupancy (the
        fraction of session wall-time each pump spent doing scheduling
        work — the serialization metric the hierarchy exists to fix), and
        per-worker busy seconds / mean idle fraction."""
        now = time.monotonic()
        with self._lock:
            t0 = self._session_t0
            t1 = self._session_t1 if self._session_t1 is not None else now
            wall = max(t1 - t0, 1e-9) if t0 is not None else 0.0
            parked = self._pump_parked
            if self._parked_since is not None and self._session_t1 is None:
                parked += now - self._parked_since
            # Idle fractions are measured against ACTIVE wall — session
            # wall minus the time the pump sat parked with zero pending
            # work — so a long-lived session that served three jobs over
            # an hour reports how busy the workers were while there WAS
            # work, not how empty the hour was.
            active = max(wall - parked, 0.0)
            denom = active if active > 1e-9 else wall
            hits, misses = self.locality_hits, self.locality_misses
            worker_busy = dict(self._worker_busy)
            n_workers = max(1, self._n_workers)
            stats: Dict[str, Any] = {
                "mode": "hierarchical" if self._subs else "flat",
                "fanout": len(self._subs) if self._subs else 1,
                "steals": self.steals,
                "steal_items": self.steal_items,
                "locality_hits": hits,
                "locality_misses": misses,
                "locality_hit_rate": (
                    hits / (hits + misses) if (hits + misses) else 0.0
                ),
                "pump_occupancy": self._pump_busy / denom if denom else 0.0,
                "pump_parked_seconds": parked,
                "active_wall_seconds": active,
                "sub_occupancy": [
                    s.busy_seconds / denom if denom else 0.0
                    for s in self._subs
                ],
                "sub_parked_seconds": [
                    s.parked_seconds
                    + (now - s.parked_since if s.parked_since is not None else 0.0)
                    for s in self._subs
                ],
                "dispatched_per_sub": [s.dispatched for s in self._subs],
                "steals_per_sub": [s.steals for s in self._subs],
                "worker_busy_seconds": worker_busy,
                "worker_idle_fraction": (
                    min(
                        1.0,
                        max(
                            0.0,
                            1.0
                            - sum(worker_busy.values()) / (denom * n_workers),
                        ),
                    )
                    if denom
                    else 0.0
                ),
                "wall_seconds": wall,
                "cancelled": self.cancelled,
                "tenant_dispatch": dict(self.tenant_dispatch),
                "tenant_depths": self._queue.depths(),
            }
        return stats

    def _record_duration_locked(self, dur: float) -> None:
        self._durations.append(dur)
        self._busy_total += dur
        self._median_cache = None

    def _median_locked(self) -> Optional[float]:
        if not self._durations:
            return None
        if self._median_cache is None:
            ordered = sorted(self._durations)
            self._median_cache = ordered[len(ordered) // 2]
        return self._median_cache

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def start(self, n_workers: int) -> None:
        """Spawn the Worker pool through the backend and start the pump.
        One session may span many stages and many inputs; submitting while
        Workers run is the intended usage."""
        with self._cond:
            if self._state in (_RUNNING, _CLOSING):
                raise RuntimeError("Manager session already started")
            prev = self._state
            self._state = _RUNNING
        try:
            self._backend.start(max(1, n_workers))
        except BaseException:
            with self._cond:  # roll back: no zombie "running" session with
                self._state = prev  # no pump to ever settle submissions
                self._cond.notify_all()
            raise
        Manager.sessions_started += 1
        wids = sorted(self._backend.heartbeat_view().keys())
        with self._lock:
            self._n_workers = len(wids) or max(1, n_workers)
            self._session_t0 = time.monotonic()
            self._session_t1 = None
            self._hier = self.hierarchy.resolve(self._n_workers)
            self._sub_error = None
            self._sub_stop = threading.Event()
            self._subs = []
            self._block_sub = None
            self._block_left = 0
            if self._hier.fanout > 1 and wids:
                # contiguous worker-id shards, one per sub-manager pump
                fanout = self._hier.fanout
                n = len(wids)
                self._subs = [
                    _SubPump(g, wids[g * n // fanout: (g + 1) * n // fanout])
                    for g in range(fanout)
                ]
        for sub in self._subs:
            sub.thread = threading.Thread(
                target=self._sub_pump, args=(sub,), daemon=True
            )
            sub.thread.start()
        self._pump_thread = threading.Thread(target=self._pump, daemon=True)
        self._pump_thread.start()

    def submit(self, item: WorkItem) -> None:
        """Enqueue work; legal before ``start`` and while Workers run.
        Re-submitting a key that already has a result is a no-op — EXCEPT
        when that result is a stale memo retained only for a forgotten
        key's still-running lease (deferred forget): the caller has ended
        that lifecycle, so this submission starts a NEW one. The stale
        memo is released, the old lifecycle's leases are orphaned (their
        completions are dropped on arrival — they may have run under a
        different scope, so their values must never settle this
        lifecycle), and attempt numbering continues from the old high
        water mark so lease ids stay unique across lifecycles.

        ``item.shared`` opts into **content-addressed sharing** (DESIGN.md
        §18): a shared submission of a key already pending subscribes its
        callback to the in-flight lifecycle (no duplicate execution), and
        a shared submission of a settled key is served the memoised value
        immediately — the mechanism by which N tenants submitting
        identical pure work pay for it once."""
        memo_value: Any = None
        serve_memo = False
        with self._cond:
            if self._state in (_CLOSING, _CLOSED):
                raise RuntimeError("Manager session is closed")
            if item.key in self._deferred_forget:
                self._deferred_forget.discard(item.key)
                self._results.pop(item.key, None)
                self._callbacks.pop(item.key, None)
                for lid in [
                    lid for lid, it in self._running.items() if it.key == item.key
                ]:
                    self._orphaned.add(lid)
                    del self._running[lid]
                # queued duplicates (heartbeat-expiry re-enqueues racing in
                # after forget) carry the OLD lifecycle's closure — purge
                # every queue they may sit in (global + delegated shards)
                self._queue.remove_keys({item.key})
                for sub in self._subs:
                    if any(it.key == item.key for it in sub.queue):
                        sub.queue = collections.deque(
                            it for it in sub.queue if it.key != item.key
                        )
                item.attempt_base = self._attempt_seq.get(item.key, 0)
            if item.key in self._results:
                if item.shared and item.callback is not None:
                    # served the live memo below, OUTSIDE the lock — the
                    # callback may re-enter submit()
                    serve_memo = True
                    memo_value = self._results[item.key]
                # historical contract: non-shared resubmit of a settled
                # key is a silent no-op
            elif (
                item.shared
                and item.key in self._pending
            ):
                # subscribe to the in-flight lifecycle: exactly-once per
                # subscriber, zero duplicate execution
                if item.callback is not None:
                    self._callbacks.setdefault(item.key, []).append(
                        item.callback
                    )
            else:
                if item.callback is not None:
                    if item.shared:
                        self._callbacks.setdefault(item.key, []).append(
                            item.callback
                        )
                    else:
                        # historical single-slot semantics: the latest
                        # non-shared submission's callback wins
                        self._callbacks[item.key] = [item.callback]
                self._pending.add(item.key)
                self._queue.append(item)
                self._cond.notify_all()
        if serve_memo:
            item.callback(item.key, memo_value)

    def drain(self) -> None:
        """Block until every submitted key has a result (success or
        permanent failure). Workers stay alive — more work may follow.

        When the backend acknowledges completions ahead of their disk
        commit (``async_commit``), drain is also the durability point: it
        invokes the backend's ``barrier()`` so that after it returns, every
        result is resolvable from the store by any process — the same
        contract callers had when workers committed synchronously."""
        with self._cond:
            while self._pending:
                self._cond.wait(_IDLE_TICK)
        barrier = getattr(self._backend, "barrier", None)
        if barrier is not None:
            barrier()

    def close(self) -> None:
        """Retire the Worker pool. Completes everything already submitted
        first (in-flight attempts and queued work all settle), then shuts
        the backend down.

        Idempotent and thread-safe: a second ``close`` — from any thread,
        including one racing ``drain`` — observes the guarded state
        transition and simply waits for the first closer to finish instead
        of double-joining the pool."""
        with self._cond:
            if self._state in (_NEW, _CLOSED):
                self._state = _CLOSED
                self._cond.notify_all()
                return
            if self._state == _CLOSING:
                # another thread owns the shutdown: wait it out
                while self._state != _CLOSED:
                    self._cond.wait(_IDLE_TICK)
                return
            self._state = _CLOSING
            self._cond.notify_all()
            pump = self._pump_thread
        if pump is not None:
            pump.join()
        self._sub_stop.set()
        with self._cond:
            self._cond.notify_all()  # unpark sub-pumps so they see the stop
        for sub in self._subs:
            if sub.thread is not None:
                sub.thread.join()
                sub.thread = None
        self._backend.shutdown()
        with self._cond:
            if self._session_t0 is not None and self._session_t1 is None:
                self._session_t1 = time.monotonic()
            self._state = _CLOSED
            self._pump_thread = None
            self._cond.notify_all()

    def results(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self._results)

    def forget(self, keys) -> None:
        """Release memoised results + attempt bookkeeping for keys whose
        lifecycle is over (drained, consumed). A long-lived session would
        otherwise retain every settled WorkItem's value for its whole life
        — the streaming executor calls this per study when sharing a
        session across an adaptive study's rounds.

        Two races are closed under the lock: stale queued duplicates of a
        forgotten key (heartbeat-expiry re-enqueues) are purged — without
        their memoised result they would re-execute — and a key whose
        losing attempt (straggler backup / presumed-dead original) still
        holds a lease keeps its result, so the late completion dedups via
        first-completion-wins instead of resurrecting a value. Such keys
        join the deferred-forget set and are released when their last lease
        settles."""
        with self._cond:
            keyset = set(keys)
            if not keyset:
                return
            self._queue.remove_keys(keyset)
            for sub in self._subs:
                if any(it.key in keyset for it in sub.queue):
                    sub.queue = collections.deque(
                        it for it in sub.queue if it.key not in keyset
                    )
            leased = {it.key for it in self._running.values()}
            # Keys with an outstanding ORPHANED lease are held too: their
            # drop-marker carries a lease id minted from the key's attempt
            # sequence, so releasing the sequence now would let a future
            # lifecycle re-mint a colliding id and have its completion
            # silently dropped. They drain when the orphan settles/dies.
            orphan_keys = {
                lid.rsplit("#", 1)[0] for lid in self._orphaned
            }
            self._deferred_forget |= keyset & (leased | orphan_keys)
            for k in keyset - leased - orphan_keys:
                self._results.pop(k, None)
                self._attempt_seq.pop(k, None)
                self._callbacks.pop(k, None)

    def cancel(self, keys) -> List[str]:
        """Revoke submitted-but-unsettled keys (DESIGN.md §18): queued
        work is purged from every queue (global + delegated shards), live
        leases are poisoned (their ids join the orphan set, so the
        worker's eventual completion is dropped on arrival — the worker
        itself is not interrupted mid-task), and each revoked key settles
        exactly once with :class:`TaskCancelled` as its value, firing its
        callbacks like any other permanent failure. Keys already settled
        or never submitted are left untouched. Returns the keys actually
        cancelled.

        After cancel, ``forget`` + re-``submit`` of the same key starts a
        clean new lifecycle: attempt numbering continues from the high
        water mark, so a straggling poisoned lease can never collide with
        — or settle — the new lifecycle."""
        cancelled: List[str] = []
        with self._cond:
            keyset = set(keys)
            if not keyset:
                return cancelled
            live = {
                k for k in keyset
                if k in self._pending and k not in self._results
            }
            if not live:
                return cancelled
            self._queue.remove_keys(live)
            for sub in self._subs:
                if any(it.key in live for it in sub.queue):
                    sub.queue = collections.deque(
                        it for it in sub.queue if it.key not in live
                    )
            for lid, it in list(self._running.items()):
                if it.key in live:
                    self._orphaned.add(lid)
                    del self._running[lid]
            cancelled = sorted(live)
            self.cancelled += len(cancelled)
        # settle outside the lock: callbacks may re-enter submit()
        for key in cancelled:
            self._settle(key, 0, TaskCancelled(f"cancelled: {key!r}"), None)
        return cancelled

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Set a fair-share tenant's dispatch quantum (default 1.0; higher
        drains proportionally faster, floor-clamped so every tenant keeps
        making progress)."""
        with self._lock:
            self._queue.set_weight(tenant, weight)

    def _drain_deferred_locked(self, key: str) -> None:
        """Release a deferred-forgotten key's bookkeeping once its LAST
        lease has been returned (caller holds the lock and has already
        popped its own lease). While any other attempt is still in flight
        — including a poisoned orphan whose drop-marker was minted from
        this key's attempt sequence — the bookkeeping must survive so the
        late completion dedups instead of colliding."""
        if key not in self._deferred_forget:
            return
        if any(it.key == key for it in self._running.values()):
            return
        if any(lid.rsplit("#", 1)[0] == key for lid in self._orphaned):
            return
        self._deferred_forget.discard(key)
        self._results.pop(key, None)
        self._attempt_seq.pop(key, None)
        self._callbacks.pop(key, None)

    # ------------------------------------------------------------------
    # Scheduling (pump-side)
    # ------------------------------------------------------------------
    def _next_locked(self) -> Optional[WorkItem]:
        # Dequeue and lease registration are atomic under one lock: a peer
        # observing (queue empty, no leases) under that lock can therefore
        # conclude the system is idle — there is no window where an item has
        # left the queue but is not yet visible in ``_running``. Items whose
        # key already has a result (a raced retry/backup) are dropped here,
        # before any lease exists, so they can never leak one.
        while True:
            if not self._queue:
                item = self._maybe_backup_locked()
                if item is None:
                    return None
                break
            item = self._queue.popleft()
            if item.key not in self._results:
                break
        self._lease_locked(item)
        return item

    def _lease_locked(self, item: WorkItem) -> None:
        """Mint a lease for ``item`` under the Manager lock. Attempt
        numbers are issued centrally — here and ONLY here — so concurrent
        attempts of one key (original + backup, or a stolen re-dispatch)
        always hold distinct leases, whichever pump leases them."""
        item.started_at = time.monotonic()
        item.attempts = self._attempt_seq.get(item.key, 0) + 1
        self._attempt_seq[item.key] = item.attempts
        self._running[f"{item.key}#{item.attempts}"] = item
        self.tenant_dispatch[item.tenant] = (
            self.tenant_dispatch.get(item.tenant, 0) + 1
        )

    # -- hierarchical scheduling (leader + sub-manager pumps) ----------
    def _route_locked(self, item: WorkItem) -> Optional[_SubPump]:
        """Pick the sub-manager to delegate ``item`` to: the shard whose
        workers hold the longest reuse-tree prefix of ``item.path`` wins
        (locality); otherwise the leader fills contiguous blocks of
        ``block_size`` into the currently-shortest queue."""
        subs = [s for s in self._subs if not s.dead]
        if not subs:
            return None
        if self._hier.locality and item.path:
            best: Optional[_SubPump] = None
            best_l = 0
            for s in subs:
                l = best_affinity(
                    item.path, [self._affinity.get(w) for w in s.worker_ids]
                )
                if l > best_l:
                    best, best_l = s, l
            if best is not None:
                return best
        if (
            self._block_left <= 0
            or self._block_sub is None
            or self._block_sub.dead
        ):
            self._block_sub = min(subs, key=lambda s: len(s.queue))
            self._block_left = self._hier.block_size
        self._block_left -= 1
        return self._block_sub

    def _distribute_locked(self) -> int:
        """Leader-side delegation: move everything queued globally into the
        sub-manager queues (locality first, contiguous blocks otherwise).
        With nothing queued anywhere, fall back to straggler backup-task
        cloning — the clone is delegated like any other item, and a queued
        clone blocks further cloning of the same key (the all-queues-empty
        guard) until it is leased."""
        moved = 0
        while self._queue:
            item = self._queue.popleft()
            sub = self._route_locked(item)
            if sub is None:  # every sub-pump died; leader will fail over
                self._queue.appendleft(item)
                return moved
            sub.queue.append(item)
            moved += 1
        if moved == 0 and not any(s.queue for s in self._subs):
            clone = self._maybe_backup_locked()
            if clone is not None:
                sub = self._route_locked(clone)
                if sub is not None:
                    sub.queue.append(clone)
                    moved += 1
        return moved

    def _steal_locked(self, thief: _SubPump) -> int:
        """Work stealing: an idle pump takes the tail half of the most
        loaded peer's queue (relative order preserved). Items are unleased
        while queued, and the move happens under the Manager lock, so
        exactly-once settlement is untouched — the thief simply becomes
        the pump that eventually mints the lease."""
        victim: Optional[_SubPump] = None
        for s in self._subs:
            if s is thief or s.dead:
                continue
            if victim is None or len(s.queue) > len(victim.queue):
                victim = s
        if victim is None or len(victim.queue) < max(2, self._hier.steal_min):
            return 0
        n = len(victim.queue) // 2
        stolen = [victim.queue.pop() for _ in range(n)]
        stolen.reverse()
        thief.queue.extend(stolen)
        thief.steals += 1
        thief.stolen_items += n
        self.steals += 1
        self.steal_items += n
        return n

    def _next_sub_locked(
        self, sub: _SubPump, worker_id: Optional[int] = None
    ) -> Optional[WorkItem]:
        """Dequeue-and-lease from a sub-manager's queue. With a target
        worker and locality enabled, the first ``_AFFINITY_WINDOW`` items
        are scanned for the longest prefix match against that worker's
        affinity path; otherwise FIFO. Locality hits/misses are tallied
        here — a hit means the chosen placement shares ≥1 path segment
        with the worker's (or, for shard-batched dispatch, the shard's)
        last completed work."""
        while sub.queue:
            idx = 0
            best_l = 0
            if self._hier.locality and worker_id is not None:
                aff = self._affinity.get(worker_id)
                if aff:
                    window = min(len(sub.queue), _AFFINITY_WINDOW)
                    for j in range(window):
                        it = sub.queue[j]
                        l = path_lcp(it.path, aff)
                        if l > best_l:
                            best_l, idx = l, j
            if idx:
                sub.queue.rotate(-idx)
                item = sub.queue.popleft()
                sub.queue.rotate(idx)
            else:
                item = sub.queue.popleft()
            if item.key in self._results:
                continue
            if self._hier.locality and item.path is not None:
                if worker_id is not None:
                    hit = best_l >= 1
                else:
                    hit = (
                        best_affinity(
                            item.path,
                            [self._affinity.get(w) for w in sub.worker_ids],
                        )
                        >= 1
                    )
                if hit:
                    self.locality_hits += 1
                else:
                    self.locality_misses += 1
            self._lease_locked(item)
            return item
        return None

    def _unlease_locked(self, item: WorkItem) -> None:
        """Revert ``_next_locked`` for a lease no worker accepted (a slot
        vanished between the demand snapshot and the offer — e.g. a worker
        died). The attempt number is returned too: nothing outside this
        process ever observed it."""
        lid = f"{item.key}#{item.attempts}"
        if lid in self._orphaned:
            # the lease was cancelled/orphaned between minting and the
            # rejected offer: the drop-marker has done its job (nothing
            # was ever dispatched) — discard it WITHOUT reverting the
            # attempt sequence, so the marker's id can never be re-minted
            # by the key's next lifecycle.
            self._orphaned.discard(lid)
            self._drain_deferred_locked(item.key)
            return
        self._running.pop(lid, None)
        if self._attempt_seq.get(item.key) == item.attempts:
            self._attempt_seq[item.key] = item.attempts - 1
        if item.key not in self._results:
            self._queue.appendleft(item)

    def _expire_dead_locked(
        self, view: Dict[int, WorkerStatus], to_settle: List
    ) -> None:
        """Re-enqueue leases held by provably-dead workers (a worker
        process that no longer exists). Unlike age-based expiry this is
        immediate — there is no ambiguity to adapt a deadline around. A key
        out of attempts with no other live lease settles as a permanent
        failure (appended to ``to_settle``; the caller settles outside the
        lock)."""
        for status in view.values():
            if status.alive:
                continue
            for lease_id in status.inflight:
                item = self._running.pop(lease_id, None)
                if item is None:
                    # an orphaned lease dies with its worker: no completion
                    # will ever arrive to drain its drop-marker
                    if lease_id in self._orphaned:
                        self._orphaned.discard(lease_id)
                        self._drain_deferred_locked(
                            lease_id.rsplit("#", 1)[0]
                        )
                    continue
                self.heartbeat_expiries += 1
                if item.key in self._results:
                    self._drain_deferred_locked(item.key)
                    continue
                if (
                    self._attempt_seq.get(item.key, 0) - item.attempt_base
                    < self.max_attempts
                ):
                    self.retries += 1
                    self._queue.append(
                        WorkItem(key=item.key, fn=item.fn, spec=item.spec,
                                 attempt_base=item.attempt_base,
                                 path=item.path, tenant=item.tenant,
                                 priority=item.priority)
                    )
                    self._cond.notify_all()
                elif not any(
                    it.key == item.key for it in self._running.values()
                ):
                    to_settle.append(
                        (
                            item.key,
                            item.attempts,
                            RemoteTaskError(
                                f"worker died holding the last attempt of "
                                f"{item.key!r}"
                            ),
                        )
                    )

    def _expire_heartbeats_locked(
        self, view: Optional[Dict[int, WorkerStatus]] = None
    ) -> None:
        """Re-enqueue leases whose Worker missed the heartbeat deadline.
        The lease is released; if the presumed-dead attempt does return
        later, first-completion-wins dedups it.

        In-process Workers cannot prove liveness while inside a task fn, so
        a long bucket is indistinguishable from a dead Worker by age alone.
        The deadline therefore adapts to observed bucket times — ``max(
        heartbeat_timeout, straggler_factor × median)`` — and with no
        completed-bucket history yet (e.g. the first bucket is a multi-
        minute jit compile) nothing is ever expired.

        ``view`` is passed only by backends whose heartbeats PROVE liveness
        mid-task (the RPC backend's workers sign life from a side thread):
        a lease held by a worker seen alive within ``_LIVENESS_FRESH``
        seconds is never age-expired — a long bucket on a live remote
        worker gets a straggler backup clone, not a revoked lease. A
        wedged worker whose heartbeats stop re-enters age-based expiry.
        (Provably-dead workers are handled separately and immediately by
        ``_expire_dead_locked``.)"""
        median = self._median_locked()
        if median is None:
            return
        deadline = max(self.heartbeat_timeout, self.straggler_factor * median)
        now = time.monotonic()
        proven_live: set = set()
        if view is not None:
            for status in view.values():
                if status.alive and now - status.last_seen <= _LIVENESS_FRESH:
                    proven_live.update(status.inflight)
        for lease, it in list(self._running.items()):
            if it.key in self._results:
                continue
            if lease in proven_live:
                continue
            started = it.started_at or now
            if now - started <= deadline:
                continue
            if (
                self._attempt_seq.get(it.key, 0) - it.attempt_base
                >= self.max_attempts
            ):
                continue
            del self._running[lease]
            self.heartbeat_expiries += 1
            self.retries += 1
            self._queue.append(WorkItem(key=it.key, fn=it.fn, spec=it.spec,
                                        attempt_base=it.attempt_base,
                                        path=it.path, tenant=it.tenant,
                                        priority=it.priority))
            self._cond.notify_all()

    def _maybe_backup_locked(self) -> Optional[WorkItem]:
        """Clone the longest-running bucket if it looks like a straggler.
        Caller holds ``self._lock``. At most one backup of a key is in
        flight at a time: while original + clone both run, the key holds two
        leases and is skipped."""
        if not self.enable_backup_tasks:
            return None
        if not self._running or len(self._durations) < 2:
            return None
        median = self._median_locked()
        now = time.monotonic()
        candidates = [
            it
            for it in self._running.values()
            if it.key not in self._results
            and sum(1 for other in self._running.values() if other.key == it.key) < 2
            and self._attempt_seq.get(it.key, 0) - it.attempt_base
            < self.max_attempts
        ]
        if not candidates:
            return None
        worst = max(candidates, key=lambda it: now - (it.started_at or now))
        age = now - (worst.started_at or now)
        if age > self.straggler_factor * max(median, 1e-3):
            self.backups_launched += 1
            return WorkItem(key=worst.key, fn=worst.fn, spec=worst.spec,
                            attempt_base=worst.attempt_base,
                            path=worst.path, tenant=worst.tenant,
                            priority=worst.priority)
        return None

    def _sub_pump(self, sub: _SubPump) -> None:
        """Sub-manager pump thread wrapper: a crashed pump returns its
        unleased work to the leader (which redistributes to surviving
        pumps); when the LAST pump dies the leader fails the session's
        pending work loudly instead of letting drain() hang."""
        try:
            self._sub_pump_loop(sub)
        except BaseException as err:  # noqa: BLE001 — fail over to leader
            with self._cond:
                sub.dead = True
                while sub.queue:
                    self._queue.append(sub.queue.popleft())
                if all(s.dead for s in self._subs):
                    self._sub_error = err
                self._cond.notify_all()

    def _sub_pump_loop(self, sub: _SubPump) -> None:
        backend = self._backend
        offer_to = getattr(backend, "offer_to", None)
        offer_batch = getattr(backend, "offer_batch", None)
        slots = max(1, int(getattr(backend, "slots_per_worker", 1)))
        while not self._sub_stop.is_set():
            # Same idle-pool parking as the leader: with zero pending work
            # the shard pump blocks on the Manager condvar instead of
            # spinning on heartbeat snapshots. Woken by submit()/close()/
            # the leader's delegation notify; state changes and sub-errors
            # break the predicate so shutdown is never missed.
            with self._cond:
                if (
                    self._state == _RUNNING
                    and self._sub_error is None
                    and not self._sub_stop.is_set()
                    and not self._pending
                    and not self._running
                    and not self._queue
                    and not any(s.queue for s in self._subs)
                ):
                    t_park = time.monotonic()
                    sub.parked_since = t_park
                    self._cond.wait()
                    sub.parked_seconds += time.monotonic() - t_park
                    sub.parked_since = None
                    continue
            view = backend.heartbeat_view()
            alive = {
                wid: st
                for wid, st in view.items()
                if wid in sub.worker_ids and st.alive
            }
            if not alive and all(wid in view for wid in sub.worker_ids):
                # the WHOLE shard died (worker death is permanent): this
                # pump can never dispatch again, and peers only steal from
                # queues ≥ steal_min — a single queued item would strand.
                # Retire cleanly: return unleased work to the leader, which
                # redistributes to surviving shards (or, with the pool
                # fully dead, fails pending loudly via its dead-pool path).
                with self._cond:
                    sub.dead = True
                    while sub.queue:
                        self._queue.append(sub.queue.popleft())
                    self._cond.notify_all()
                return
            free = sum(
                max(0, slots - len(st.inflight)) for st in alive.values()
            )
            if free <= 0:
                # all shard slots busy: wait a tick (woken early by any
                # settle/submit notify) instead of a blind sleep
                with self._cond:
                    self._cond.wait(_IDLE_TICK)
                continue
            if self._hier.steal:
                with self._cond:
                    if not sub.queue:
                        self._steal_locked(sub)
            t0 = time.monotonic()
            if offer_batch is not None:
                did = self._sub_dispatch_batched(sub, offer_batch, free)
            else:
                did = self._sub_dispatch_targeted(
                    sub, alive, slots, offer_to
                )
            if did:
                sub.busy_seconds += time.monotonic() - t0
            else:
                with self._cond:
                    self._cond.wait(_IDLE_TICK)

    def _sub_dispatch_targeted(
        self, sub: _SubPump, alive: Dict[int, WorkerStatus], slots: int,
        offer_to,
    ) -> int:
        """Per-worker targeted dispatch (thread backend): each free worker
        in the shard gets the queued item with the longest affinity-prefix
        match. Falls back to untargeted ``offer`` if the backend cannot
        address workers (shard ownership then degrades to advisory)."""
        dispatched = 0
        for wid, st in alive.items():
            if len(st.inflight) >= slots:
                continue
            with self._cond:
                item = self._next_sub_locked(sub, worker_id=wid)
            if item is None:
                break
            lease = Lease(
                key=item.key, attempt=item.attempts, fn=item.fn,
                spec=item.spec,
            )
            ok = (
                offer_to(lease, wid)
                if offer_to is not None
                else self._backend.offer(lease)
            )
            if ok:
                dispatched += 1
                with self._cond:
                    sub.dispatched += 1
                    self.dispatch_counts[self.backend_name] = (
                        self.dispatch_counts.get(self.backend_name, 0) + 1
                    )
            else:  # slot vanished since the snapshot (worker death)
                with self._cond:
                    self._unlease_locked(item)
                break
        return dispatched

    def _sub_dispatch_batched(self, sub: _SubPump, offer_batch, free: int) -> int:
        """Shard-restricted batched dispatch (process backend): lease up
        to ``free`` items and hand them to the backend restricted to this
        sub-manager's workers. Shards partition the pool, so concurrent
        sub-pumps touch disjoint worker handles."""
        batch: List[WorkItem] = []
        with self._cond:
            while len(batch) < free:
                item = self._next_sub_locked(sub)
                if item is None:
                    break
                batch.append(item)
        if not batch:
            return 0
        leases = [
            Lease(key=it.key, attempt=it.attempts, fn=it.fn, spec=it.spec)
            for it in batch
        ]
        try:
            rejected = {
                lease.lease_id
                for lease in offer_batch(leases, worker_ids=sub.worker_ids)
            }
        except TypeError:  # backend without shard targeting: untargeted
            rejected = {lease.lease_id for lease in offer_batch(leases)}
        accepted = len(batch) - len(rejected)
        with self._cond:
            if accepted:
                sub.dispatched += accepted
                self.dispatch_counts[self.backend_name] = (
                    self.dispatch_counts.get(self.backend_name, 0) + accepted
                )
            for it in reversed(batch):
                if f"{it.key}#{it.attempts}" in rejected:
                    self._unlease_locked(it)
        return accepted

    def _settle(
        self, key: str, attempt: int, value: Any, duration: Optional[float]
    ) -> None:
        """Record a final value (result or permanent failure) for a key and
        fire its callback exactly once. The key stays in ``_pending`` until
        the callback returns, so ``drain`` cannot observe a momentarily-empty
        pending set while a callback is still about to submit downstream
        work (the per-input stage edge of the streaming executor)."""
        cbs: Optional[List[Callable[[str, Any], None]]] = None
        won = False
        with self._cond:
            self._running.pop(f"{key}#{attempt}", None)
            if key not in self._results:  # first completion wins
                won = True
                self._results[key] = value
                if duration is not None and not isinstance(value, Exception):
                    self._record_duration_locked(duration)
                cbs = self._callbacks.pop(key, None)
            self._drain_deferred_locked(key)
            self._cond.notify_all()
        if not won:  # raced duplicate: the winner owns callback + pending
            return
        try:
            if cbs:
                # every subscriber of the lifecycle fires exactly once —
                # shared submissions fan one completion out to many jobs
                for cb in cbs:
                    cb(key, value)
        finally:
            with self._cond:
                self._pending.discard(key)
                self._cond.notify_all()

    def _handle_completion(self, comp: Completion) -> None:
        with self._cond:
            if comp.lease_id in self._orphaned:
                # a lease stranded by its key's resubmission or
                # cancellation (new lifecycle): the value may be from
                # another scope — drop it. The marker may have been the
                # last thing pinning a deferred-forgotten key.
                self._orphaned.discard(comp.lease_id)
                self._drain_deferred_locked(comp.key)
                return
            item = self._running.get(comp.lease_id)
            if comp.worker_id is not None:
                if comp.duration:
                    self._worker_busy[comp.worker_id] = (
                        self._worker_busy.get(comp.worker_id, 0.0)
                        + comp.duration
                    )
                if comp.ok and item is not None and item.path is not None:
                    # feed the affinity map: this worker now holds the
                    # reuse-tree prefix of the work it just finished
                    self._affinity[comp.worker_id] = item.path
        if comp.ok:
            self._settle(comp.key, comp.attempt, comp.value, comp.duration)
            return
        err = comp.exc if comp.exc is not None else RemoteTaskError(
            comp.error or "remote task failed"
        )
        # Lease release and re-enqueue happen under one lock so peers never
        # observe (queue empty, no leases) while a retry is still in flight.
        with self._cond:
            self._running.pop(comp.lease_id, None)
            if (
                item is not None
                and item.attempts - item.attempt_base < self.max_attempts
                and item.key not in self._results
            ):
                self.retries += 1
                # attempt numbers are issued by _next_locked at lease time
                self._queue.append(
                    WorkItem(key=item.key, fn=item.fn, spec=item.spec,
                             attempt_base=item.attempt_base,
                             path=item.path, tenant=item.tenant,
                             priority=item.priority)
                )
                self._cond.notify_all()
                return
            if item is None and comp.key not in self._results:
                # the lease was already expired and re-driven; this late
                # failure report must not settle the key under the retry
                return
            if any(it.key == comp.key for it in self._running.values()):
                # an out-of-attempts failure must not condemn the key while
                # another attempt (straggler original / backup clone) is
                # still live — first COMPLETION wins, and if that attempt
                # also fails, ITS failure settles (it will find no live
                # peer then). Same guard _expire_dead_locked applies.
                return
        self._settle(comp.key, comp.attempt, err, None)

    def _pump(self) -> None:
        """The scheduling loop: one thread drives completions, expiry and
        dispatch for the whole session, leaving execution entirely to the
        backend. A structural backend failure fails the session's pending
        work loudly instead of leaving ``drain`` waiting on a dead pump."""
        try:
            self._pump_loop()
        except BaseException as pump_err:  # noqa: BLE001 — fail pending work
            self._sub_stop.set()
            with self._cond:
                delegated = [it for s in self._subs for it in s.queue]
                stranded = {
                    it.key
                    for it in list(self._queue) + delegated
                    + list(self._running.values())
                } | set(self._pending)
                self._queue.clear()
                for s in self._subs:
                    s.queue.clear()
                self._running.clear()
            for key in stranded:
                self._settle(
                    key, 0,
                    RemoteTaskError(f"dispatch pump failed: {pump_err!r}"),
                    None,
                )
            with self._cond:  # keys that already had results stay settled
                self._pending -= set(self._results)
                self._cond.notify_all()
            raise
        finally:
            self._sub_stop.set()
            with self._cond:
                if self._session_t1 is None:
                    self._session_t1 = time.monotonic()
                if self._parked_since is not None:
                    self._pump_parked += (
                        time.monotonic() - self._parked_since
                    )
                    self._parked_since = None
                self._cond.notify_all()  # unpark sub-pumps: stop is set

    def _pump_loop(self) -> None:
        backend = self._backend
        hier = bool(self._subs)
        while True:
            # Idle-pool parking (DESIGN.md §18): with zero pending work —
            # nothing queued anywhere, no leases in flight — a long-lived
            # session's pump parks on the condition variable instead of
            # busy-polling the backend every tick. submit()/close() wake
            # it with notify_all; the first post-wake completion poll is
            # non-blocking so freshly submitted work dispatches
            # immediately instead of riding out a sleeping poll (this is
            # the adaptive driver's round-boundary stall).
            just_woke = False
            with self._cond:
                if (
                    self._state == _RUNNING
                    and self._sub_error is None
                    and not self._pending
                    and not self._running
                    and not self._orphaned
                    and not self._queue
                    and not any(s.queue for s in self._subs)
                ):
                    if self._parked_since is None:
                        self._parked_since = time.monotonic()
                    # Timed, not indefinite: while parked the pump still
                    # owes the backend a slow drain (heartbeat frames
                    # carry worker stats; a lease orphaned moments before
                    # the pool went idle completes late and its dropped
                    # completion must still be consumed). submit()/close()
                    # notify_all for the instant-wake path.
                    self._cond.wait(_PARK_TICK)
                    just_woke = True
                if self._parked_since is not None:
                    self._pump_parked += (
                        time.monotonic() - self._parked_since
                    )
                    self._parked_since = None
            comps = backend.poll_completions(0.0 if just_woke else _IDLE_TICK)
            t_work = time.monotonic()
            for comp in comps:
                self._handle_completion(comp)
            view = backend.heartbeat_view()
            to_settle: List = []
            with self._cond:
                if self._sub_error is not None:
                    # every sub-manager pump died: nothing can dispatch —
                    # escalate through the pump-failure path (fail pending)
                    raise RuntimeError(
                        "all sub-manager pumps failed"
                    ) from self._sub_error
                self._expire_dead_locked(view, to_settle)
                self._expire_heartbeats_locked(
                    view
                    if getattr(backend, "heartbeats_prove_liveness", False)
                    else None
                )
                if view and not any(st.alive for st in view.values()):
                    # the whole pool is gone (every worker process died):
                    # nothing can ever complete — fail what's left instead
                    # of spinning forever
                    delegated = [it for s in self._subs for it in s.queue]
                    for item in (
                        list(self._queue) + delegated
                        + list(self._running.values())
                    ):
                        if item.key not in self._results:
                            to_settle.append(
                                (
                                    item.key,
                                    item.attempts,
                                    RemoteTaskError(
                                        "every worker died; "
                                        f"{item.key!r} can never complete"
                                    ),
                                )
                            )
                    self._queue.clear()
                    for s in self._subs:
                        s.queue.clear()
                    self._running.clear()
            for key, attempt, err in to_settle:
                self._settle(key, attempt, err, None)
            if hier:
                # manager-of-managers: the leader only delegates; the
                # sub-pumps own demand-driven dispatch for their shards
                # (parked sub-pumps are woken when items land in shards)
                with self._cond:
                    if self._distribute_locked():
                        self._cond.notify_all()
            else:
                # demand-driven dispatch: free slots = per-worker queue
                # depth (slots_per_worker > 1 when the backend batches
                # frames — a worker holds a small backlog so it never
                # idles between round trips; 1 for the historical
                # one-lease-per-worker)
                slots = max(1, int(getattr(backend, "slots_per_worker", 1)))
                free = sum(
                    max(0, slots - len(st.inflight))
                    for st in view.values()
                    if st.alive
                )
                offer_batch = getattr(backend, "offer_batch", None)
                if offer_batch is not None:
                    self._dispatch_batched(offer_batch, free)
                else:
                    while free > 0:
                        with self._cond:
                            item = self._next_locked()
                        if item is None:
                            break
                        lease = Lease(
                            key=item.key, attempt=item.attempts, fn=item.fn,
                            spec=item.spec,
                        )
                        if backend.offer(lease):
                            with self._cond:
                                self.dispatch_counts[self.backend_name] = (
                                    self.dispatch_counts.get(self.backend_name, 0)
                                    + 1
                                )
                            free -= 1
                        else:  # slot vanished since snapshot (worker death)
                            with self._cond:
                                self._unlease_locked(item)
                            break
            with self._cond:
                self._pump_busy += time.monotonic() - t_work
                if (
                    self._state == _CLOSING
                    and not self._pending
                    and not self._running
                    and not self._queue
                    and not any(s.queue for s in self._subs)
                ):
                    return

    def _dispatch_batched(self, offer_batch, free: int) -> None:
        """Batched dispatch (DESIGN.md §14): lease up to ``free`` items in
        one pass and hand them to the backend as a single ``offer_batch``
        call — the backend coalesces each worker's share into one frame.
        Rejected leases (slots vanished since the demand snapshot) are
        unleased in reverse lease order, restoring queue position and
        attempt numbers exactly as the one-at-a-time path would."""
        while free > 0:
            batch: List = []
            with self._cond:
                while len(batch) < free:
                    item = self._next_locked()
                    if item is None:
                        break
                    batch.append(item)
            if not batch:
                return
            leases = [
                Lease(key=it.key, attempt=it.attempts, fn=it.fn, spec=it.spec)
                for it in batch
            ]
            rejected = {lease.lease_id for lease in offer_batch(leases)}
            accepted = len(batch) - len(rejected)
            if accepted:
                with self._cond:
                    self.dispatch_counts[self.backend_name] = (
                        self.dispatch_counts.get(self.backend_name, 0) + accepted
                    )
            if rejected:
                with self._cond:
                    for it in reversed(batch):
                        if f"{it.key}#{it.attempts}" in rejected:
                            self._unlease_locked(it)
                return
            free -= accepted

    # ------------------------------------------------------------------
    # One-shot batch mode (the pre-streaming API, kept verbatim)
    # ------------------------------------------------------------------
    def run(self, n_workers: int, *, expected: int) -> Dict[str, Any]:
        """Run until ``expected`` distinct results exist."""
        self.start(n_workers)
        try:
            with self._cond:
                while len(self._results) < expected and self._pending:
                    self._cond.wait(_IDLE_TICK)
        finally:
            self.close()
        # analysis: ok[locks] close() joined the pump: no writer is left
        return dict(self._results)


def run_study_distributed(
    buckets: List[Any],
    execute_bucket: Callable[[Any], Dict[int, Any]],
    *,
    n_workers: int = 2,
    manager: Optional[Manager] = None,
) -> Dict[int, Any]:
    """Execute merged-stage buckets across Workers; returns run_id -> output."""
    mgr = manager or Manager()
    for i, b in enumerate(buckets):
        mgr.submit(WorkItem(key=f"bucket{i}", fn=lambda b=b: execute_bucket(b)))
    per_bucket = mgr.run(n_workers, expected=len(buckets))
    out: Dict[int, Any] = {}
    for v in per_bucket.values():
        if isinstance(v, Exception):
            raise v
        out.update(v)
    return out

"""Manager-Worker demand-driven runtime (paper §II: RTF execution model),
with the fault-tolerance features a 1000-node deployment needs:

* demand-driven dispatch — Workers pull the next bucket when free (natural
  load balancing, same as the paper's 92%-efficiency runs);
* heartbeats + retry — a bucket whose Worker misses its heartbeat deadline
  is re-enqueued (at-least-once; results are idempotent because tasks are
  pure functions of (input, params)); the deadline adapts to observed
  bucket times so a long-running bucket (e.g. a first-time jit compile) is
  not mistaken for a dead Worker;
* straggler mitigation — when the queue is empty and a bucket has been
  running longer than ``straggler_factor`` × the median bucket time, a
  backup copy is launched on an idle Worker; first completion wins (the
  classic demand-driven tail-cloning trick);
* elastic scaling — Workers can join/leave between buckets; the Manager
  only tracks outstanding leases.

Sessions are **long-lived** (DESIGN.md §10): ``start`` spawns the Worker
pool once, ``submit`` is legal while Workers are running (including from a
completion callback on a Worker thread), ``drain`` blocks until every
submitted item has a result, and ``close`` retires the pool. The one-shot
``run`` wrapper keeps the original batch semantics on top of the same
machinery. Per-item completion callbacks fire exactly once per key — on the
*first* completion, under the same lock that records the result — so a
raced straggler backup can never double-report; the callback body itself
runs outside the lock so it may re-enter ``submit`` (how the streaming
executor chains per-input stage edges).

Workers here are threads driving real JAX execution (the container is one
node); across real nodes the same Manager logic fronts an RPC boundary —
the scheduling semantics are identical, which is what the fig8 benchmark
models at 256 nodes.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["WorkItem", "Manager", "run_study_distributed"]

# How long an idle Worker sleeps between wake-up checks; bounds the latency
# of straggler/heartbeat detection while the queue is empty.
_IDLE_TICK = 0.02


@dataclasses.dataclass
class WorkItem:
    key: str
    fn: Callable[[], Any]
    attempts: int = 0
    started_at: Optional[float] = None
    worker: Optional[int] = None
    # Called exactly once, as fn's first completion (or permanent failure,
    # with the Exception as the value) is recorded. Runs on the completing
    # Worker's thread, outside the Manager lock.
    callback: Optional[Callable[[str, Any], None]] = None


class Manager:
    # Total Worker-pool sessions ever started in this process; the
    # differential suite uses deltas of this to prove execute_study spins up
    # ONE session per study instead of one per stage×input.
    sessions_started = 0

    def __init__(
        self,
        *,
        max_attempts: int = 3,
        heartbeat_timeout: float = 60.0,
        straggler_factor: float = 3.0,
        enable_backup_tasks: bool = True,
    ):
        self._queue: "collections.deque[WorkItem]" = collections.deque()
        self._results: Dict[str, Any] = {}
        self._running: Dict[str, WorkItem] = {}
        self._attempt_seq: Dict[str, int] = {}  # highest attempt # issued per key
        self._callbacks: Dict[str, Callable[[str, Any], None]] = {}
        self._pending: set = set()  # keys submitted, no result yet
        # Keys forgotten while still holding a lease: their bookkeeping is
        # kept for first-completion-wins dedup and released when the last
        # lease settles (drained in _settle), so a long-lived fleet session
        # stays bounded even when forget() races in-flight attempts.
        self._deferred_forget: set = set()
        # Recent-window of winning-attempt durations for the straggler /
        # heartbeat heuristics: bounded so a session spanning thousands of
        # inputs never grows the median computation, with the sorted median
        # cached between appends (idle workers poll it every tick).
        self._durations: "collections.deque[float]" = collections.deque(maxlen=512)
        self._median_cache: Optional[float] = None
        self._busy_total = 0.0  # lifetime sum (the efficiency numerator)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._threads: List[threading.Thread] = []
        self._closed = False
        self.max_attempts = max_attempts
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.enable_backup_tasks = enable_backup_tasks
        self.retries = 0
        self.backups_launched = 0
        self.heartbeat_expiries = 0

    @property
    def is_running(self) -> bool:
        """True between ``start`` and ``close`` — i.e. the session can
        accept submissions and execute them."""
        return bool(self._threads)

    @property
    def busy_seconds(self) -> float:
        """Sum of winning-attempt wall-times — the useful-work numerator of
        the parallel-efficiency accounting."""
        with self._lock:
            return self._busy_total

    def _record_duration_locked(self, dur: float) -> None:
        self._durations.append(dur)
        self._busy_total += dur
        self._median_cache = None

    def _median_locked(self) -> Optional[float]:
        if not self._durations:
            return None
        if self._median_cache is None:
            ordered = sorted(self._durations)
            self._median_cache = ordered[len(ordered) // 2]
        return self._median_cache

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def start(self, n_workers: int) -> None:
        """Spawn the Worker pool. One session may span many stages and many
        inputs; submitting while Workers run is the intended usage."""
        if self._threads:
            raise RuntimeError("Manager session already started")
        self._closed = False
        Manager.sessions_started += 1
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(max(1, n_workers))
        ]
        for t in self._threads:
            t.start()

    def submit(self, item: WorkItem) -> None:
        """Enqueue work; legal before ``start`` and while Workers run.
        Re-submitting a key that already has a result is a no-op."""
        with self._cond:
            if self._closed:
                raise RuntimeError("Manager session is closed")
            if item.key in self._results:
                return
            if item.callback is not None:
                self._callbacks[item.key] = item.callback
            self._pending.add(item.key)
            self._queue.append(item)
            self._cond.notify()

    def drain(self) -> None:
        """Block until every submitted key has a result (success or
        permanent failure). Workers stay alive — more work may follow."""
        with self._cond:
            while self._pending:
                self._cond.wait(_IDLE_TICK)

    def close(self) -> None:
        """Retire the Worker pool (waits for in-flight attempts to return)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []

    def results(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self._results)

    def forget(self, keys) -> None:
        """Release memoised results + attempt bookkeeping for keys whose
        lifecycle is over (drained, consumed). A long-lived session would
        otherwise retain every settled WorkItem's value for its whole life
        — the streaming executor calls this per study when sharing a
        session across an adaptive study's rounds.

        Two races are closed under the lock: stale queued duplicates of a
        forgotten key (heartbeat-expiry re-enqueues) are purged — without
        their memoised result they would re-execute — and a key whose
        losing attempt (straggler backup / presumed-dead original) still
        holds a lease keeps its result, so the late completion dedups via
        first-completion-wins instead of resurrecting a value. Such keys
        join the deferred-forget set and are released when their last lease
        settles — previously they leaked for the session's lifetime."""
        with self._cond:
            keyset = set(keys)
            if not keyset:
                return
            self._queue = collections.deque(
                it for it in self._queue if it.key not in keyset
            )
            leased = {it.key for it in self._running.values()}
            self._deferred_forget |= keyset & leased
            for k in keyset - leased:
                self._results.pop(k, None)
                self._attempt_seq.pop(k, None)
                self._callbacks.pop(k, None)

    def _drain_deferred_locked(self, key: str) -> None:
        """Release a deferred-forgotten key's bookkeeping once its LAST
        lease has been returned (caller holds the lock and has already
        popped its own lease). While any other attempt is still in flight
        the memoised result must survive so the late completion dedups."""
        if key not in self._deferred_forget:
            return
        if any(it.key == key for it in self._running.values()):
            return
        self._deferred_forget.discard(key)
        self._results.pop(key, None)
        self._attempt_seq.pop(key, None)
        self._callbacks.pop(key, None)

    # ------------------------------------------------------------------
    # Worker protocol
    # ------------------------------------------------------------------
    def _next_locked(self, worker_id: int) -> Optional[WorkItem]:
        # Dequeue and lease registration are atomic under one lock: a peer
        # observing (queue empty, no leases) under that lock can therefore
        # conclude the system is idle — there is no window where an item has
        # left the queue but is not yet visible in ``_running``. Items whose
        # key already has a result (a raced retry/backup) are dropped here,
        # before any lease exists, so they can never leak one.
        while True:
            if not self._queue:
                item = self._maybe_backup_locked()
                if item is None:
                    return None
                break
            item = self._queue.popleft()
            if item.key not in self._results:
                break
        item.started_at = time.monotonic()
        item.worker = worker_id
        # attempt numbers are issued centrally so concurrent attempts of
        # one key (original + backup) always hold distinct leases
        item.attempts = self._attempt_seq.get(item.key, 0) + 1
        self._attempt_seq[item.key] = item.attempts
        self._running[f"{item.key}#{item.attempts}"] = item
        return item

    def _expire_heartbeats_locked(self) -> None:
        """Re-enqueue leases whose Worker missed the heartbeat deadline
        (a Worker death mid-lease). The lease is released; if the presumed-
        dead attempt does return later, first-completion-wins dedups it.

        In-process Workers cannot heartbeat while inside a task fn, so a
        long bucket is indistinguishable from a dead Worker by age alone.
        The deadline therefore adapts to observed bucket times — ``max(
        heartbeat_timeout, straggler_factor × median)`` — and with no
        completed-bucket history yet (e.g. the first bucket is a multi-
        minute jit compile) nothing is ever expired."""
        median = self._median_locked()
        if median is None:
            return
        deadline = max(self.heartbeat_timeout, self.straggler_factor * median)
        now = time.monotonic()
        for lease, it in list(self._running.items()):
            if it.key in self._results:
                continue
            started = it.started_at or now
            if now - started <= deadline:
                continue
            if self._attempt_seq.get(it.key, 0) >= self.max_attempts:
                continue
            del self._running[lease]
            self.heartbeat_expiries += 1
            self.retries += 1
            self._queue.append(WorkItem(key=it.key, fn=it.fn))
            self._cond.notify()

    def _maybe_backup_locked(self) -> Optional[WorkItem]:
        """Clone the longest-running bucket if it looks like a straggler.
        Caller holds ``self._lock``. At most one backup of a key is in
        flight at a time: while original + clone both run, the key holds two
        leases and is skipped."""
        if not self.enable_backup_tasks:
            return None
        if not self._running or len(self._durations) < 2:
            return None
        median = self._median_locked()
        now = time.monotonic()
        candidates = [
            it
            for it in self._running.values()
            if it.key not in self._results
            and sum(1 for other in self._running.values() if other.key == it.key) < 2
            and self._attempt_seq.get(it.key, 0) < self.max_attempts
        ]
        if not candidates:
            return None
        worst = max(candidates, key=lambda it: now - (it.started_at or now))
        age = now - (worst.started_at or now)
        if age > self.straggler_factor * max(median, 1e-3):
            self.backups_launched += 1
            return WorkItem(key=worst.key, fn=worst.fn)
        return None

    def _settle(self, item: WorkItem, value: Any) -> None:
        """Record a final value (result or permanent failure) for a key and
        fire its callback exactly once. The key stays in ``_pending`` until
        the callback returns, so ``drain`` cannot observe a momentarily-empty
        pending set while a callback is still about to submit downstream
        work (the per-input stage edge of the streaming executor)."""
        cb = None
        won = False
        with self._cond:
            self._running.pop(f"{item.key}#{item.attempts}", None)
            if item.key not in self._results:  # first completion wins
                won = True
                self._results[item.key] = value
                if item.started_at is not None and not isinstance(value, Exception):
                    self._record_duration_locked(time.monotonic() - item.started_at)
                cb = self._callbacks.pop(item.key, None)
            self._drain_deferred_locked(item.key)
            self._cond.notify_all()
        if not won:  # raced duplicate: the winner owns callback + pending
            return
        try:
            if cb is not None:
                cb(item.key, value)
        finally:
            with self._cond:
                self._pending.discard(item.key)
                self._cond.notify_all()

    def _fail(self, item: WorkItem, err: Exception) -> None:
        # Lease release and re-enqueue happen under one lock so peers never
        # observe (queue empty, no leases) while a retry is still in flight.
        with self._cond:
            if item.attempts < self.max_attempts and item.key not in self._results:
                self._running.pop(f"{item.key}#{item.attempts}", None)
                self.retries += 1
                # attempt numbers are issued by _next_locked at lease time
                self._queue.append(WorkItem(key=item.key, fn=item.fn))
                self._cond.notify()
                return
        self._settle(item, err)

    def _worker(self, worker_id: int) -> None:
        while True:
            with self._cond:
                item = self._next_locked(worker_id)
                if item is None:
                    self._expire_heartbeats_locked()
                    item = self._next_locked(worker_id)
                if item is None:
                    if self._closed and not self._pending:
                        return
                    self._cond.wait(_IDLE_TICK)
                    continue
            if item.key in self._results:
                with self._lock:  # bucket completed after we leased: release
                    self._running.pop(f"{item.key}#{item.attempts}", None)
                    self._drain_deferred_locked(item.key)
                continue
            try:
                value = item.fn()
            except Exception as e:  # noqa: BLE001 — retry path
                self._fail(item, e)
            else:
                self._settle(item, value)

    # ------------------------------------------------------------------
    # One-shot batch mode (the pre-streaming API, kept verbatim)
    # ------------------------------------------------------------------
    def run(self, n_workers: int, *, expected: int) -> Dict[str, Any]:
        """Run until ``expected`` distinct results exist."""
        self.start(n_workers)
        try:
            with self._cond:
                while len(self._results) < expected and self._pending:
                    self._cond.wait(_IDLE_TICK)
        finally:
            self.close()
        return dict(self._results)


def run_study_distributed(
    buckets: List[Any],
    execute_bucket: Callable[[Any], Dict[int, Any]],
    *,
    n_workers: int = 2,
    manager: Optional[Manager] = None,
) -> Dict[int, Any]:
    """Execute merged-stage buckets across Workers; returns run_id -> output."""
    mgr = manager or Manager()
    for i, b in enumerate(buckets):
        mgr.submit(WorkItem(key=f"bucket{i}", fn=lambda b=b: execute_bucket(b)))
    per_bucket = mgr.run(n_workers, expected=len(buckets))
    out: Dict[int, Any] = {}
    for v in per_bucket.values():
        if isinstance(v, Exception):
            raise v
        out.update(v)
    return out

"""Elastic scaling: resume a run on a smaller (or larger) mesh.

Checkpoints are mesh-agnostic (full logical arrays + manifest;
checkpoint/checkpointer.py), and every sharding in dist/sharding.py is a
*function of the mesh*, so after losing a pod the surviving processes:

  1. rebuild a mesh from the surviving devices (make_mesh_from_devices),
  2. re-derive param/opt shardings for the new mesh (param_shardings),
  3. restore the checkpoint and device_put onto the new shardings,
  4. resume the step function — recompiled for the new topology.

``reshard_tree`` is the core primitive; it also serves scale-UP (new pods
join) and mesh-shape changes (e.g. trading 'data' for 'model' when the
per-chip memory budget changes after a down-size).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.dist.sharding import make_ctx, param_shardings

__all__ = ["reshard_tree", "resume_on_mesh"]


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """device_put a pytree onto new shardings (no-op leaves for None)."""
    if shardings is None:
        return tree
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def resume_on_mesh(checkpointer, template: Any, mesh, *, mode: str = "train",
                   step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore the latest checkpoint and place it on ``mesh``."""
    restored, meta = checkpointer.restore(template, step=step)
    ctx = make_ctx(mesh, mode=mode)
    sh = param_shardings(restored, ctx)
    return reshard_tree(restored, sh), meta

"""Hierarchical storage for inter-stage data objects (paper §II: RAM and
disk tiers managed by the runtime; stages communicate by reading/writing
data objects rather than messaging).

The RAM tier is capacity-bounded; overflowing objects spill to the disk tier
(npz files). Disk filenames are **content-addressed** — the sha256 of the
(deterministically serialised) key — so a store re-opened on the same
directory by a *different process* resolves the same keys to the same files
(Python's built-in ``hash`` is salted per process and is useless here).
This is what lets a resumed SA study (``repro.study.StudyState``) rehydrate
prior-round results instead of recomputing them.

The RMSR schedule exists precisely to keep the working set inside the RAM
tier — the paper notes that spilling every task output of a fine-grain stage
costs more than recomputing (§III), which is why memory-bounded scheduling
beats a disk cache for *intra-round* traffic; the disk tier earns its keep
across rounds and process restarts, where recomputation would repeat whole
stages.
"""

from __future__ import annotations

import collections
import hashlib
import pathlib
import tempfile
import threading
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["HierarchicalStore", "stable_key"]


def stable_key(key: Any) -> str:
    """Deterministic content address of a (possibly nested-tuple) key.

    ``repr`` of the canonical key types used by the engine cache — strings,
    ints, floats, bools and tuples thereof — is stable across processes,
    unlike ``hash``. sha256 keeps filenames short and collision-free.
    """
    return hashlib.sha256(repr(key).encode()).hexdigest()


class HierarchicalStore:
    """RAM tier (LRU, byte-bounded) over a content-addressed npz disk tier.

    ``hits`` counts RAM-tier hits, ``disk_hits`` disk-tier rehydrations,
    ``misses`` keys found in neither tier, ``spills`` RAM→disk evictions.
    """

    def __init__(self, ram_bytes: int = 1 << 30, disk_dir: Optional[str] = None):
        self.ram_bytes = ram_bytes
        self._ram: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._used = 0
        self._disk = pathlib.Path(disk_dir or tempfile.mkdtemp(prefix="rtf_store_"))
        self._disk.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self.spills = 0
        self.hits = 0
        self.disk_hits = 0
        self.misses = 0

    @property
    def disk_dir(self) -> str:
        return str(self._disk)

    @staticmethod
    def _nbytes(obj: Any) -> int:
        if hasattr(obj, "nbytes"):
            return int(obj.nbytes)
        if isinstance(obj, dict):
            return sum(HierarchicalStore._nbytes(v) for v in obj.values())
        return 64

    def _path(self, key: str) -> pathlib.Path:
        return self._disk / f"{stable_key(key)}.npz"

    def put(self, key: str, obj: Any) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
            size = self._nbytes(obj)
            self._evict_for(size)
            self._ram[key] = obj
            self._ram.move_to_end(key)
            self._sizes[key] = size
            self._used += size

    def _write_disk(self, key: str, v: Any) -> None:
        path = self._path(key)
        if isinstance(v, dict):
            np.savez(path, **{kk: np.asarray(vv) for kk, vv in v.items()})
        else:
            np.savez(path, __value__=np.asarray(v))
        (self._disk / f"{stable_key(key)}.key").write_text(key)

    def _evict_for(self, incoming: int) -> None:
        while self._used + incoming > self.ram_bytes and self._ram:
            k, v = self._ram.popitem(last=False)  # LRU
            self._used -= self._sizes.pop(k)
            self.spills += 1
            self._write_disk(k, v)

    def persist(self, key: str) -> None:
        """Write a RAM-resident object to the disk tier without evicting it
        (a durability flush, e.g. before a StudyState checkpoint)."""
        with self._lock:
            if key in self._ram:
                self._write_disk(key, self._ram[key])

    def persist_all(self) -> None:
        """Write every RAM-resident object to the disk tier (durability
        barrier: after this, a store re-opened on the directory resolves
        everything this one holds)."""
        with self._lock:
            for k, v in self._ram.items():
                self._write_disk(k, v)

    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._ram or self._path(key).exists()

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            if key in self._ram:
                self.hits += 1
                self._ram.move_to_end(key)
                return self._ram[key]
            path = self._path(key)
            if path.exists():
                self.disk_hits += 1
                with np.load(path) as z:
                    if "__value__" in z:
                        value: Any = z["__value__"]
                    else:
                        value = {k: z[k] for k in z.files}
                # promote into the (LRU-bounded) RAM tier: a hot spilled
                # entry must not pay deserialisation on every read
                size = self._nbytes(value)
                self._evict_for(size)
                self._ram[key] = value
                self._sizes[key] = size
                self._used += size
                return value
            self.misses += 1
            return None

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
            path = self._path(key)
            if path.exists():
                path.unlink()

    @property
    def used_bytes(self) -> int:
        return self._used

"""Hierarchical storage for inter-stage data objects (paper §II: RAM and
disk tiers managed by the runtime; stages communicate by reading/writing
data objects rather than messaging).

The RAM tier is capacity-bounded; overflowing objects spill to the disk tier
(npz files). Disk filenames are **content-addressed** — the sha256 of the
(deterministically serialised) key — so a store re-opened on the same
directory by a *different process* resolves the same keys to the same files
(Python's built-in ``hash`` is salted per process and is useless here).
This is what lets a resumed SA study (``repro.study.StudyState``) rehydrate
prior-round results instead of recomputing them.

Crash safety (DESIGN.md §12): every disk write goes to a ``.tmp`` sibling,
is fsynced, and lands via ``os.replace`` — a killed writer can leave only
an orphaned ``.tmp``, never a truncated entry under the final name. Each
entry additionally carries a fixed-size footer (magic + payload length +
sha256) verified on load; an entry failing verification — however it got
there — is *quarantined* (moved aside), counted on the ``corrupt`` counter
and reported as a miss, so a poisoned directory self-heals by recomputing.

:class:`SharedStore` layers cross-process coordination on top: a per-key
advisory file lock (``fcntl.flock``) so N writers over one directory never
double-write an entry, and an append-only last-writer-wins manifest
(``manifest.jsonl``) recording every committed key for audit/accounting —
the fleet runner (``repro.study.run_fleet_study``) mounts one SharedStore
per process; each round's delta plans against the union of every worker's
TrieLedger entries, and the store serves the corresponding outputs.

The RMSR schedule exists precisely to keep the working set inside the RAM
tier — the paper notes that spilling every task output of a fine-grain stage
costs more than recomputing (§III), which is why memory-bounded scheduling
beats a disk cache for *intra-round* traffic; the disk tier earns its keep
across rounds and process restarts, where recomputation would repeat whole
stages.
"""

from __future__ import annotations

import collections
import contextlib
import hashlib
import io
import json
import os
import pathlib
import pickle
import struct
import tempfile
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional, Set, Tuple

import numpy as np

try:  # advisory file locks are POSIX-only; SharedStore degrades gracefully
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None  # type: ignore[assignment]

__all__ = [
    "AsyncCommitQueue",
    "HierarchicalStore",
    "SharedStore",
    "mount_store",
    "stable_key",
]

# Entry footer: | payload bytes | magic (8) | payload length (8, LE) |
# sha256(payload) (32) |. The payload is a complete npz archive; loads slice
# it back out, so nothing ever parses the footer as zip data.
_FOOTER_MAGIC = b"RTFSTRv1"
_FOOTER_SIZE = len(_FOOTER_MAGIC) + 8 + 32

_QUARANTINE_DIR = "quarantine"


def stable_key(key: Any) -> str:
    """Deterministic content address of a (possibly nested-tuple) key.

    ``repr`` of the canonical key types used by the engine cache — strings,
    ints, floats, bools and tuples thereof — is stable across processes,
    unlike ``hash``. sha256 keeps filenames short and collision-free.
    """
    return hashlib.sha256(repr(key).encode()).hexdigest()


def mount_store(
    spec: Optional[str],
    ram_bytes: int,
    *,
    writer_id: Optional[str] = None,
) -> "HierarchicalStore":
    """Resolve a store SPEC into a mounted cross-process store.

    ``None`` or a plain directory path mounts the flock-coordinated
    :class:`SharedStore` on that directory (the single-host default);
    ``"obj:<root>"`` mounts the object-store tier — an
    :class:`~repro.runtime.objstore.ObjectBackedStore` over a
    :class:`~repro.runtime.objstore.LocalFSObjectStore` rooted at
    ``<root>`` — which needs no shared filesystem semantics beyond the
    object API (DESIGN.md §16). The spec is a plain string, so it crosses
    spawn and TCP boundaries verbatim: RPC and socket workers mount
    exactly the tier the leader named. Every mounted store exposes the
    spec back as ``.disk_dir``, so a recorded mount re-resolves here.
    """
    if spec is not None and spec.startswith("obj:"):
        from repro.runtime.objstore import LocalFSObjectStore, ObjectBackedStore

        root = spec[len("obj:"):]
        if not root:
            raise ValueError(f"object store spec names no root: {spec!r}")
        return ObjectBackedStore(
            ram_bytes,
            LocalFSObjectStore(root),
            spec=spec,
            writer_id=writer_id,
        )
    return SharedStore(ram_bytes, disk_dir=spec, writer_id=writer_id)


def _serialise(v: Any) -> bytes:
    """npz for array payloads (dicts of str→array, arrays); a pickle
    fallback — stored as a uint8 array under ``__pickled__`` so the entry
    stays a plain npz archive — for everything else. The fallback is what
    lets RPC worker results (arbitrary Python values, dicts keyed by int
    run_id) cross the store **bit-exactly**: coercing a Python int through
    ``np.asarray`` would silently wrap at 64 bits, which the conformance
    suite's collision-sensitive integer workloads would detect."""
    def _is_array(x: Any) -> bool:
        # genuinely array-like only (ndarray / jnp / np scalar): coercing a
        # Python scalar through np.asarray would change its type (and wrap
        # a large int), breaking the bit-exact round-trip contract
        return isinstance(x, np.ndarray) or hasattr(x, "__array__")

    buf = io.BytesIO()
    if isinstance(v, dict) and v and all(isinstance(k, str) for k in v):
        if all(_is_array(vv) for vv in v.values()):
            arrs = {kk: np.asarray(vv) for kk, vv in v.items()}
            if not any(a.dtype.hasobject for a in arrs.values()):
                np.savez(buf, **arrs)
                return buf.getvalue()
    elif _is_array(v):
        a = np.asarray(v)
        if not a.dtype.hasobject:
            np.savez(buf, __value__=a)
            return buf.getvalue()
    blob = pickle.dumps(v, protocol=pickle.HIGHEST_PROTOCOL)
    np.savez(buf, __pickled__=np.frombuffer(blob, dtype=np.uint8))
    return buf.getvalue()


def _pack_entry(payload: bytes) -> bytes:
    return (
        payload
        + _FOOTER_MAGIC
        + struct.pack("<Q", len(payload))
        + hashlib.sha256(payload).digest()
    )


def _has_footer_magic(data: bytes) -> bool:
    return (
        len(data) >= _FOOTER_SIZE
        and data[-_FOOTER_SIZE:][:8] == _FOOTER_MAGIC
    )


def _probe_footer(path: pathlib.Path) -> str:
    """Classify an on-disk entry by its footer WITHOUT reading the payload
    (the shared primitive under both the read-side ``contains`` probe and
    the write-side commit probe): ``"missing"`` (unreadable/absent),
    ``"short"`` (smaller than a footer — no real npz is), ``"legacy"``
    (no magic: a pre-footer entry, np.load is its verifier), ``"bad-length"``
    (magic present, recorded length disagrees with file size: torn), or
    ``"ok"`` (footer structurally valid; the digest is checked on load)."""
    try:
        size = path.stat().st_size
        if size < _FOOTER_SIZE:
            return "short"
        with open(path, "rb") as f:
            f.seek(size - _FOOTER_SIZE)
            footer = f.read(_FOOTER_SIZE)
    except OSError:
        return "missing"
    if footer[:8] != _FOOTER_MAGIC:
        return "legacy"
    (length,) = struct.unpack("<Q", footer[8:16])
    return "ok" if length + _FOOTER_SIZE == size else "bad-length"


def _footer_ok(data: bytes) -> Optional[bytes]:
    """Return the verified payload of a footered entry, or None if ``data``
    is not a well-formed (length- and digest-checked) entry."""
    if not _has_footer_magic(data):
        return None
    payload, footer = data[:-_FOOTER_SIZE], data[-_FOOTER_SIZE:]
    (length,) = struct.unpack("<Q", footer[8:16])
    if length != len(payload):
        return None
    if hashlib.sha256(payload).digest() != footer[16:]:
        return None
    return payload


class AsyncCommitQueue:
    """In-memory staging tier + background flusher in front of a store
    (DESIGN.md §14: the RPC backend's async commit fast path).

    ``stage(key, value)`` records the value in the staging dict and enqueues
    it; a daemon flusher thread drains the queue into the store through the
    existing crash-safe protocol (``put`` + ``persist`` — serialise → tmp
    sibling → fsync → atomic rename → footer-verified entry), then drops the
    staged copy. Between ``stage`` and the flush landing, ``peek`` serves
    the value from memory — the read-your-writes window the RPC leader uses
    to answer worker fetches for not-yet-durable upstream results.

    ``barrier()`` blocks until everything staged so far is durably
    committed (the ``drain()``/``StudyState.save`` durability call): after
    it returns, a store re-opened on the directory resolves every staged
    key. A flush failure is counted (``errors``) and the entry is dropped
    from staging so the barrier can never hang on a poisoned value —
    durability degrades to the lease-retry path (tasks are pure; a
    recompute republishes the same bytes).
    """

    def __init__(self, store: "HierarchicalStore"):
        self._store = store
        self._staged: Dict[str, Any] = {}  # guard: _lock
        self._queue: "collections.deque[str]" = collections.deque()  # guard: _lock
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._closed = False  # guard: _lock
        self.staged = 0  # guard: _lock
        self.committed = 0  # guard: _lock
        self.errors = 0  # guard: _lock
        self.staged_peak = 0  # guard: _lock

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._drain_loop, name="rtf-flusher", daemon=True
            )
            self._thread.start()

    def stage(self, key: str, value: Any) -> None:
        """Record ``value`` for durable commit; returns immediately."""
        with self._cond:
            if self._closed:
                raise RuntimeError("AsyncCommitQueue is closed")
            self._staged[key] = value
            self._queue.append(key)
            self.staged += 1
            self.staged_peak = max(self.staged_peak, len(self._staged))
            self._ensure_thread()
            self._cond.notify_all()

    def peek(self, key: str) -> Optional[Any]:
        """The staged-but-not-yet-durable value of ``key``, or None."""
        with self._lock:
            return self._staged.get(key)

    def pending(self) -> int:
        with self._lock:
            return len(self._staged)

    def _drain_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait(0.1)
                if not self._queue:
                    return  # closed and drained
                key = self._queue.popleft()
                value = self._staged.get(key)
            if value is not None:
                try:
                    self._store.put(key, value)
                    self._store.persist(key)
                    with self._cond:
                        self.committed += 1
                except BaseException:  # noqa: BLE001 — see class docstring
                    with self._cond:
                        self.errors += 1
            # drop the staged copy only after the disk commit (peek must
            # keep serving the value until the store can)
            with self._cond:
                self._staged.pop(key, None)
                self._cond.notify_all()

    def barrier(self, timeout: Optional[float] = None) -> bool:
        """Block until every staged entry is durably committed (or
        dropped after a flush failure). Returns False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._ensure_thread()
            while self._staged:
                if deadline is not None and time.monotonic() >= deadline:
                    return False
                self._cond.wait(0.05)
        return True

    def close(self, flush: bool = True, timeout: Optional[float] = None) -> None:
        """Retire the flusher; with ``flush`` (default) drains first.
        ``timeout`` bounds the drain — a flusher wedged inside a hung store
        write must not be able to hang a fleet teardown (the backend
        ``shutdown`` path passes one; the entries it abandons are staged
        pure values the lease-retry path can always recompute)."""
        if flush:
            self.barrier(timeout)
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)


class HierarchicalStore:
    """RAM tier (LRU, byte-bounded) over a content-addressed npz disk tier.

    ``hits`` counts RAM-tier hits, ``disk_hits`` disk-tier rehydrations,
    ``misses`` keys found in neither tier, ``spills`` RAM→disk evictions,
    ``corrupt`` disk entries that failed verification and were quarantined.
    """

    def __init__(self, ram_bytes: int = 1 << 30, disk_dir: Optional[str] = None):
        self.ram_bytes = ram_bytes
        self._ram: "collections.OrderedDict[str, Any]" = collections.OrderedDict()  # guard: _lock
        self._sizes: Dict[str, int] = {}  # guard: _lock
        self._used = 0  # guard: _lock
        self._disk = pathlib.Path(disk_dir or tempfile.mkdtemp(prefix="rtf_store_"))
        self._disk.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self.spills = 0  # guard: _lock
        self.hits = 0  # guard: _lock
        self.disk_hits = 0  # guard: _lock
        self.misses = 0  # guard: _lock
        self.corrupt = 0  # guard: _lock
        # Test/fault-injection hook: called with the tmp path after the tmp
        # file is written+fsynced but BEFORE os.replace publishes it — the
        # window a mid-write kill lands in. Raising here models the kill.
        self.fault_after_tmp_write: Optional[Callable[[pathlib.Path], None]] = None

    @property
    def disk_dir(self) -> str:
        return str(self._disk)

    @staticmethod
    def _nbytes(obj: Any) -> int:
        if hasattr(obj, "nbytes"):
            return int(obj.nbytes)
        if isinstance(obj, dict):
            return sum(HierarchicalStore._nbytes(v) for v in obj.values())
        return 64

    def _path(self, key: str) -> pathlib.Path:
        return self._disk / f"{stable_key(key)}.npz"

    def put(self, key: str, obj: Any) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
            size = self._nbytes(obj)
            evicted = self._evict_for(size)
            self._ram[key] = obj
            self._ram.move_to_end(key)
            self._sizes[key] = size
            self._used += size
        self._write_evicted(evicted)

    def _write_evicted(self, evicted) -> None:
        """Write spilled entries OUTSIDE the store lock (disk writes are
        fsync-heavy and, for SharedStore, flocked — holding the store-wide
        lock across them would serialize every reader). In the window
        between eviction and landing, a concurrent get() of an evicted key
        reads as a miss and recomputes — tasks are pure, so that is only
        wasted work, never a wrong value."""
        for k, v in evicted:
            self._write_disk(k, v)

    # ------------------------------------------------------------------
    # Crash-safe disk writes: tmp sibling + fsync + atomic rename
    # ------------------------------------------------------------------
    def _atomic_write(self, path: pathlib.Path, blob: bytes) -> None:
        """Publish ``blob`` under ``path`` atomically: a reader either sees
        the complete previous entry or the complete new one, never a
        truncation — a killed writer leaves only an orphaned ``.tmp``."""
        # pid+tid-unique: disk writes run outside the store lock, so two
        # threads may write the same key concurrently — each needs its own
        # tmp file or the loser's os.replace finds its tmp renamed away
        tmp = path.with_name(
            f"{path.name}.{os.getpid()}.{threading.get_ident()}.tmp"
        )
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        if self.fault_after_tmp_write is not None:
            self.fault_after_tmp_write(tmp)
        os.replace(tmp, path)
        self._fsync_dir()

    def _fsync_dir(self) -> None:
        try:
            dfd = os.open(self._disk, os.O_RDONLY)
        except OSError:  # pragma: no cover - platform without dir fds
            return
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def _write_disk(self, key: str, v: Any) -> None:
        self._atomic_write(self._path(key), _pack_entry(_serialise(v)))
        self._write_key_sidecar(key)

    def _write_key_sidecar(self, key: str) -> None:
        """Best-effort ``<sha>.key`` reverse-mapping for humans debugging a
        store directory; nothing reads it, so it gets a plain write (no
        tmp/fsync) and only once per key."""
        sidecar = self._disk / f"{stable_key(key)}.key"
        try:
            if not sidecar.exists():
                sidecar.write_text(key)
        except OSError:  # pragma: no cover - diagnostics only
            pass

    # ------------------------------------------------------------------
    # Verified disk reads + quarantine
    # ------------------------------------------------------------------
    def _maybe_quarantine(self, path: pathlib.Path) -> bool:
        """Move a failed-verification entry aside (never delete: the bytes
        are evidence); the key then reads as a miss and the next put
        republishes a good entry — the self-heal path. Re-verifies first:
        a peer may have replaced the bad file with a freshly committed good
        entry between our failed read and now, and quarantining THAT would
        lose a committed entry. Returns True only if a file was actually
        moved; callers count ``corrupt`` then. SharedStore overrides this
        to re-verify under the per-key write lock, closing the race
        completely."""
        return self._quarantine_if_still_bad(path)

    def _quarantine_if_still_bad(self, path: pathlib.Path) -> bool:
        try:
            data = path.read_bytes()
        except OSError:
            return False  # gone (peer quarantined or deleted it)
        if _footer_ok(data) is not None:
            return False  # repaired underneath us: keep it
        qdir = self._disk / _QUARANTINE_DIR
        try:
            qdir.mkdir(exist_ok=True)
            os.replace(path, qdir / f"{path.name}.{time.time_ns()}")
            return True
        except OSError:  # racing quarantiners: the loser's replace fails
            return False

    def _load_disk_unlocked(self, path: pathlib.Path) -> Tuple[str, Any]:
        """Load + verify one disk entry WITHOUT the store lock (callers
        update counters under it afterwards). Returns ``("ok", value)``,
        ``("missing", None)``, or — after quarantining the file —
        ``("corrupt", None)`` for truncation, bit-rot or zero-byte files.

        An entry carrying the footer magic must pass length+sha; a
        footer-less file is a **legacy** (pre-footer) entry, for which
        ``np.load`` itself is the verifier — a torn legacy write fails to
        parse and is quarantined, a complete one is accepted, so a store
        directory written before the footer protocol still resumes with
        zero recomputation. The legacy path never applies to footered
        entries: a bit-flipped payload could still parse, so a failed
        digest is final."""
        for _ in range(3):  # retry when a peer repairs the entry under us
            try:
                data = path.read_bytes()
            except OSError:
                return "missing", None
            if _has_footer_magic(data):
                payload = _footer_ok(data)
                if payload is None:
                    if self._maybe_quarantine(path):
                        return "corrupt", None
                    continue  # entry changed since our read: re-read
            else:
                payload = data  # legacy entry: parse failure == corrupt
            try:
                with np.load(io.BytesIO(payload)) as z:
                    if "__pickled__" in z:
                        return "ok", pickle.loads(z["__pickled__"].tobytes())
                    if "__value__" in z:
                        return "ok", z["__value__"]
                    return "ok", {k: z[k] for k in z.files}
            except Exception:  # noqa: BLE001 — parse failure is corruption
                if self._maybe_quarantine(path):
                    return "corrupt", None
                continue
        return "corrupt", None  # kept changing underneath us: give up

    def _disk_entry_ok(self, path: pathlib.Path) -> bool:
        """Cheap existence+integrity probe for ``contains`` (runs OUTSIDE
        the store lock — it touches the filesystem): footer magic +
        recorded length vs file size (no digest). Quarantines on failure so
        ``contains`` never reports a torn entry as present. A footer-less
        file big enough to be a legacy npz is reported present
        optimistically — ``get`` fully validates."""
        status = _probe_footer(path)
        if status == "ok":
            return True
        if status == "legacy":
            return True  # pre-footer entry: np.load verifies on get
        if status == "missing":
            return False
        # "short" / "bad-length": a torn entry — quarantine and report absent
        if self._maybe_quarantine(path):
            with self._lock:
                self.corrupt += 1
        return False

    def _evict_for(self, incoming: int):  # holds: _lock
        """LRU-evict under the caller-held store lock; returns the evicted
        ``(key, value)`` pairs for the caller to write to disk AFTER
        releasing the lock (see ``_write_evicted``)."""
        evicted = []
        while self._used + incoming > self.ram_bytes and self._ram:
            k, v = self._ram.popitem(last=False)  # LRU
            self._used -= self._sizes.pop(k)
            self.spills += 1
            evicted.append((k, v))
        return evicted

    def persist(self, key: str) -> None:
        """Write a RAM-resident object to the disk tier without evicting it
        (a durability flush, e.g. before a StudyState checkpoint)."""
        with self._lock:
            value = self._ram.get(key)
        if value is not None:
            self._write_disk(key, value)

    def persist_all(self) -> int:
        """Write every RAM-resident object to the disk tier (durability
        barrier: after this, a store re-opened on the directory resolves
        everything this one holds). The writes run outside the store lock —
        they are fsync-heavy and, for SharedStore, flocked. Returns the
        number of entries written through (for SharedStore an entry a peer
        already committed counts too: it is durable either way)."""
        with self._lock:
            snapshot = list(self._ram.items())
        for k, v in snapshot:
            self._write_disk(k, v)
        return len(snapshot)

    def contains(self, key: str) -> bool:
        with self._lock:
            if key in self._ram:
                return True
        # the disk probe (footer read, possibly a quarantine — for
        # SharedStore a flocked one) runs OUTSIDE the store lock: holding
        # it across file I/O would serialize every RAM-tier reader
        return self._disk_entry_ok(self._path(key))

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            if key in self._ram:
                self.hits += 1
                self._ram.move_to_end(key)
                return self._ram[key]
        # the disk load (read + digest + np.load) runs OUTSIDE the store
        # lock — holding it across file I/O would serialize every worker's
        # store consultation behind one rehydration
        status, value = self._load_disk_unlocked(self._path(key))
        with self._lock:
            if key in self._ram:  # raced: a peer thread promoted it first
                self.hits += 1
                self._ram.move_to_end(key)
                return self._ram[key]
            if status == "ok":
                self.disk_hits += 1
                # promote into the (LRU-bounded) RAM tier: a hot spilled
                # entry must not pay deserialisation on every read
                size = self._nbytes(value)
                evicted = self._evict_for(size)
                self._ram[key] = value
                self._sizes[key] = size
                self._used += size
            elif status == "corrupt":
                self.corrupt += 1
                self.misses += 1
            else:
                self.misses += 1
        if status == "ok":
            self._write_evicted(evicted)
            return value
        return None

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
        # the disk unlink runs OUTSIDE the store lock (same rationale as
        # _write_evicted); a concurrent reader of the doomed key sees the
        # entry or a miss, both of which it already had to handle
        self._path(key).unlink(missing_ok=True)

    @property
    def used_bytes(self) -> int:
        return self._used  # analysis: ok[locks] racy int read, diagnostics only

    def counters(self) -> Dict[str, int]:
        """Point-in-time counter snapshot (the RPC workers ship this in
        their heartbeat stats; study summaries aggregate it)."""
        with self._lock:
            return {
                "hits": self.hits,
                "disk_hits": self.disk_hits,
                "misses": self.misses,
                "spills": self.spills,
                "corrupt": self.corrupt,
            }


class SharedStore(HierarchicalStore):
    """A :class:`HierarchicalStore` that N processes can safely mount on ONE
    directory (DESIGN.md §12).

    Readers need no coordination: entries land via atomic rename, so a read
    sees a complete entry or nothing. Writers coordinate per key:

    * an advisory ``fcntl.flock`` on ``locks/<sha>.lock`` serialises writers
      of one key, and a writer that finds a valid committed entry under the
      lock skips its own write (``dedup_writes`` counter) — values are pure
      functions of the key, so the first committed entry is THE entry;
    * every commit appends one JSON line to ``manifest.jsonl`` (under the
      manifest lock, fsynced): ``{key, sha, len, writer, seq, ts}``. Replays
      are last-writer-wins, so the manifest is idempotent under retries and
      tolerates a torn final line (a killed appender). ``committed_keys()``
      folds it into the set of keys the directory serves — an audit /
      accounting view (the fleet runner reports it; round planning unions
      TrieLedger entries shipped in worker payloads, a different namespace
      from store keys). The entry files remain the ground truth: they
      self-verify on read.
    """

    def __init__(
        self,
        ram_bytes: int = 1 << 30,
        disk_dir: Optional[str] = None,
        *,
        writer_id: Optional[str] = None,
    ):
        super().__init__(ram_bytes, disk_dir)
        self.writer_id = writer_id or f"pid{os.getpid()}"
        self._locks_dir = self._disk / "locks"
        self._locks_dir.mkdir(exist_ok=True)
        self._manifest = self._disk / "manifest.jsonl"
        self._manifest_lockfile = self._disk / "manifest.lock"
        self._seq = 0
        self.dedup_writes = 0  # guard: _counters_lock (peer-committed write elisions)
        # shas this instance has itself committed (or seen committed): the
        # re-flush fast path — a repeated persist_all skips them without
        # even taking the flock. Guarded by its own lock because writes now
        # run outside the store-wide lock.
        self._persisted: Set[str] = set()  # guard: _counters_lock
        self._counters_lock = threading.Lock()

    @contextlib.contextmanager
    def _flock(self, path: pathlib.Path) -> Iterator[None]:
        if fcntl is None:  # pragma: no cover - non-POSIX fallback
            yield
            return
        fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield
        finally:
            # closing drops the flock; each acquisition opens a fresh fd, so
            # two threads of one process exclude each other too
            os.close(fd)

    def _key_lockfile(self, key: str) -> pathlib.Path:
        return self._locks_dir / f"{stable_key(key)}.lock"

    def _write_disk(self, key: str, v: Any) -> None:
        sha = stable_key(key)
        with self._counters_lock:
            if sha in self._persisted:
                return  # this instance already committed it; rename is final
        path = self._path(key)
        with self._flock(self._key_lockfile(key)):
            # strict commit probe: only a structurally-valid FOOTERED entry
            # counts as committed — legacy and torn files fail it and are
            # overwritten with a fresh footered entry (repair-on-write),
            # unlike the read path's optimistic legacy handling
            if _probe_footer(path) == "ok":
                # a peer committed first; values are pure functions of the
                # key, so ours is identical — elide the double-write
                with self._counters_lock:
                    self.dedup_writes += 1
                    self._persisted.add(sha)
                return
            blob = _pack_entry(_serialise(v))
            self._atomic_write(path, blob)
            self._write_key_sidecar(key)
            self._manifest_append(key, len(blob) - _FOOTER_SIZE)
        with self._counters_lock:
            self._persisted.add(sha)

    def _maybe_quarantine(self, path: pathlib.Path) -> bool:
        """Quarantine under the per-key write lock: with the flock held no
        peer can be mid-commit, so the re-verify inside
        ``_quarantine_if_still_bad`` conclusively distinguishes 'still the
        bad bytes' from 'a peer just repaired it' — a committed entry can
        never be swept into quarantine."""
        with self._flock(self._locks_dir / f"{path.stem}.lock"):
            did = self._quarantine_if_still_bad(path)
        if did:
            with self._counters_lock:
                self._persisted.discard(path.stem)
        return did

    def delete(self, key: str) -> None:
        super().delete(key)
        with self._counters_lock:
            self._persisted.discard(stable_key(key))

    def _manifest_append(self, key: str, payload_len: int) -> None:
        self._seq += 1
        line = (
            json.dumps(
                {
                    "key": key,
                    "sha": stable_key(key),
                    "len": payload_len,
                    "writer": self.writer_id,
                    "seq": self._seq,
                    "ts": time.time(),
                }
            )
            + "\n"
        )
        with self._flock(self._manifest_lockfile):
            with open(self._manifest, "a+b") as f:
                # A writer killed mid-append can leave a TORN final line
                # with no trailing newline. Appending straight after it
                # would merge our valid record onto the torn fragment,
                # producing one unparseable line — replay would then drop a
                # GOOD commit record, not just the torn one. Terminate the
                # fragment first so our record starts a fresh line.
                end = f.seek(0, os.SEEK_END)
                if end > 0:
                    f.seek(end - 1)
                    if f.read(1) != b"\n":
                        f.write(b"\n")
                f.write(line.encode())
                f.flush()
                os.fsync(f.fileno())

    def manifest_records(self) -> Dict[str, Dict[str, Any]]:
        """Fold the manifest into its last-writer-wins view: key → the most
        recent commit record. Unparseable lines (a torn final append from a
        killed writer) are skipped — the entry files themselves are the
        ground truth and self-verify on read."""
        records: Dict[str, Dict[str, Any]] = {}
        try:
            with self._flock(self._manifest_lockfile):
                text = self._manifest.read_text()
        except OSError:
            return records
        for line in text.splitlines():
            try:
                rec = json.loads(line)
                records[rec["key"]] = rec
            except (ValueError, KeyError, TypeError):
                continue
        return records

    def committed_keys(self) -> Set[str]:
        """Keys the directory's manifest says are committed — the basis of
        the fleet's cross-process ledger union."""
        return set(self.manifest_records())

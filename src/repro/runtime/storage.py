"""Hierarchical storage for inter-stage data objects (paper §II: RAM and
disk tiers managed by the runtime; stages communicate by reading/writing
data objects rather than messaging).

The RAM tier is capacity-bounded; overflowing objects spill to the disk tier
(npz files). The RMSR schedule exists precisely to keep the working set inside
the RAM tier — the paper notes that spilling every task output of a
fine-grain stage costs more than recomputing (§III), which is why memory-
bounded scheduling beats a disk cache.
"""

from __future__ import annotations

import collections
import pathlib
import tempfile
import threading
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["HierarchicalStore"]


class HierarchicalStore:
    def __init__(self, ram_bytes: int = 1 << 30, disk_dir: Optional[str] = None):
        self.ram_bytes = ram_bytes
        self._ram: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._used = 0
        self._disk = pathlib.Path(disk_dir or tempfile.mkdtemp(prefix="rtf_store_"))
        self._disk.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self.spills = 0
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _nbytes(obj: Any) -> int:
        if hasattr(obj, "nbytes"):
            return int(obj.nbytes)
        if isinstance(obj, dict):
            return sum(HierarchicalStore._nbytes(v) for v in obj.values())
        return 64

    def put(self, key: str, obj: Any) -> None:
        with self._lock:
            size = self._nbytes(obj)
            self._evict_for(size)
            self._ram[key] = obj
            self._ram.move_to_end(key)
            self._sizes[key] = size
            self._used += size

    def _evict_for(self, incoming: int) -> None:
        while self._used + incoming > self.ram_bytes and self._ram:
            k, v = self._ram.popitem(last=False)  # LRU
            self._used -= self._sizes.pop(k)
            self.spills += 1
            path = self._disk / f"{abs(hash(k))}.npz"
            if isinstance(v, dict):
                np.savez(path, **{kk: np.asarray(vv) for kk, vv in v.items()})
            else:
                np.savez(path, __value__=np.asarray(v))
            (self._disk / f"{abs(hash(k))}.key").write_text(k)

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            if key in self._ram:
                self.hits += 1
                self._ram.move_to_end(key)
                return self._ram[key]
            path = self._disk / f"{abs(hash(key))}.npz"
            if path.exists():
                self.misses += 1
                with np.load(path) as z:
                    if "__value__" in z:
                        return z["__value__"]
                    return {k: z[k] for k in z.files}
            return None

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._ram:
                self._used -= self._sizes.pop(key)
                del self._ram[key]
            path = self._disk / f"{abs(hash(key))}.npz"
            if path.exists():
                path.unlink()

    @property
    def used_bytes(self) -> int:
        return self._used

"""Hierarchy specs for the manager-of-managers scheduler (DESIGN.md §15).

The paper's headline efficiency (>92% at 256 nodes × 28 cores) is out of
reach for a single Manager pump thread: at that scale the pump — not the
workers — is the global serialization point. The companion deployments
(arXiv:1811.11653, arXiv:1612.03413) solve this with a demand-driven
manager *hierarchy*: a leader delegates contiguous blocks of work to N
sub-manager pumps, each owning a shard of the worker pool, with
locality-aware assignment and work stealing between pumps.

This module holds the declarative side of that design — the
:class:`HierarchySpec` dataclass, the ``parse_hierarchy`` spec grammar
(mirroring ``process_flag_kwargs`` for backends), and the reuse-tree
prefix matching used by locality-aware dispatch. The machinery itself
lives in :mod:`repro.runtime.manager`.

Spec grammar (the ``hierarchy=`` argument accepted throughout the engine)::

    None / "flat" / 1      -> flat: the single-pump Manager, byte-for-byte
    4                      -> 4 sub-manager pumps, locality + stealing on
    "4" / "fanout=4"       -> same
    "fanout=4,-steal"      -> 4 pumps, stealing disabled
    "fanout=2,-locality,block=16,steal_min=4"
    "auto"                 -> fanout resolved from the pool size at start()
    HierarchySpec(...)     -> passed through verbatim
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

__all__ = ["HierarchySpec", "parse_hierarchy", "path_lcp"]

# "auto" sizes one sub-pump per this many workers (capped below): small
# pools stay flat, big pools get enough pumps that no single one is the
# serialization point.
_AUTO_WORKERS_PER_PUMP = 8
_AUTO_MAX_FANOUT = 16


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """Topology + policy of the hierarchical scheduler.

    ``fanout``     — number of sub-manager pumps; 1 keeps the flat
                     single-pump Manager (the historical code path).
    ``locality``   — route work to the sub-manager/worker already holding
                     the longest reuse-tree prefix (per-worker affinity map
                     fed by Completion records).
    ``steal``      — an idle pump steals the tail half of the most loaded
                     peer's queue (exactly-once settlement is preserved:
                     items move between queues under the Manager lock and
                     only leave a queue when leased).
    ``block_size`` — contiguous lease block the leader delegates to one
                     sub-manager at a time (locality routing overrides).
    ``steal_min``  — never steal from a queue shorter than this.
    ``auto``       — resolve ``fanout`` from the worker-pool size at
                     ``start()`` (one pump per ~8 workers, capped at 16).
    """

    fanout: int = 1
    locality: bool = True
    steal: bool = True
    block_size: int = 8
    steal_min: int = 2
    auto: bool = False

    def resolve(self, n_workers: int) -> "HierarchySpec":
        """Concrete spec for a pool of ``n_workers``: auto-fanout is
        resolved and fanout is clamped so every pump owns ≥1 worker."""
        fanout = self.fanout
        if self.auto:
            fanout = max(1, n_workers // _AUTO_WORKERS_PER_PUMP)
            fanout = min(fanout, _AUTO_MAX_FANOUT)
        fanout = max(1, min(fanout, max(1, n_workers)))
        if fanout == self.fanout and not self.auto:
            return self
        return dataclasses.replace(self, fanout=fanout, auto=False)


def parse_hierarchy(spec: Any) -> HierarchySpec:
    """Normalise any accepted ``hierarchy=`` value to a HierarchySpec."""
    if spec is None:
        return HierarchySpec(fanout=1)
    if isinstance(spec, HierarchySpec):
        return spec
    if isinstance(spec, int):
        return HierarchySpec(fanout=max(1, spec))
    if not isinstance(spec, str):
        raise ValueError(
            f"hierarchy spec must be None, an int fanout, a string, or a "
            f"HierarchySpec; got {type(spec).__name__}"
        )
    text = spec.strip().lower()
    if text in ("", "flat"):
        return HierarchySpec(fanout=1)
    if text == "auto":
        return HierarchySpec(auto=True)
    try:  # bare numeric string, e.g. CLI "--hierarchy 4"
        return HierarchySpec(fanout=max(1, int(text)))
    except ValueError:
        pass
    kwargs: dict = {}
    for token in text.split(","):
        token = token.strip()
        if not token:
            continue
        if token == "-steal":
            kwargs["steal"] = False
        elif token == "+steal" or token == "steal":
            kwargs["steal"] = True
        elif token == "-locality":
            kwargs["locality"] = False
        elif token == "+locality" or token == "locality":
            kwargs["locality"] = True
        elif "=" in token:
            name, _, raw = token.partition("=")
            name = name.strip()
            try:
                value = int(raw)
            except ValueError:
                raise ValueError(
                    f"hierarchy spec {spec!r}: {name}={raw!r} is not an int"
                ) from None
            if name == "fanout":
                kwargs["fanout"] = max(1, value)
            elif name == "block":
                kwargs["block_size"] = max(1, value)
            elif name == "steal_min":
                kwargs["steal_min"] = max(1, value)
            else:
                raise ValueError(
                    f"hierarchy spec {spec!r}: unknown option {name!r}"
                )
        else:
            raise ValueError(
                f"hierarchy spec {spec!r}: unknown token {token!r}"
            )
    return HierarchySpec(**kwargs)


def path_lcp(a: Optional[Sequence[Any]], b: Optional[Sequence[Any]]) -> int:
    """Length of the longest common prefix of two reuse-tree paths (0 when
    either is missing/empty) — the locality metric of affinity dispatch."""
    if not a or not b:
        return 0
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def best_affinity(
    path: Optional[Tuple],
    affinities: Sequence[Optional[Tuple]],
) -> int:
    """Longest common prefix between ``path`` and any of ``affinities``."""
    if not path:
        return 0
    best = 0
    for aff in affinities:
        l = path_lcp(path, aff)
        if l > best:
            best = l
    return best

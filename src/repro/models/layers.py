"""Shared neural-net layers (functional style; params are pytrees of arrays).

Conventions:
  * params are stored fp32 ("master" precision), cast to bf16 for compute;
  * stacked per-layer weights carry a leading L dim and are consumed by
    ``lax.scan`` (small HLO, fast compile, weight-gather per layer — the
    MaxText pattern);
  * all shapes chosen so every weight dim that must shard is divisible by
    the mesh axes (see DESIGN.md §5 and configs/base.py padded_vocab).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16

__all__ = [
    "COMPUTE_DTYPE",
    "rms_norm",
    "rope_frequencies",
    "apply_rope",
    "swiglu",
    "dense_ffn",
    "normal_init",
    "cross_entropy",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def dense_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    dt = COMPUTE_DTYPE
    h = swiglu(x @ w_gate.astype(dt), x @ w_up.astype(dt))
    return h @ w_down.astype(dt)


def normal_init(key: jax.Array, shape: Tuple[int, ...], std: Optional[float] = None) -> jax.Array:
    """Fan-in-scaled normal init. Fan-in is the second-to-last dim (stacked
    per-layer weights carry leading L/E dims that must not affect scale)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = std if std is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(jnp.float32)


def cross_entropy(
    logits: jax.Array, labels: jax.Array, *, valid: Optional[jax.Array] = None,
    vocab_size: Optional[int] = None,
) -> jax.Array:
    """Mean token cross-entropy in fp32. ``vocab_size`` masks padded vocab
    entries (padded_vocab > vocab_size); ``valid`` masks positions."""
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < logits.shape[-1]:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), -1e9, dtype=jnp.float32)
        logits = logits + jnp.concatenate([jnp.zeros((vocab_size,)), neg])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if valid is None:
        return jnp.mean(nll)
    v = valid.astype(jnp.float32)
    return jnp.sum(nll * v) / jnp.maximum(jnp.sum(v), 1.0)

"""Attention for training/prefill (blocked streaming softmax) and decode
(full-cache masked, flash-decoding style under GSPMD).

Why blocked: dense S×S logits at prefill_32k would need tens of GB of
transient memory; the lax.scan-over-key-chunks formulation keeps the
transient at (B, H, Sq, chunk) while computing the same fp32-softmax result.
The per-layer ``window`` may be a *traced* scalar (gemma3's local:global
pattern scans layers with a per-layer window array), so masking is dynamic.

On TPU backends the static-window cases dispatch to the Pallas
FlashAttention-2 kernel (kernels/flash_attention.py) instead.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE

__all__ = ["blocked_attention", "decode_attention"]

_NEG = -1e30


def blocked_attention(
    q: jax.Array,           # (B, Sq, H, D)
    k: jax.Array,           # (B, Sk, KV, D)
    v: jax.Array,           # (B, Sk, KV, D)
    *,
    window,                 # int or traced scalar; full attention = Sk
    q_offset: int = 0,      # absolute position of q[0] (prefill continuation)
    prefix_len=0,           # bidirectional prefix (PaliGemma prefix-LM)
    chunk: int = 1024,
    unroll: bool = False,   # analysis mode: unroll the key-chunk scan
) -> jax.Array:
    """Causal (+ sliding-window / prefix-LM) attention with streaming
    softmax over key chunks; exact fp32 accumulation."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / (d**0.5)
    qpos = (jnp.arange(sq) + q_offset)[:, None]  # (Sq, 1)
    q32 = (q * scale).astype(COMPUTE_DTYPE)
    kc = k.reshape(b, n_chunks, chunk, kv, d)
    vc = v.reshape(b, n_chunks, chunk, kv, d)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, c_idx = xs  # kb/vb: (B, chunk, KV, D)
        kb = jnp.repeat(kb, rep, axis=2)
        vb = jnp.repeat(vb, rep, axis=2)
        logits = jnp.einsum(
            "bqhd,bkhd->bhqk", q32, kb.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        kpos = c_idx * chunk + jnp.arange(chunk)[None, :]  # (1, chunk)
        mask = (kpos <= qpos) | (kpos < prefix_len)
        mask &= kpos > qpos - window
        mask &= kpos < sk  # key padding
        logits = jnp.where(mask[None, None], logits, _NEG)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(COMPUTE_DTYPE), vb.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, h, sq), _NEG, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body,
        init,
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)),
        unroll=unroll,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, D)


def decode_attention(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KV, D)
    v_cache: jax.Array,
    cur_len,             # traced int: number of valid cache positions
    *,
    window,              # int or traced; full = S
) -> jax.Array:
    """One-token attention against the full cache. Under pjit the cache's
    sequence dim is sharded over 'model' (and 'data' when batch==1); GSPMD
    turns the masked softmax into the flash-decoding partial-softmax +
    combine pattern automatically."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    rep = h // kv
    scale = 1.0 / (d**0.5)
    kpos = jnp.arange(s)
    mask = (kpos < cur_len) & (kpos >= cur_len - window)
    # group q heads onto their kv head: h = kv * rep
    qg = q.reshape(b, 1, kv, rep, d)
    lg = jnp.einsum(
        "bqgrd,bkgd->bgrqk",
        (qg * scale).astype(COMPUTE_DTYPE),
        k_cache.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    )  # (B, KV, rep, 1, S)
    lg = jnp.where(mask[None, None, None, None, :], lg, _NEG)
    p = jax.nn.softmax(lg, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v_cache.astype(COMPUTE_DTYPE))
    return out.reshape(b, 1, h, d).astype(q.dtype)

"""State-space blocks: Mamba2 (SSD) and RWKV-6 (Finch) time mixing.

Both reduce to the diagonal-gated linear recurrence implemented by
``repro.kernels.ssm_scan`` (chunked, matmul-heavy — MXU-friendly):

    h_t = a_t ⊙ h_{t-1} + b_t ⊗ x_t ;   y_t = h_t^T c_t

Mamba2 uses a scalar-per-head decay a_t (broadcast over the state dim);
RWKV-6 uses a per-channel decay (a_t of shape (..., N)) plus the
first-occurrence bonus ``u`` readout. Decode steps update the recurrence
state directly (O(1) per token) — this is what makes these archs eligible
for the long_500k cell.

RWKV-6 note (DESIGN.md §2): we index the decay so that h_t = w_t·h_{t-1} +
k_t v_t (decay applied at the consuming step); this is the same recurrence
as the paper's wkv up to a one-step reindexing of w, with the current-token
bonus expressed as y += (u−1)⊙(r·k) v.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import COMPUTE_DTYPE, rms_norm

__all__ = [
    "mamba2_block",
    "mamba2_decode",
    "mamba2_init_cache",
    "rwkv6_block",
    "rwkv6_decode",
    "rwkv6_init_cache",
]


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

_CONV_K = 4


def _causal_conv(x: jax.Array, w: jax.Array, prev: Optional[jax.Array] = None):
    """Depthwise causal conv, kernel _CONV_K. x: (B, S, C); w: (K, C).
    ``prev``: (B, K-1, C) carry-in state. Returns (y, new_prev)."""
    b, s, c = x.shape
    if prev is None:
        prev = jnp.zeros((b, _CONV_K - 1, c), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + s, :] * w[i][None, None, :] for i in range(_CONV_K))
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -( _CONV_K - 1):, :]


def _mamba_project(x, p, cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n, h = cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"].astype(COMPUTE_DTYPE)
    z, xs, bc, cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32)))  # (B,S,H) decay
    return z, xs, bc, cc, dt, a


def mamba2_block(x: jax.Array, p: Dict[str, jax.Array], cfg, *, return_cache: bool = False, analysis: bool = False):
    """x: (B, S, D) -> (B, S, D). Train/prefill path (chunked scan).
    ``return_cache`` also returns the final recurrence/conv state (prefill)."""
    b, s, _ = x.shape
    h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z, xs, bc, cc, dt, a = _mamba_project(x, p, cfg)
    xs, conv_state = _causal_conv(xs, p["conv_w"].astype(COMPUTE_DTYPE))
    xh = xs.reshape(b, s, h, pdim)
    beff = bc[:, :, None, :] * dt[..., None]          # (B,S,H,N)
    ceff = jnp.broadcast_to(cc[:, :, None, :], (b, s, h, n))
    y, hfinal = kops.ssm_scan(
        xh, a, beff.astype(COMPUTE_DTYPE), ceff.astype(COMPUTE_DTYPE), analysis=analysis
    )
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, h * pdim).astype(COMPUTE_DTYPE)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(COMPUTE_DTYPE)
    if return_cache:
        return out, {"state": hfinal, "conv": conv_state}
    return out


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "state": jnp.zeros((batch, h, n, pdim), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, d_in), dtype),
    }


def mamba2_decode(
    x: jax.Array, p: Dict[str, jax.Array], cfg, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D); O(1) state update."""
    b = x.shape[0]
    h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z, xs, bc, cc, dt, a = _mamba_project(x, p, cfg)
    xs, conv_new = _causal_conv(xs, p["conv_w"].astype(COMPUTE_DTYPE), cache["conv"])
    xh = xs.reshape(b, 1, h, pdim)[:, 0]              # (B,H,P)
    beff = bc[:, 0, None, :] * dt[:, 0, :, None]      # (B,H,N)
    state = a[:, 0, :, None, None] * cache["state"] + beff[..., None] * xh[:, :, None, :].astype(jnp.float32)
    y = jnp.einsum("bhnp,bhn->bhp", state, jnp.broadcast_to(cc[:, 0, None, :], (b, h, n)).astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, h * pdim).astype(COMPUTE_DTYPE)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), {"state": state, "conv": conv_new}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1} stream; ``prev`` is the carry-in last token (B, D)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _rwkv_mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def _rwkv_project(x, xprev, p, cfg):
    b, s, d = x.shape
    h, n = cfg.ssm_heads, cfg.ssm_head_dim
    dt = COMPUTE_DTYPE
    r = _rwkv_mix(x, xprev, p["mu_r"]) @ p["w_r"].astype(dt)
    k = _rwkv_mix(x, xprev, p["mu_k"]) @ p["w_k"].astype(dt)
    v = _rwkv_mix(x, xprev, p["mu_v"]) @ p["w_v"].astype(dt)
    g = _rwkv_mix(x, xprev, p["mu_g"]) @ p["w_g"].astype(dt)
    # data-dependent per-channel decay (low-rank): w in (0, 1)
    xw = _rwkv_mix(x, xprev, p["mu_w"])
    wlog = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["w_lora_a"].astype(dt)).astype(jnp.float32)
        @ p["w_lora_b"].astype(jnp.float32)
    )
    w = jnp.exp(-jnp.exp(wlog))  # (B,S,D) per-channel decay
    shape = (b, s, h, n)
    return (r.reshape(shape), k.reshape(shape), v.reshape(shape), g, w.reshape(shape))


def _rwkv_readout(r, k, v, y_scan, p, cfg, b, s):
    """bonus + group-norm + gate + out-proj, shared by train/decode."""
    h, n = cfg.ssm_heads, cfg.ssm_head_dim
    u = p["u"].astype(jnp.float32).reshape(h, n)
    bonus = jnp.einsum(
        "bshn,bshn,bshp->bshp",
        r.astype(jnp.float32), (u - 1.0)[None, None] * k.astype(jnp.float32),
        v.astype(jnp.float32),
    )
    y = y_scan.astype(jnp.float32) + bonus
    # per-head group norm
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["ln_w"].astype(jnp.float32).reshape(1, 1, h, n) + p["ln_b"].astype(
        jnp.float32
    ).reshape(1, 1, h, n)
    return y.reshape(b, s, h * n).astype(COMPUTE_DTYPE)


def rwkv6_block(
    x: jax.Array, p: Dict[str, jax.Array], cfg, *, return_state: bool = False,
    analysis: bool = False,
):
    """RWKV-6 time-mix, train/prefill path. x: (B, S, D)."""
    b, s, d = x.shape
    xprev = _token_shift(x)
    r, k, v, g, w = _rwkv_project(x, xprev, p, cfg)
    # recurrence: h_t = diag(w_t) h_{t-1} + k_t ⊗ v_t ; y = r·h_t
    y_scan, hfinal = kops.ssm_scan(v, w, k, r, analysis=analysis)  # per-channel decay
    y = _rwkv_readout(r, k, v, y_scan, p, cfg, b, s)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    out = y @ p["w_o"].astype(COMPUTE_DTYPE)
    if return_state:
        return out, hfinal
    return out


def rwkv6_init_cache(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    h, n = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, h, n, n), jnp.float32),
        "tm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv6_decode(
    x: jax.Array, p: Dict[str, jax.Array], cfg, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, D); O(1) per-token state update."""
    b = x.shape[0]
    h, n = cfg.ssm_heads, cfg.ssm_head_dim
    xprev = cache["tm_prev"][:, None, :].astype(x.dtype)
    r, k, v, g, w = _rwkv_project(x, xprev, p, cfg)
    state = (
        w[:, 0, :, :, None].astype(jnp.float32) * cache["state"]
        + k[:, 0, :, :, None].astype(jnp.float32) * v[:, 0, :, None, :].astype(jnp.float32)
    )
    y_scan = jnp.einsum("bhnp,bhn->bhp", state, r[:, 0].astype(jnp.float32))[:, None]
    y = _rwkv_readout(r, k, v, y_scan, p, cfg, b, 1)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    out = y @ p["w_o"].astype(COMPUTE_DTYPE)
    return out, {"state": state, "tm_prev": x[:, 0], "cm_prev": cache["cm_prev"]}


def rwkv6_channel_mix(
    x: jax.Array, p: Dict[str, jax.Array], prev: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """RWKV FFN (channel mix). Returns (y, last_token)."""
    dt = COMPUTE_DTYPE
    xprev = _token_shift(x, prev)
    xk = _rwkv_mix(x, xprev, p["mu_ck"])
    xr = _rwkv_mix(x, xprev, p["mu_cr"])
    kk = jnp.square(jax.nn.relu((xk @ p["w_ck"].astype(dt)).astype(jnp.float32)))
    y = kk.astype(dt) @ p["w_cv"].astype(dt)
    rr = jax.nn.sigmoid((xr @ p["w_cr"].astype(dt)).astype(jnp.float32)).astype(dt)
    return rr * y, x[:, -1]

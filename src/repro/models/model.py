"""Config-driven model builder: parameter init, train forward, prefill and
decode for every assigned architecture family.

Families and their layer stacks (all per-layer weights are stacked on a
leading L dim and consumed by ``lax.scan`` — small HLO, O(1) compile cost in
depth):

  dense / moe / vlm / audio — pre-norm transformer blocks (GQA + RoPE +
      SwiGLU FFN or top-k MoE). vlm prepends stub patch embeddings
      (prefix-LM attention over the image prefix); audio consumes stub
      EnCodec frame embeddings and emits one head per codebook.
  hybrid (zamba2) — 9 super-blocks of 6 Mamba2 layers, with ONE weight-shared
      attention+MLP block applied after every super-block (the zamba2
      pattern, 54 = 9×6).
  ssm (rwkv6) — RWKV-6 time-mix + channel-mix blocks.

The ``ctx`` argument (ParallelCtx) is None on a single device; under a mesh
it drives sharding constraints + the MoE shard_map (see dist/sharding.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import blocked_attention, decode_attention
from repro.models.layers import (
    COMPUTE_DTYPE,
    apply_rope,
    cross_entropy,
    dense_ffn,
    normal_init,
    rms_norm,
)
from repro.models.moe import moe_ffn

__all__ = ["init_params", "forward_train", "prefill", "decode_step", "init_cache"]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _attn_params(key, cfg: ModelConfig, layers: int):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    shape = lambda *s: (layers, *s) if layers else s
    return {
        "wq": normal_init(ks[0], shape(d, h * hd)),
        "wk": normal_init(ks[1], shape(d, kv * hd)),
        "wv": normal_init(ks[2], shape(d, kv * hd)),
        "wo": normal_init(ks[3], shape(h * hd, d), std=1.0 / np.sqrt(h * hd)),
    }


def _ffn_params(key, cfg: ModelConfig, layers: int):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    shape = lambda *s: (layers, *s) if layers else s
    if cfg.num_experts:
        e = cfg.num_experts
        return {
            "router": normal_init(ks[3], shape(d, e), std=0.02),
            "w_gate": normal_init(ks[0], shape(e, d, f), std=1.0 / np.sqrt(d)),
            "w_up": normal_init(ks[1], shape(e, d, f), std=1.0 / np.sqrt(d)),
            "w_down": normal_init(ks[2], shape(e, f, d), std=1.0 / np.sqrt(f)),
        }
    return {
        "w_gate": normal_init(ks[0], shape(d, f)),
        "w_up": normal_init(ks[1], shape(d, f)),
        "w_down": normal_init(ks[2], shape(f, d)),
    }


def _mamba_params(key, cfg: ModelConfig, layers: int):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    z = 2 * d_in + 2 * n + h
    ks = jax.random.split(key, 3)
    shape = lambda *s: (layers, *s) if layers else s
    return {
        "ln": jnp.zeros(shape(d), jnp.float32),
        "in_proj": normal_init(ks[0], shape(d, z)),
        "conv_w": normal_init(ks[1], shape(ssm_mod._CONV_K, d_in), std=0.5),
        "dt_bias": jnp.zeros(shape(h), jnp.float32),
        "a_log": jnp.zeros(shape(h), jnp.float32),
        "d_skip": jnp.ones(shape(h), jnp.float32),
        "norm": jnp.zeros(shape(d_in), jnp.float32),
        "out_proj": normal_init(ks[2], shape(d_in, d)),
    }


def _rwkv_params(key, cfg: ModelConfig, layers: int):
    d, f = cfg.d_model, cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 10)
    shape = lambda *s: (layers, *s) if layers else s
    mu = lambda: jnp.full(shape(d), 0.5, jnp.float32)
    return {
        "ln1": jnp.zeros(shape(d), jnp.float32),
        "ln2": jnp.zeros(shape(d), jnp.float32),
        "mu_r": mu(), "mu_k": mu(), "mu_v": mu(), "mu_g": mu(), "mu_w": mu(),
        "w_r": normal_init(ks[0], shape(d, d)),
        "w_k": normal_init(ks[1], shape(d, d)),
        "w_v": normal_init(ks[2], shape(d, d)),
        "w_g": normal_init(ks[3], shape(d, d)),
        "w0": jnp.full(shape(d), -0.6, jnp.float32),
        "w_lora_a": normal_init(ks[4], shape(d, lora), std=0.02),
        "w_lora_b": normal_init(ks[5], shape(lora, d), std=0.02),
        "u": jnp.full(shape(d), 0.5, jnp.float32),
        "ln_w": jnp.ones(shape(d), jnp.float32),
        "ln_b": jnp.zeros(shape(d), jnp.float32),
        "w_o": normal_init(ks[6], shape(d, d)),
        "mu_ck": mu(), "mu_cr": mu(),
        "w_ck": normal_init(ks[7], shape(d, f)),
        "w_cv": normal_init(ks[8], shape(f, d)),
        "w_cr": normal_init(ks[9], shape(d, d)),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    d, vp = cfg.d_model, cfg.padded_vocab
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"final_norm": jnp.zeros((d,), jnp.float32)}
    if cfg.family == "audio":
        params["lm_head"] = normal_init(ks[1], (d, cfg.num_codebooks * vp), std=0.02)
    else:
        params["embed"] = normal_init(ks[0], (vp, d), std=0.02)
        params["lm_head"] = normal_init(ks[1], (d, vp), std=0.02)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        L = cfg.num_layers
        params["layers"] = {
            "ln1": jnp.zeros((L, d), jnp.float32),
            "ln2": jnp.zeros((L, d), jnp.float32),
            **_attn_params(ks[2], cfg, L),
            **_ffn_params(ks[3], cfg, L),
        }
    elif cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.attn_every  # super-blocks
        params["mamba"] = jax.tree.map(
            lambda x: x.reshape(nb, cfg.attn_every, *x.shape[1:]),
            _mamba_params(ks[2], cfg, cfg.num_layers),
        )
        params["shared_attn"] = {
            "ln1": jnp.zeros((d,), jnp.float32),
            "ln2": jnp.zeros((d,), jnp.float32),
            **_attn_params(ks[3], cfg, 0),
            **{
                k: v
                for k, v in _ffn_params(ks[4], dataclasses.replace(cfg, num_experts=0), 0).items()
            },
        }
    elif cfg.family == "ssm":
        params["layers"] = _rwkv_params(ks[2], cfg, cfg.num_layers)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Transformer blocks (train/prefill path)
# ---------------------------------------------------------------------------


def _analysis(ctx) -> bool:
    return bool(ctx is not None and getattr(ctx, "analysis", False))


def _attn_block(x, p, cfg: ModelConfig, *, window, positions, prefix_len=0,
                q_offset=0, ctx=None, return_kv=False):
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = COMPUTE_DTYPE
    a = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (a @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (a @ p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = (a @ p["wv"].astype(dt)).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    from repro.dist.sharding import constrain_qkv

    q, k, v = constrain_qkv(q, k, v, ctx)
    o = blocked_attention(
        q, k, v, window=window, q_offset=q_offset, prefix_len=prefix_len,
        unroll=_analysis(ctx),
    )
    x = x + o.reshape(b, s, h * hd) @ p["wo"].astype(dt)
    return (x, (k, v)) if return_kv else (x, None)


def _ffn_block(x, p, cfg: ModelConfig, ctx=None):
    a = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        y = moe_ffn(
            a,
            {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")},
            k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor,
            ctx=ctx,
        )
    else:
        y = dense_ffn(a, p["w_gate"], p["w_up"], p["w_down"])
    return x + y


def _transformer_stack(x, layers, cfg: ModelConfig, *, positions, windows,
                       prefix_len=0, ctx=None, collect_kv=False):
    """Scan the stacked transformer layers; optionally collect (k, v) per
    layer for cache construction (prefill)."""

    def body(h, xs):
        p, window = xs
        h, kvs = _attn_block(
            h, p, cfg, window=window, positions=positions,
            prefix_len=prefix_len, ctx=ctx, return_kv=collect_kv,
        )
        h = _ffn_block(h, p, cfg, ctx=ctx)
        return h, kvs

    wrapped = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    windows_arr = jnp.asarray(windows, jnp.int32)
    x, kvs = jax.lax.scan(wrapped, x, (layers, windows_arr), unroll=_analysis(ctx))
    return x, kvs


# ---------------------------------------------------------------------------
# Hybrid (zamba2) stack
# ---------------------------------------------------------------------------


def _hybrid_stack(x, params, cfg: ModelConfig, *, positions, ctx=None):
    shared = params["shared_attn"]
    b, s, d = x.shape

    def super_block(h, mp):
        def inner(hh, p):
            hh = hh + ssm_mod.mamba2_block(
                rms_norm(hh, p["ln"], cfg.norm_eps), p, cfg, analysis=_analysis(ctx)
            )
            return hh, None

        h, _ = jax.lax.scan(inner, h, mp, unroll=_analysis(ctx))
        h, _ = _attn_block(
            h, shared, cfg, window=s, positions=positions, ctx=ctx
        )
        h = _ffn_block(h, shared, cfg, ctx=ctx)
        return h, None

    wrapped = jax.checkpoint(super_block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(wrapped, x, params["mamba"], unroll=_analysis(ctx))
    return x


# ---------------------------------------------------------------------------
# RWKV stack
# ---------------------------------------------------------------------------


def _rwkv_stack(x, layers, cfg: ModelConfig, ctx=None):
    def body(h, p):
        h = h + ssm_mod.rwkv6_block(
            rms_norm(h, p["ln1"], cfg.norm_eps), p, cfg, analysis=_analysis(ctx)
        )
        y, _ = ssm_mod.rwkv6_channel_mix(rms_norm(h, p["ln2"], cfg.norm_eps), p)
        return h + y, None

    wrapped = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(wrapped, x, layers, unroll=_analysis(ctx))
    return x


# ---------------------------------------------------------------------------
# Public forwards
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, int]:
    """Returns (hidden (B,S,D) bf16, prefix_len)."""
    if cfg.family == "audio":
        return batch["frame_embeds"].astype(COMPUTE_DTYPE), 0
    emb = params["embed"]
    tok = jnp.take(emb, batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(COMPUTE_DTYPE)
        return jnp.concatenate([patches, tok], axis=1), cfg.num_patches
    return tok, 0


def _backbone(cfg: ModelConfig, params, x, *, positions, seq_len, prefix_len, ctx):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = cfg.layer_windows(seq_len)
        x, _ = _transformer_stack(
            x, params["layers"], cfg, positions=positions, windows=windows,
            prefix_len=prefix_len, ctx=ctx,
        )
    elif cfg.family == "hybrid":
        x = _hybrid_stack(x, params, cfg, positions=positions, ctx=ctx)
    elif cfg.family == "ssm":
        x = _rwkv_stack(x, params["layers"], cfg, ctx=ctx)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward_train(cfg: ModelConfig, params, batch, ctx=None) -> jax.Array:
    """Returns mean token cross-entropy (fp32 scalar)."""
    from repro.dist.sharding import constrain_hidden

    x, prefix_len = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = constrain_hidden(x, cfg, ctx)
    x = _backbone(cfg, params, x, positions=positions, seq_len=s,
                  prefix_len=prefix_len, ctx=ctx)
    dt = COMPUTE_DTYPE
    if cfg.family == "audio":
        vp = cfg.padded_vocab
        logits = (x @ params["lm_head"].astype(dt)).reshape(
            b, s, cfg.num_codebooks, vp
        )
        return cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)
    logits = x @ params["lm_head"].astype(dt)
    if cfg.family == "vlm":
        logits = logits[:, prefix_len:]  # loss over text positions only
    labels = batch["labels"]
    valid = labels >= 0
    return cross_entropy(
        logits, jnp.maximum(labels, 0), valid=valid, vocab_size=cfg.vocab_size
    )


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Cache pytree sized for ``max_len`` positions."""
    hd, kv = cfg.head_dim, cfg.num_kv_heads
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L, batch, max_len, kv, hd), COMPUTE_DTYPE),
            "v": jnp.zeros((L, batch, max_len, kv, hd), COMPUTE_DTYPE),
        }
    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.attn_every
        mam = ssm_mod.mamba2_init_cache(cfg, batch, COMPUTE_DTYPE)
        return {
            "mamba": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (nb, cfg.attn_every, *x.shape)
                ),
                mam,
            ),
            "k": jnp.zeros((nb, batch, max_len, kv, hd), COMPUTE_DTYPE),
            "v": jnp.zeros((nb, batch, max_len, kv, hd), COMPUTE_DTYPE),
        }
    if cfg.family == "ssm":
        rw = ssm_mod.rwkv6_init_cache(cfg, batch, COMPUTE_DTYPE)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers, *x.shape)), rw
        )
    raise ValueError(cfg.family)


def _decode_attn_layer(x, p, cfg, kc, vc, cur_len, window, positions):
    """One decode attention block against a (B,S,KV,hd) cache layer."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = COMPUTE_DTYPE
    a = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (a @ p["wq"].astype(dt)).reshape(b, 1, h, hd)
    k = (a @ p["wk"].astype(dt)).reshape(b, 1, kv, hd)
    v = (a @ p["wv"].astype(dt)).reshape(b, 1, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cur_len, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cur_len, axis=1)
    o = decode_attention(q, kc, vc, cur_len + 1, window=window)
    x = x + o.reshape(b, 1, h * hd) @ p["wo"].astype(dt)
    return x, kc, vc


def decode_step(cfg: ModelConfig, params, batch, cache, cur_len, ctx=None):
    """One token for every sequence. ``batch``: {"tokens": (B, 1)} (or
    {"frame_embeds": (B, 1, D)} for audio). Returns (logits, new_cache)."""
    if cfg.family == "audio":
        x = batch["frame_embeds"].astype(COMPUTE_DTYPE)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
    b = x.shape[0]
    positions = jnp.broadcast_to(cur_len, (b, 1))
    s_cache = jax.tree.leaves(cache)[0].shape[2] if cfg.family != "ssm" else 0

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = jnp.asarray(cfg.layer_windows(10**9), jnp.int32)
        windows = jnp.minimum(windows, jnp.int32(2**30))

        def body(h, xs):
            p, window, kc, vc = xs
            h, kc, vc = _decode_attn_layer(h, p, cfg, kc, vc, cur_len, window, positions)
            h = _ffn_block(h, p, cfg, ctx=ctx)
            return h, (kc, vc)

        x, (knew, vnew) = jax.lax.scan(
            body, x, (params["layers"], windows, cache["k"], cache["v"]),
            unroll=_analysis(ctx),
        )
        cache = {"k": knew, "v": vnew}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def super_block(h, xs):
            mp, mcache, kc, vc = xs

            def inner(carry, xs2):
                hh, _ = carry
                p, mc = xs2
                y, mc_new = ssm_mod.mamba2_decode(
                    rms_norm(hh, p["ln"], cfg.norm_eps), p, cfg, mc
                )
                return (hh + y, 0), mc_new

            (h, _), mcache_new = jax.lax.scan(inner, (h, 0), (mp, mcache))
            h, kc, vc = _decode_attn_layer(
                h, shared, cfg, kc, vc, cur_len, jnp.int32(2**30), positions
            )
            h = _ffn_block(h, shared, cfg, ctx=ctx)
            return h, (mcache_new, kc, vc)

        x, (mnew, knew, vnew) = jax.lax.scan(
            super_block, x, (params["mamba"], cache["mamba"], cache["k"], cache["v"]),
            unroll=_analysis(ctx),
        )
        cache = {"mamba": mnew, "k": knew, "v": vnew}
    elif cfg.family == "ssm":
        def body(h, xs):
            p, c = xs
            y, c1 = ssm_mod.rwkv6_decode(
                rms_norm(h, p["ln1"], cfg.norm_eps), p, cfg, c
            )
            h = h + y
            z, cm_prev = ssm_mod.rwkv6_channel_mix(
                rms_norm(h, p["ln2"], cfg.norm_eps), p,
                prev=c["cm_prev"].astype(COMPUTE_DTYPE),
            )
            c1["cm_prev"] = cm_prev
            return h + z, c1

        x, cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=_analysis(ctx))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    return logits, cache


def prefill(cfg: ModelConfig, params, batch, max_len: int, ctx=None):
    """Run the prompt; returns (last-position logits, filled cache, length)."""
    from repro.dist.sharding import constrain_hidden

    x, prefix_len = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = constrain_hidden(x, cfg, ctx)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = cfg.layer_windows(s)
        x, kvs = _transformer_stack(
            x, params["layers"], cfg, positions=positions, windows=windows,
            prefix_len=prefix_len, ctx=ctx, collect_kv=True,
        )
        k, v = kvs  # (L, B, S, KV, hd)
        pad = max_len - s
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        }
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def super_block(h, mp):
            def inner(hh, p):
                y, c = ssm_mod.mamba2_block(
                    rms_norm(hh, p["ln"], cfg.norm_eps), p, cfg, return_cache=True,
                    analysis=_analysis(ctx),
                )
                return hh + y, c

            h, mcache = jax.lax.scan(inner, h, mp, unroll=_analysis(ctx))
            h, (k, v) = _attn_block(
                h, shared, cfg, window=s, positions=positions, ctx=ctx,
                return_kv=True,
            )
            h = _ffn_block(h, shared, cfg, ctx=ctx)
            return h, (mcache, k, v)

        x, (mcaches, ks, vs) = jax.lax.scan(
            super_block, x, params["mamba"], unroll=_analysis(ctx)
        )
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        cache = {"mamba": mcaches, "k": jnp.pad(ks, pad), "v": jnp.pad(vs, pad)}
    else:  # ssm (rwkv6): chunked scans already expose their final states

        def body(h, p):
            a = rms_norm(h, p["ln1"], cfg.norm_eps)
            y, state = ssm_mod.rwkv6_block(
                a, p, cfg, return_state=True, analysis=_analysis(ctx)
            )
            h = h + y
            an = rms_norm(h, p["ln2"], cfg.norm_eps)
            z, cm_prev = ssm_mod.rwkv6_channel_mix(an, p)
            c = {"state": state, "tm_prev": a[:, -1], "cm_prev": cm_prev}
            return h + z, c

        x, cache = jax.lax.scan(body, x, params["layers"], unroll=_analysis(ctx))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    return logits, cache, s

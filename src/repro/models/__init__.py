"""Model zoo: composable layer library + config-driven builder."""

from repro.models.model import (  # noqa: F401
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)

"""Mixture-of-Experts FFN with capacity-based gather dispatch.

Design (DESIGN.md §5): tokens are already sharded over the mesh (batch over
the dp axes; sequence over 'model' in the training SP layout), so dispatch is
*local per shard* — a shard_map keeps the argsort/cumsum/gather on-device with
zero collectives in the training layout. In the serving layout the expert FFN
dims are tensor-parallel over 'model' and the partial sums are psum-combined.

FLOP count is exact k/E of dense-all-experts (plus the capacity_factor
overhead); dropped tokens (over capacity) fall back to the residual path,
standard top-k-with-capacity semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import COMPUTE_DTYPE, swiglu

__all__ = ["moe_ffn", "moe_ffn_local"]


def moe_ffn_local(
    x: jax.Array,            # (T, D) local tokens
    router_w: jax.Array,     # (D, E)
    w_gate: jax.Array,       # (E, D, F)  (F possibly TP-local)
    w_up: jax.Array,         # (E, D, F)
    w_down: jax.Array,       # (E, F, D)
    *,
    k: int,
    capacity_factor: float = 1.25,
    tp_axis: Optional[str] = None,
    dropless_threshold: int = 4096,
) -> jax.Array:
    t, d = x.shape
    e = router_w.shape[1]
    dt = COMPUTE_DTYPE

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    gval, gidx = jax.lax.top_k(gates, k)              # (T, k)
    gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)

    eflat = gidx.reshape(-1)                          # (T*k,)
    onehot = jax.nn.one_hot(eflat, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1, eflat[:, None], 1)[:, 0]
    # dropless for small token counts (decode / small prefill: every token
    # fits even if all pick the same expert); capacity-bounded at train scale
    if t * k <= dropless_threshold:
        cap = t
    else:
        cap = max(1, int(t * k / e * capacity_factor))
    keep = pos < cap
    slot = jnp.where(keep, eflat * cap + pos, e * cap)  # overflow -> sink row
    tok = jnp.arange(t * k) // k

    xe = jnp.zeros((e * cap + 1, d), dt).at[slot].set(x[tok].astype(dt))
    xe = xe[: e * cap].reshape(e, cap, d)
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt)),
        jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dt)),
    )
    ye = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))
    if tp_axis is not None:
        ye = jax.lax.psum(ye, tp_axis)                # combine TP partials
    ye = jnp.concatenate([ye.reshape(e * cap, d), jnp.zeros((1, d), dt)], 0)
    out = ye[slot] * (gval.reshape(-1)[:, None] * keep[:, None]).astype(dt)
    return out.reshape(t, k, d).sum(1)


def moe_ffn(
    x: jax.Array,            # (B, S, D) global
    params: Dict[str, jax.Array],
    *,
    k: int,
    capacity_factor: float = 1.25,
    ctx: Optional[Any] = None,   # ParallelCtx (dist/sharding.py) or None
) -> jax.Array:
    """Global MoE FFN. Without a mesh context runs the local path directly
    (smoke tests / single device). With a context, shard_maps so dispatch
    stays per-shard; the layout follows ctx.mode ('train' SP vs 'serve' TP)."""
    b, s, d = x.shape
    rw, wg, wu, wd = params["router"], params["w_gate"], params["w_up"], params["w_down"]

    if ctx is None or ctx.mesh is None:
        y = moe_ffn_local(
            x.reshape(b * s, d), rw, wg, wu, wd, k=k, capacity_factor=capacity_factor
        )
        return y.reshape(b, s, d)

    mesh = ctx.mesh
    dp = tuple(ctx.dp)
    ma = ctx.model_axis
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if b % max(dp_size, 1) == 0 else None  # batch=1 decode cells
    fsdp_ax = "data" if "data" in mesh.axis_names else None
    if ctx.mode == "train":
        xspec = P(bspec, ma, None)  # SP layout: batch over dp, seq over model
        # expert weights enter at their AT-REST FSDP sharding and are
        # all-gathered INSIDE in bf16; the gather's transpose is a bf16
        # reduce-scatter, replacing the fp32 full-gradient all-reduce that a
        # replicated in_spec would force (EXPERIMENTS.md §Perf it.3).
        wspec = (P(), P(None, fsdp_ax, ma), P(None, fsdp_ax, ma), P(None, fsdp_ax, ma))
        tp_axis = None
        gather_axes = [a for a in (fsdp_ax, ma) if a]
    else:
        xspec = P(bspec, None, None)  # serve layout: TP experts over model
        wspec = (P(), P(None, None, ma), P(None, None, ma), P(None, ma, None))
        tp_axis = ma
        gather_axes = []

    def _gather_w(w):
        # at-rest (E, D|F, F|D) sharded P(None, fsdp_ax, ma): axis1 ← fsdp,
        # axis2 ← model
        if gather_axes and fsdp_ax:
            w = jax.lax.all_gather(w, fsdp_ax, axis=1, tiled=True)
        if gather_axes:
            w = jax.lax.all_gather(w, ma, axis=2, tiled=True)
        return w

    def local(xl, rwl, wgl, wul, wdl):
        wgl, wul, wdl = _gather_w(wgl), _gather_w(wul), _gather_w(wdl)
        bl, sl, _ = xl.shape
        y = moe_ffn_local(
            xl.reshape(bl * sl, d), rwl, wgl, wul, wdl,
            k=k, capacity_factor=capacity_factor, tp_axis=tp_axis,
        )
        return y.reshape(bl, sl, d)

    from repro.dist.sharding import shard_map_compat

    return shard_map_compat(
        local,
        mesh=mesh,
        in_specs=(xspec,) + wspec,
        out_specs=xspec,
    )(x, rw, wg, wu, wd)

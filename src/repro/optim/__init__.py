"""Optimizers + schedules (sharded-state AdamW)."""

from repro.optim.adamw import OptConfig, adamw_init, adamw_update, global_norm  # noqa: F401

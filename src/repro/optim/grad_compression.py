"""Gradient compression for cross-pod data parallelism.

Under pure pjit the gradient reduction dtype follows the autodiff dtype; to
control the *wire* format across the slow pod-interconnect explicitly, this
module provides a shard_map-based DP reducer: gradients are compressed
(bf16, or int8 with per-chunk scales), all-reduced over the chosen axes, and
decompressed — halving (or quartering) cross-pod gradient traffic, the
classic large-cluster trick for interconnect-bound data parallelism.

Error feedback (residual accumulation) keeps int8 compression unbiased over
steps.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["compress_decompress", "compressed_psum", "make_dp_grad_reducer"]


def compress_decompress(g: jax.Array, scheme: str = "bf16") -> jax.Array:
    """Simulate the wire format (for numerics tests and local use)."""
    if scheme == "bf16":
        return g.astype(jnp.bfloat16).astype(g.dtype)
    if scheme == "int8":
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(g.dtype) * scale
    raise ValueError(scheme)


def compressed_psum(g: jax.Array, axis: str, scheme: str = "bf16") -> jax.Array:
    """psum with a compressed wire format (call inside shard_map)."""
    if scheme == "bf16":
        return jax.lax.psum(g.astype(jnp.bfloat16), axis).astype(g.dtype)
    if scheme == "int8":
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        scale = jax.lax.pmax(scale, axis)  # shared scale across the group
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        # int8 psum would overflow; widen to int32 on the wire (still 4×
        # smaller than fp32 after the 4× count reduction? no — int32 == fp32;
        # real deployments use ring-RS with int8 segments. We model the
        # numerics here and count the wire as int8 in the roofline.)
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        return s.astype(g.dtype) * scale
    raise ValueError(scheme)


def make_dp_grad_reducer(mesh, dp_axes: Tuple[str, ...], scheme: str = "bf16"):
    """Returns reduce(grads_tree) -> mean-reduced grads over the dp axes,
    with the compressed wire format, as a shard_map over the full mesh."""
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]

    def _reduce_leaf(g):
        def local(x):
            out = x
            for a in dp_axes:
                out = compressed_psum(out, a, scheme)
            return out / n

        from repro.dist.sharding import shard_map_compat

        return shard_map_compat(
            local,
            mesh=mesh,
            in_specs=P(*([None] * g.ndim)),
            out_specs=P(*([None] * g.ndim)),
        )(g)

    return lambda grads: jax.tree.map(_reduce_leaf, grads)

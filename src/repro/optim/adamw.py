"""AdamW with global-norm clipping, functional (optax-free), sharded states.

Optimizer states are plain pytrees mirroring the parameter tree, so the
FSDP param shardings (dist/sharding.py) apply verbatim to m/v — each chip
holds exactly its shard of the fp32 master params + moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any, state: Dict[str, Any], params: Any, cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return p - lr * step, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        pn, mn, vn = upd(g, m, v, p)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    unflat = lambda xs: jax.tree.unflatten(treedef, xs)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unflat(new_p), {"m": unflat(new_m), "v": unflat(new_v), "count": count}, metrics

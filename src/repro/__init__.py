"""repro — production-grade JAX framework reproducing and extending
"Run-time Parameter Sensitivity Analysis Optimizations" (RMSR, 2019):
multi-level computation reuse for parameter sensitivity analysis, adapted to
TPU pods, plus the LM-architecture zoo, distributed runtime, and Pallas
kernels required to deploy it at scale."""

__version__ = "1.0.0"

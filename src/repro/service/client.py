"""ServiceClient — the tenant-side handle to a remote StudyServer.

One TCP connection, the §16 length-prefixed pickle frame codec, strict
request/response: every call sends one ``"t"``-tagged frame and blocks
for the one reply. Server-side failures arrive as ``{"t": "err"}`` frames
and surface here as :class:`ServiceError`, so a tenant's bad spec or
blown quota reads as an exception, not a dict to inspect.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, List, Optional

from repro.runtime.net import PROTOCOL_VERSION, SocketConn, parse_address
from repro.runtime.transport import _recv_frame, _send_frame
from repro.service.spec import StudySpec

__all__ = ["ServiceClient", "ServiceError"]


class ServiceError(RuntimeError):
    """The server rejected or failed a request (bad spec, unknown job,
    quota exceeded, protocol mismatch)."""


class ServiceClient:
    """Blocking client for one tenant against one StudyServer address.

    Thread-safe: a lock serializes request/response pairs, so one client
    may be shared by a tenant's polling and submitting threads.
    """

    def __init__(
        self, addr: str, tenant: str, *, connect_timeout: float = 10.0
    ) -> None:
        self.tenant = tenant
        host, port = parse_address(addr)
        sock = socket.create_connection((host, port), timeout=connect_timeout)
        self._conn = SocketConn(sock)
        self._lock = threading.Lock()
        self._send_lock = threading.Lock()
        # frame-consumer: svc_hello via hello
        hello = _recv_frame(self._conn)
        if hello.get("t") != "svc_hello":
            raise ServiceError(f"unexpected greeting frame: {hello!r}")
        if hello.get("proto") != PROTOCOL_VERSION:
            raise ServiceError(
                f"protocol mismatch: server speaks {hello.get('proto')}, "
                f"client speaks {PROTOCOL_VERSION}"
            )

    # ------------------------------------------------------------------
    def _rpc(self, msg: Dict[str, Any], ok_tag: str) -> Dict[str, Any]:
        # frame-consumer: sub_ok,stat_ok,res_ok,cancel_ok,jobs_ok,weight_ok,sstats_ok,bye_ok,err via reply
        with self._lock:
            # analysis: ok[blocking] the request/response round-trip IS what
            # this lock serializes — interleaved frames from two threads
            # would pair replies to the wrong calls
            _send_frame(self._conn, self._send_lock, msg)
            reply = _recv_frame(self._conn)  # analysis: ok[blocking] see above
        kind = reply.get("t")
        if kind == "err":
            raise ServiceError(reply.get("error", "unknown server error"))
        if kind != ok_tag:
            raise ServiceError(
                f"expected {ok_tag!r} reply, got {kind!r}"
            )
        return reply

    # ------------------------------------------------------------------
    # The job API over the wire
    # ------------------------------------------------------------------
    def submit(self, spec: StudySpec) -> str:
        reply = self._rpc(
            {"t": "sub", "tenant": self.tenant, "spec": spec.to_json()},
            "sub_ok",
        )
        return reply["job_id"]

    def status(self, job_id: str) -> Dict[str, Any]:
        return self._rpc({"t": "stat", "job_id": job_id}, "stat_ok")["job"]

    def result(
        self,
        job_id: str,
        *,
        wait: bool = True,
        timeout: Optional[float] = None,
        poll_s: float = 0.2,
    ) -> Dict[str, Any]:
        """Terminal snapshot of the job. ``wait`` polls client-side (one
        short server round-trip per poll — the connection is never parked
        in a long server-side wait, so cancels and status checks from
        other threads keep flowing)."""
        deadline = (
            None if timeout is None else time.monotonic() + max(0.0, timeout)
        )
        while True:
            reply = self._rpc(
                {"t": "res", "job_id": job_id, "wait": False}, "res_ok"
            )
            job = reply["job"]
            if job["state"] in ("DONE", "FAILED", "CANCELLED"):
                return job
            if not wait:
                return job
            if deadline is not None and time.monotonic() >= deadline:
                return job
            time.sleep(poll_s)

    def cancel(self, job_id: str) -> Dict[str, Any]:
        return self._rpc({"t": "cancel", "job_id": job_id}, "cancel_ok")[
            "job"
        ]

    def list_jobs(
        self, *, all_tenants: bool = False
    ) -> List[Dict[str, Any]]:
        msg: Dict[str, Any] = {"t": "jobs"}
        if not all_tenants:
            msg["tenant"] = self.tenant
        return self._rpc(msg, "jobs_ok")["jobs"]

    def set_tenant_weight(self, weight: float, tenant: str = "") -> None:
        self._rpc(
            {
                "t": "weight",
                "tenant": tenant or self.tenant,
                "weight": float(weight),
            },
            "weight_ok",
        )

    def server_stats(self) -> Dict[str, Any]:
        return self._rpc({"t": "sstats"}, "sstats_ok")["stats"]

    def close(self) -> None:
        try:
            self._rpc({"t": "bye"}, "bye_ok")
        except (ServiceError, EOFError, OSError):
            pass
        self._conn.close()

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

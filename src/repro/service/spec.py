"""StudySpec — the serializable description of one study job (DESIGN.md
§18).

A spec is everything a tenant sends over the wire to request a study
against a server's workflow/dataset: which region of the parameter space
to evaluate (explicit points, a grid sweep, or MOAT trajectories over
optional per-parameter bounds), which engine bucketing policy to plan
with, the job's fair-share priority, and an optional wall-clock timeout.
It is a plain-dict payload (``to_json``/``from_json``) so it rides the
length-prefixed frame codec unchanged.

The spec's **signature** is the content address of the work it denotes:
the sha-256 of the canonically-ordered resolved run list plus the
planning knobs that shape task identity. Two tenants submitting specs
with equal signatures produce byte-identical plans and therefore
byte-identical WorkItem keys — the Manager's shared-submission path then
executes the tasks once and fans the completions out to both jobs.
Overlapping-but-unequal specs still reuse partial work through the
server's shared ResultCache (scoped by input and trie prefix, which are
signature-independent).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Dict, List, Optional, Sequence

from repro.core.params import ParamSet, ParamSpace, paramset
from repro.engine.types import CACHING_POLICIES, POLICIES

__all__ = ["StudySpec", "SpecError"]

# Resolution guardrails: a malformed or adversarial spec must fail at
# admission, not melt the pool.
_MAX_RUNS = 4096


class SpecError(ValueError):
    """The spec cannot be resolved against the server's parameter space
    (unknown parameter, bad sampler, run-count blow-up, …) — rejected at
    admission, before any work is planned or queued."""


@dataclasses.dataclass
class StudySpec:
    """One study request.

    sampler      — "explicit" (``param_sets`` is the run list), "grid"
                   (cartesian sweep of ``names`` over their values, every
                   other parameter pinned at the space default), or "moat"
                   (``n_trajectories`` Morris trajectories, seeded).
    param_sets   — explicit run list (dicts; missing names filled with the
                   space default) for sampler="explicit".
    names        — the parameters a grid sweep varies (default: all).
    bounds       — optional per-parameter value-list overrides (the spec's
                   sub-space): each named parameter must exist in the
                   server space; its listed values replace the server grid
                   for this study only.
    n_trajectories / seed — MOAT sampling shape.
    policy       — engine bucketing policy; caching policies (rtma / rmsr /
                   hybrid) engage the server's shared ResultCache.
    max_bucket_size / active_paths — planner knobs (same as plan_study).
    priority     — within-tenant dispatch priority (higher first).
    timeout_s    — optional wall-clock bound; the server cancels the job
                   when it lapses.
    metrics      — which result payloads to compute: "objective" (the
                   per-run objective vector, averaged over inputs) and/or
                   "per_input" (the per-input objective matrix).
    poll_s       — the client's suggested result-poll interval (carried in
                   the spec so a tenant's tooling round-trips it; the
                   server does not act on it).
    """

    sampler: str = "explicit"
    param_sets: Optional[List[Dict[str, Any]]] = None
    names: Optional[List[str]] = None
    bounds: Optional[Dict[str, List[Any]]] = None
    n_trajectories: int = 2
    seed: int = 0
    policy: str = "hybrid"
    max_bucket_size: Optional[int] = None
    active_paths: Optional[int] = 4
    priority: int = 0
    timeout_s: Optional[float] = None
    metrics: List[str] = dataclasses.field(
        default_factory=lambda: ["objective"]
    )
    poll_s: float = 0.2

    # ------------------------------------------------------------------
    # Wire form
    # ------------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "StudySpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise SpecError(f"unknown StudySpec fields: {sorted(unknown)}")
        return cls(**d)

    # ------------------------------------------------------------------
    # Validation + resolution against a server's space
    # ------------------------------------------------------------------
    def _effective_space(self, space: ParamSpace) -> ParamSpace:
        if not self.bounds:
            return space
        unknown = set(self.bounds) - set(space.names)
        if unknown:
            raise SpecError(
                f"bounds name unknown parameters: {sorted(unknown)}"
            )
        d = {p.name: list(p.values) for p in space.params}
        for name, values in self.bounds.items():
            if not values:
                raise SpecError(f"bounds for {name!r} are empty")
            d[name] = list(values)
        return ParamSpace.from_dict(d)

    def validate(self, space: ParamSpace) -> None:
        if self.sampler not in ("explicit", "grid", "moat"):
            raise SpecError(f"unknown sampler {self.sampler!r}")
        if self.policy not in POLICIES:
            raise SpecError(
                f"unknown policy {self.policy!r} (one of {sorted(POLICIES)})"
            )
        if self.sampler == "explicit" and not self.param_sets:
            raise SpecError("sampler='explicit' needs a non-empty param_sets")
        if self.sampler == "moat" and self.n_trajectories < 1:
            raise SpecError("n_trajectories must be >= 1")
        if self.priority < -16 or self.priority > 16:
            raise SpecError("priority must be within [-16, 16]")
        self._effective_space(space)  # raises on bad bounds

    def resolve(self, space: ParamSpace) -> List[ParamSet]:
        """The concrete run list this spec denotes over ``space``."""
        self.validate(space)
        eff = self._effective_space(space)
        if self.sampler == "explicit":
            out: List[ParamSet] = []
            defaults = dict(eff.default())
            for d in self.param_sets or ():
                unknown = set(d) - set(eff.names)
                if unknown:
                    raise SpecError(
                        f"param_set names unknown parameters: {sorted(unknown)}"
                    )
                full = dict(defaults)
                full.update(d)
                out.append(paramset(full))
        elif self.sampler == "grid":
            names = list(self.names or eff.names)
            unknown = set(names) - set(eff.names)
            if unknown:
                raise SpecError(f"grid names unknown: {sorted(unknown)}")
            by_name = {p.name: p.values for p in eff.params}
            count = 1
            for n in names:
                count *= len(by_name[n])
                if count > _MAX_RUNS:
                    raise SpecError(
                        f"grid sweep exceeds {_MAX_RUNS} runs; shrink "
                        "names/bounds or submit explicit points"
                    )
            defaults = dict(eff.default())
            out = []
            for combo in itertools.product(*(by_name[n] for n in names)):
                full = dict(defaults)
                full.update(zip(names, combo))
                out.append(paramset(full))
        else:  # moat
            from repro.study.samplers import MoatSampler
            from repro.study.state import StudyState

            state = StudyState(eff, seed=self.seed)
            sets, _meta = MoatSampler(self.n_trajectories).propose(state, 0)
            out = list(sets)
        if len(out) > _MAX_RUNS:
            raise SpecError(f"spec resolves to {len(out)} > {_MAX_RUNS} runs")
        if not out:
            raise SpecError("spec resolves to an empty run list")
        return out

    def signature(self, space: ParamSpace) -> str:
        """Content address of the work this spec denotes: equal signatures
        ⇒ identical plans ⇒ identical WorkItem keys ⇒ the Manager executes
        the study once however many tenants submit it. Dispatch-only
        fields (priority, timeout, metrics, poll) are deliberately
        excluded — they change who waits how, not what is computed."""
        runs = self.resolve(space)
        payload = json.dumps(
            {
                "runs": [[list(kv) for kv in ps] for ps in runs],
                "policy": self.policy,
                "max_bucket_size": self.max_bucket_size,
                "active_paths": self.active_paths,
            },
            sort_keys=True,
            default=str,
        )
        return hashlib.sha256(payload.encode()).hexdigest()

    def wants_caching(self) -> bool:
        return self.policy in CACHING_POLICIES

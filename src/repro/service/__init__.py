"""SA-as-a-service: a long-lived multi-tenant study server (DESIGN.md
§18) over one persistent Manager/SharedStore pool.

``StudyServer`` owns the pool and the job registry; tenants submit
serializable :class:`StudySpec` jobs — in process via the server object,
or over TCP via :class:`ServiceClient` against ``python -m repro.service``.
"""

from repro.service.client import ServiceClient, ServiceError  # noqa: F401
from repro.service.registry import (  # noqa: F401
    JOB_STATES,
    JobRecord,
    JobRegistry,
    QuotaExceeded,
    TenantQuota,
)
from repro.service.server import StudyServer  # noqa: F401
from repro.service.spec import SpecError, StudySpec  # noqa: F401

__all__ = [
    "JOB_STATES",
    "JobRecord",
    "JobRegistry",
    "QuotaExceeded",
    "ServiceClient",
    "ServiceError",
    "SpecError",
    "StudyServer",
    "StudySpec",
    "TenantQuota",
]

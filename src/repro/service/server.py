"""StudyServer — SA-as-a-service (DESIGN.md §18).

One long-lived server owns ONE persistent Manager session, one shared
:class:`~repro.engine.executor.ResultCache`, and one dataset+workflow; N
tenants submit :class:`~repro.service.spec.StudySpec` jobs against it
asynchronously:

* ``submit(tenant, spec) -> job_id`` — validate, resolve, plan, admission-
  check against the tenant's quota, register, and launch a job thread;
* ``status``/``result``/``cancel``/``list_jobs`` — the async job API;
* cross-tenant reuse — every job submits its WorkItems as **shared**
  (content-addressed key prefix = the spec signature), so identical
  concurrent submissions execute once in the Manager, and overlapping
  ones share task results through the server-wide cache;
* fair-share — each job's WorkItems carry ``tenant``/``priority``, so the
  Manager's deficit-round-robin dispatch keeps one tenant's backlog from
  starving another's;
* cancellation — ``cancel(job_id)`` revokes the job's *exclusive* keys in
  the Manager (queued work purged, in-flight leases poisoned) and signals
  the job thread; keys shared with other live jobs keep running for them.

The wire layer reuses the §16 socket conventions verbatim: length-
prefixed pickle frames over :class:`~repro.runtime.net.SocketConn`,
tagged by ``"t"``.
"""

from __future__ import annotations

import pickle
import socket
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.engine import ClusterSpec, plan_study
from repro.engine.executor import ResultCache
from repro.engine.streaming import execute_study, study_task_keys
from repro.engine.types import DEFAULT_CACHE_BYTES
from repro.runtime.fairshare import TaskCancelled
from repro.runtime.manager import Manager
from repro.runtime.net import PROTOCOL_VERSION, SocketConn, parse_address
from repro.runtime.transport import _recv_frame, _send_frame
from repro.service.registry import JobRegistry, QuotaExceeded, TenantQuota
from repro.service.spec import SpecError, StudySpec

__all__ = ["StudyServer"]


class StudyServer:
    """A multi-tenant async study server over one workflow and dataset.

    ``build`` semantics mirror the fleet runner: pass ``workflow``,
    ``space``, ``inputs``, ``objective`` (and optionally ``input_keys``)
    directly, or use :meth:`from_build` with a module-level build callable
    returning that mapping.
    """

    def __init__(
        self,
        *,
        workflow: Any,
        space: Any,
        inputs: Sequence[Any],
        objective: Callable[[Any, int], float],
        input_keys: Optional[Sequence[Any]] = None,
        n_workers: int = 2,
        backend: Any = None,
        hierarchy: Any = None,
        cluster: Optional[ClusterSpec] = None,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        default_quota: Optional[TenantQuota] = None,
    ) -> None:
        self.workflow = workflow
        self.space = space
        self.inputs = list(inputs)
        self.objective = objective
        self.input_keys = (
            list(input_keys)
            if input_keys is not None
            else list(range(len(self.inputs)))
        )
        self.cluster = cluster or ClusterSpec(n_workers=n_workers)
        self.registry = JobRegistry(default_quota)
        self.cache = ResultCache(cache_bytes)
        self._mgr = Manager(
            backend=backend,
            max_attempts=self.cluster.max_attempts,
            heartbeat_timeout=self.cluster.heartbeat_timeout,
            straggler_factor=self.cluster.straggler_factor,
            enable_backup_tasks=self.cluster.enable_backup_tasks,
            hierarchy=hierarchy,
        )
        self._mgr.start(self.cluster.n_workers)
        self._lock = threading.Lock()
        self._threads: Dict[str, threading.Thread] = {}  # guard: _lock
        self._timers: Dict[str, threading.Timer] = {}  # guard: _lock
        self._closed = False  # guard: _lock
        # wire-serving state (None until serve()/serve_background())
        self._srv_sock: Optional[socket.socket] = None  # guard: _lock
        self._serve_stop = threading.Event()
        self._conn_threads: List[threading.Thread] = []  # guard: _lock

    @classmethod
    def from_build(
        cls,
        build: Callable[..., Dict[str, Any]],
        build_kwargs: Optional[Dict[str, Any]] = None,
        **server_kwargs: Any,
    ) -> "StudyServer":
        spec = build(**(build_kwargs or {}))
        return cls(
            workflow=spec["workflow"],
            space=spec["space"],
            inputs=spec["inputs"],
            objective=spec["objective"],
            input_keys=spec.get("input_keys"),
            **server_kwargs,
        )

    @property
    def manager(self) -> Manager:
        return self._mgr

    # ------------------------------------------------------------------
    # The async job API
    # ------------------------------------------------------------------
    def submit(self, tenant: str, spec: StudySpec) -> str:
        """Admit and launch one study job; returns its job id.

        Raises :class:`~repro.service.spec.SpecError` on an unresolvable
        spec and :class:`~repro.service.registry.QuotaExceeded` on an
        over-budget one — both before any work is planned into the pool.
        """
        if not tenant or "/" in tenant:
            raise SpecError("tenant must be a non-empty name without '/'")
        with self._lock:
            if self._closed:
                raise RuntimeError("StudyServer is closed")
        param_sets = spec.resolve(self.space)
        sig = spec.signature(self.space)
        # Content-derived key prefix: equal signatures ⇒ equal WorkItem
        # keys ⇒ the Manager's shared-submission path executes once and
        # fans out to every subscribed job.
        prefix = f"svc:{sig[:16]}:"
        plan = plan_study(
            self.workflow,
            param_sets,
            cluster=self.cluster,
            policy=spec.policy,
            max_bucket_size=spec.max_bucket_size,
            active_paths=spec.active_paths,
        )
        keys = study_task_keys(plan, len(self.inputs), prefix)
        record = self.registry.admit(
            tenant,
            spec,
            prefix=prefix,
            signature=sig,
            keys=keys,
            priority=spec.priority,
        )
        thread = threading.Thread(
            target=self._run_job,
            args=(record.job_id, spec, plan, param_sets, prefix),
            name=f"svc-job-{record.job_id}",
            daemon=True,
        )
        with self._lock:
            if self._closed:
                self.registry.finish(
                    record.job_id, "CANCELLED", error="server closed"
                )
                self.registry.release(record.job_id)
                raise RuntimeError("StudyServer is closed")
            self._threads[record.job_id] = thread
            if spec.timeout_s is not None and spec.timeout_s > 0:
                timer = threading.Timer(
                    spec.timeout_s,
                    self._timeout_job,
                    args=(record.job_id,),
                )
                timer.daemon = True
                self._timers[record.job_id] = timer
                timer.start()
        thread.start()
        return record.job_id

    def _timeout_job(self, job_id: str) -> None:
        try:
            self.cancel(job_id)
        except Exception:  # noqa: BLE001 — watchdog must never raise
            pass

    def _run_job(
        self,
        job_id: str,
        spec: StudySpec,
        plan: Any,
        param_sets: List[Any],
        prefix: str,
    ) -> None:
        record = self.registry.get(job_id)
        try:
            self.registry.mark_running(job_id)
            t0 = time.perf_counter()
            stream = execute_study(
                plan,
                self.inputs,
                cluster=self.cluster,
                cache=self.cache,
                manager=self._mgr,
                input_keys=self.input_keys,
                key_prefix=prefix,
                shared=True,
                tenant=record.tenant,
                priority=spec.priority,
                cancel_event=record.cancel_event,
                on_progress=lambda done, _total: self.registry.progress(
                    job_id, done
                ),
            )
            n_inputs = len(self.inputs)
            payload: Dict[str, Any] = {
                "param_sets": [dict(ps) for ps in param_sets],
                "n_runs": len(param_sets),
                "n_inputs": n_inputs,
                "tasks_executed": stream.tasks_executed,
                "cache_hits": stream.cache_hits,
                "cache_misses": stream.cache_misses,
                "wall_seconds": time.perf_counter() - t0,
                "signature": record.signature,
            }
            if "objective" in spec.metrics or "per_input" in spec.metrics:
                per_input = [
                    [
                        float(self.objective(stream.outputs[i][rid], i))
                        for i in range(n_inputs)
                    ]
                    for rid in range(len(param_sets))
                ]
                if "per_input" in spec.metrics:
                    payload["per_input"] = per_input
                payload["objective"] = [
                    sum(vals) / len(vals) for vals in per_input
                ]
            self.registry.finish(
                job_id,
                "DONE",
                result=payload,
                result_bytes=len(
                    pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
                ),
            )
        except TaskCancelled:
            self.registry.finish(job_id, "CANCELLED", error="cancelled")
        except BaseException as err:  # noqa: BLE001 — job verdicts are data
            self.registry.finish(
                job_id,
                "FAILED",
                error="".join(
                    traceback.format_exception_only(type(err), err)
                ).strip(),
            )
        finally:
            with self._lock:
                timer = self._timers.pop(job_id, None)
            if timer is not None:
                timer.cancel()
            # reuse-tree release rule: forget ONLY keys no live job still
            # references — a sibling job sharing this signature (or a
            # later resubmission racing in) keeps the memos alive
            freed = self.registry.release(job_id)
            if freed and self._mgr.is_running:
                self._mgr.forget(freed)

    def status(self, job_id: str) -> Dict[str, Any]:
        return self.registry.get(job_id).public()

    def result(
        self,
        job_id: str,
        *,
        wait: bool = False,
        timeout: Optional[float] = None,
        poll_s: float = 0.05,
    ) -> Dict[str, Any]:
        """The job's terminal snapshot (``result`` payload included). With
        ``wait`` it blocks until the job leaves the live states (or the
        timeout lapses — the job keeps running; only the wait gives up)."""
        deadline = (
            None if timeout is None else time.monotonic() + max(0.0, timeout)
        )
        while True:
            rec = self.registry.get(job_id)
            snap = rec.public(with_result=True)
            if snap["state"] in ("DONE", "FAILED", "CANCELLED"):
                return snap
            if not wait:
                return snap
            if deadline is not None and time.monotonic() >= deadline:
                return snap
            time.sleep(poll_s)

    def cancel(self, job_id: str) -> Dict[str, Any]:
        """Cancel a job: exclusive keys are revoked in the Manager (queued
        purged, leases poisoned, exactly-once TaskCancelled settlement)
        and the job thread is signalled. Idempotent — cancelling a
        terminal job (or one that finished while the cancel was in
        flight) changes nothing and returns the settled snapshot."""
        rec = self.registry.get(job_id)
        rec.cancel_event.set()
        exclusive = self.registry.exclusive_keys(job_id)
        if exclusive and self._mgr.is_running:
            self._mgr.cancel(exclusive)
        return self.registry.get(job_id).public()

    def list_jobs(self, tenant: Optional[str] = None) -> List[Dict[str, Any]]:
        return self.registry.list_jobs(tenant)

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        self._mgr.set_tenant_weight(tenant, weight)

    def set_tenant_quota(self, tenant: str, quota: TenantQuota) -> None:
        self.registry.set_quota(tenant, quota)

    def stats(self) -> Dict[str, Any]:
        return {
            "scheduler": self._mgr.scheduler_stats(),
            "registry": self.registry.stats(),
            "cache": {
                "hits": self.cache.hits,
                "misses": self.cache.misses,
                "spills": self.cache.spills,
                "rehydrations": self.cache.rehydrations,
            },
            "n_inputs": len(self.inputs),
            "backend": self._mgr.backend_name,
        }

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def close(self, *, cancel_live: bool = True) -> None:
        """Retire the server: stop the wire listener, cancel (or wait out)
        live jobs, join job threads, and close the Manager session."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = dict(self._threads)
            timers = dict(self._timers)
            self._timers.clear()
        self._serve_stop.set()
        with self._lock:
            srv = self._srv_sock
            self._srv_sock = None
        if srv is not None:
            try:
                srv.close()
            except OSError:
                pass
        for timer in timers.values():
            timer.cancel()
        if cancel_live:
            for job_id in threads:
                try:
                    self.cancel(job_id)
                except KeyError:
                    pass
        for thread in threads.values():
            thread.join(timeout=30.0)
        with self._lock:
            conn_threads = list(self._conn_threads)
            self._conn_threads.clear()
        for thread in conn_threads:
            thread.join(timeout=5.0)
        self._mgr.close()

    def __enter__(self) -> "StudyServer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # ------------------------------------------------------------------
    # Wire layer (§16 conventions: length-prefixed pickle frames)
    # ------------------------------------------------------------------
    def serve_background(self, addr: str = "127.0.0.1:0") -> str:
        """Bind and serve on a daemon thread; returns the bound
        ``host:port`` (port 0 asks the OS for an ephemeral one)."""
        host, port = parse_address(addr)
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(64)
        with self._lock:
            if self._closed:
                srv.close()
                raise RuntimeError("StudyServer is closed")
            self._srv_sock = srv
        bound = f"{host}:{srv.getsockname()[1]}"
        thread = threading.Thread(
            target=self._accept_loop, args=(srv,), daemon=True,
            name="svc-accept",
        )
        thread.start()
        with self._lock:
            self._conn_threads.append(thread)
        return bound

    def serve_forever(self) -> None:
        """Block until the server is closed (after ``serve_background``)."""
        while not self._serve_stop.wait(0.5):
            pass

    def serve(self, addr: str) -> str:
        """Bind and block (the ``python -m repro.service`` entry): a
        convenience over ``serve_background`` + ``serve_forever``."""
        bound = self.serve_background(addr)
        self.serve_forever()
        return bound

    def _accept_loop(self, srv: socket.socket) -> None:
        while not self._serve_stop.is_set():
            try:
                sock, _peer = srv.accept()
            except OSError:
                return  # listener closed
            conn = SocketConn(sock)
            thread = threading.Thread(
                target=self._handle_conn, args=(conn,), daemon=True,
                name="svc-conn",
            )
            thread.start()
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conn_threads.append(thread)

    def _handle_conn(self, conn: SocketConn) -> None:
        """Per-connection request loop. One frame in, one frame out;
        request handling never holds the server lock across a send."""
        send_lock = threading.Lock()
        try:
            _send_frame(
                conn, send_lock, {"t": "svc_hello", "proto": PROTOCOL_VERSION}
            )
            while not self._serve_stop.is_set():
                msg = _recv_frame(conn)
                reply = self._dispatch_frame(msg)
                _send_frame(conn, send_lock, reply)
                if msg.get("t") == "bye":
                    return
        except (EOFError, OSError):
            return  # peer went away; nothing to clean up server-side
        finally:
            conn.close()

    def _dispatch_frame(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        kind = msg.get("t")
        try:
            if kind == "sub":
                spec = StudySpec.from_json(msg["spec"])
                job_id = self.submit(msg["tenant"], spec)
                return {"t": "sub_ok", "job_id": job_id}
            if kind == "stat":
                return {"t": "stat_ok", "job": self.status(msg["job_id"])}
            if kind == "res":
                job = self.result(
                    msg["job_id"],
                    wait=bool(msg.get("wait", False)),
                    timeout=msg.get("timeout"),
                )
                return {"t": "res_ok", "job": job}
            if kind == "cancel":
                return {"t": "cancel_ok", "job": self.cancel(msg["job_id"])}
            if kind == "jobs":
                return {
                    "t": "jobs_ok",
                    "jobs": self.list_jobs(msg.get("tenant")),
                }
            if kind == "weight":
                self.set_tenant_weight(
                    msg["tenant"], float(msg["weight"])
                )
                return {"t": "weight_ok"}
            if kind == "sstats":
                return {"t": "sstats_ok", "stats": self.stats()}
            if kind == "bye":
                return {"t": "bye_ok"}
            return {"t": "err", "error": f"unknown frame tag {kind!r}"}
        except (SpecError, QuotaExceeded, KeyError, RuntimeError) as err:
            return {
                "t": "err",
                "error": f"{type(err).__name__}: {err}",
            }

"""``python -m repro.service`` — run a StudyServer over TCP.

    python -m repro.service serve --addr 127.0.0.1:7481 \\
        --build repro.app.pipeline:pathology_service_build --workers 4

``--build`` names a ``module:callable`` returning the fleet-build mapping
(``workflow`` / ``space`` / ``inputs`` / ``objective`` / ``input_keys``);
the server binds, prints the bound address, and serves until interrupted.
"""

from __future__ import annotations

import argparse
import importlib
import sys
from typing import Any, Callable, Dict


def _resolve_build(ref: str) -> Callable[..., Dict[str, Any]]:
    mod_name, sep, attr = ref.partition(":")
    if not sep or not attr:
        raise SystemExit(f"--build must be 'module:callable', got {ref!r}")
    mod = importlib.import_module(mod_name)
    fn = getattr(mod, attr, None)
    if not callable(fn):
        raise SystemExit(f"{ref!r} does not name a callable")
    return fn


def main(argv: Any = None) -> int:
    parser = argparse.ArgumentParser(prog="repro.service")
    sub = parser.add_subparsers(dest="cmd", required=True)
    serve = sub.add_parser("serve", help="run a study server")
    serve.add_argument("--addr", default="127.0.0.1:0")
    serve.add_argument(
        "--build",
        default="repro.app.pipeline:pathology_service_build",
        help="module:callable returning the fleet-build mapping",
    )
    serve.add_argument("--workers", type=int, default=2)
    serve.add_argument(
        "--backend",
        default=None,
        help="worker backend (default: in-process threads)",
    )
    args = parser.parse_args(argv)

    from repro.service.server import StudyServer

    server = StudyServer.from_build(
        _resolve_build(args.build),
        n_workers=args.workers,
        backend=args.backend,
    )
    bound = server.serve_background(args.addr)
    print(f"repro.service listening on {bound}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Job registry — the service's bookkeeping core (DESIGN.md §18).

The registry owns three things, all under one lock:

* **job records** — the QUEUED → RUNNING → {DONE, FAILED, CANCELLED}
  lifecycle, per-job progress counters, and retained result payloads;
* **key refcounts** — every WorkItem key a live job references, mapped to
  the set of jobs referencing it. Shared (content-addressed) submissions
  mean one key can serve many jobs; the Manager's memo for a key may be
  released (``forget``) only when the LAST referencing job ends, and a
  key may be *cancelled* only while exactly one live job references it —
  both queries answered here;
* **tenant quotas** — admission control: live-task and retained-result-
  byte budgets per tenant, checked atomically with registration so two
  racing submissions cannot both squeeze under the cap.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Set

__all__ = [
    "JobRecord",
    "JobRegistry",
    "QuotaExceeded",
    "TenantQuota",
    "JOB_STATES",
]

# Lifecycle state machine: QUEUED -> RUNNING -> one of the terminal three.
# CANCELLED can be entered from QUEUED or RUNNING; terminal states never
# transition again (cancel on a terminal job is an idempotent no-op).
JOB_STATES = ("QUEUED", "RUNNING", "DONE", "FAILED", "CANCELLED")
_TERMINAL = frozenset(("DONE", "FAILED", "CANCELLED"))


class QuotaExceeded(RuntimeError):
    """Admission rejected: the tenant's live-task, live-job or retained-
    result-byte budget would be exceeded. Nothing was registered."""


@dataclasses.dataclass
class TenantQuota:
    """Per-tenant admission budget.

    max_live_tasks   — total WorkItem keys across the tenant's QUEUED +
                       RUNNING jobs (a submission counts its full task
                       list at admission, before anything is queued).
    max_live_jobs    — concurrent non-terminal jobs.
    max_result_bytes — retained result payload bytes across the tenant's
                       DONE jobs (freed when a job is evicted/forgotten).
    """

    max_live_tasks: int = 200_000
    max_live_jobs: int = 64
    max_result_bytes: int = 256 << 20


@dataclasses.dataclass
class JobRecord:
    """One job's full lifecycle record. Mutable fields are guarded by the
    owning registry's lock; ``cancel_event`` is the cross-thread cancel
    signal the executor polls."""

    job_id: str
    tenant: str
    spec: Any
    prefix: str
    signature: str
    keys: List[str]
    total_tasks: int
    priority: int = 0
    state: str = "QUEUED"
    done_tasks: int = 0
    error: Optional[str] = None
    result: Optional[Dict[str, Any]] = None
    result_bytes: int = 0
    created_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    cancel_event: threading.Event = dataclasses.field(
        default_factory=threading.Event
    )

    def public(self, *, with_result: bool = False) -> Dict[str, Any]:
        """The wire-safe snapshot of this record (no events/threads)."""
        out = {
            "job_id": self.job_id,
            "tenant": self.tenant,
            "state": self.state,
            "priority": self.priority,
            "total_tasks": self.total_tasks,
            "done_tasks": self.done_tasks,
            "error": self.error,
            "created_at": self.created_at,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "signature": self.signature,
        }
        if with_result:
            out["result"] = self.result
        return out


class JobRegistry:
    """Thread-safe job/refcount/quota bookkeeping for one StudyServer."""

    def __init__(self, default_quota: Optional[TenantQuota] = None) -> None:
        self._lock = threading.Lock()
        self._jobs: Dict[str, JobRecord] = {}  # guard: _lock
        # WorkItem key -> ids of live jobs referencing it. The Manager memo
        # behind a key may be forgotten only when this set empties.
        self._key_refs: Dict[str, Set[str]] = {}  # guard: _lock
        self._tenant_seq: Dict[str, int] = {}  # guard: _lock
        self._quotas: Dict[str, TenantQuota] = {}  # guard: _lock
        self._default_quota = default_quota or TenantQuota()

    # ------------------------------------------------------------------
    # Quotas
    # ------------------------------------------------------------------
    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        with self._lock:
            self._quotas[tenant] = quota

    def _quota_locked(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self._default_quota)

    def _usage_locked(self, tenant: str) -> Dict[str, int]:
        live_tasks = live_jobs = result_bytes = 0
        for rec in self._jobs.values():
            if rec.tenant != tenant:
                continue
            if rec.state not in _TERMINAL:
                live_jobs += 1
                live_tasks += rec.total_tasks
            result_bytes += rec.result_bytes
        return {
            "live_tasks": live_tasks,
            "live_jobs": live_jobs,
            "result_bytes": result_bytes,
        }

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def admit(
        self,
        tenant: str,
        spec: Any,
        *,
        prefix: str,
        signature: str,
        keys: List[str],
        priority: int = 0,
        est_result_bytes: int = 0,
    ) -> JobRecord:
        """Atomically check the tenant's quota and register the job.
        Raises :class:`QuotaExceeded` without side effects on rejection."""
        with self._lock:
            quota = self._quota_locked(tenant)
            use = self._usage_locked(tenant)
            if use["live_jobs"] + 1 > quota.max_live_jobs:
                raise QuotaExceeded(
                    f"tenant {tenant!r}: {use['live_jobs']} live jobs at the "
                    f"cap of {quota.max_live_jobs}"
                )
            if use["live_tasks"] + len(keys) > quota.max_live_tasks:
                raise QuotaExceeded(
                    f"tenant {tenant!r}: job of {len(keys)} tasks would "
                    f"exceed the live-task budget "
                    f"({use['live_tasks']}/{quota.max_live_tasks} used)"
                )
            if (
                use["result_bytes"] + est_result_bytes
                > quota.max_result_bytes
            ):
                raise QuotaExceeded(
                    f"tenant {tenant!r}: retained results at "
                    f"{use['result_bytes']} bytes; job would exceed the "
                    f"{quota.max_result_bytes}-byte budget"
                )
            seq = self._tenant_seq.get(tenant, 0)
            self._tenant_seq[tenant] = seq + 1
            rec = JobRecord(
                job_id=f"{tenant}/j{seq}",
                tenant=tenant,
                spec=spec,
                prefix=prefix,
                signature=signature,
                keys=list(keys),
                total_tasks=len(keys),
                priority=priority,
                created_at=time.time(),
            )
            self._jobs[rec.job_id] = rec
            for k in rec.keys:
                self._key_refs.setdefault(k, set()).add(rec.job_id)
            return rec

    def get(self, job_id: str) -> JobRecord:
        with self._lock:
            rec = self._jobs.get(job_id)
            if rec is None:
                raise KeyError(f"unknown job {job_id!r}")
            return rec

    def list_jobs(self, tenant: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            return [
                rec.public()
                for rec in self._jobs.values()
                if tenant is None or rec.tenant == tenant
            ]

    def mark_running(self, job_id: str) -> None:
        with self._lock:
            rec = self._jobs[job_id]
            if rec.state == "QUEUED":
                rec.state = "RUNNING"
                rec.started_at = time.time()

    def progress(self, job_id: str, done: int) -> None:
        with self._lock:
            rec = self._jobs.get(job_id)
            if rec is not None and rec.state == "RUNNING":
                rec.done_tasks = max(rec.done_tasks, int(done))

    def finish(
        self,
        job_id: str,
        state: str,
        *,
        result: Optional[Dict[str, Any]] = None,
        result_bytes: int = 0,
        error: Optional[str] = None,
    ) -> None:
        """Transition to a terminal state. First terminal transition wins
        (a cancel racing a natural completion cannot flip the verdict)."""
        if state not in _TERMINAL:
            raise ValueError(f"{state!r} is not a terminal job state")
        with self._lock:
            rec = self._jobs[job_id]
            if rec.state in _TERMINAL:
                return
            rec.state = state
            rec.finished_at = time.time()
            rec.result = result
            rec.result_bytes = int(result_bytes)
            rec.error = error
            if state == "DONE":
                rec.done_tasks = rec.total_tasks

    # ------------------------------------------------------------------
    # Key reference counting (the reuse-tree release rule)
    # ------------------------------------------------------------------
    def exclusive_keys(self, job_id: str) -> List[str]:
        """Keys referenced by this job and NO other live job — the only
        keys a cancel may revoke in the Manager (revoking a shared key
        would poison another tenant's subscription)."""
        with self._lock:
            rec = self._jobs.get(job_id)
            if rec is None:
                return []
            return [
                k
                for k in rec.keys
                if self._key_refs.get(k, set()) <= {job_id}
            ]

    def release(self, job_id: str) -> List[str]:
        """Drop the job's key references; returns the keys whose refcount
        hit zero — the caller forgets exactly those in the Manager. Safe
        to call once per job (idempotent: a second call finds no refs)."""
        freed: List[str] = []
        with self._lock:
            rec = self._jobs.get(job_id)
            if rec is None:
                return freed
            for k in rec.keys:
                refs = self._key_refs.get(k)
                if refs is None:
                    continue
                refs.discard(job_id)
                if not refs:
                    del self._key_refs[k]
                    freed.append(k)
        return freed

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            by_state: Dict[str, int] = {}
            for rec in self._jobs.values():
                by_state[rec.state] = by_state.get(rec.state, 0) + 1
            return {
                "jobs": len(self._jobs),
                "by_state": by_state,
                "live_keys": len(self._key_refs),
                "shared_keys": sum(
                    1 for refs in self._key_refs.values() if len(refs) > 1
                ),
            }

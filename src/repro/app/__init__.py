"""The paper's motivating pathology-image application, implemented in JAX."""

from repro.app.pipeline import (  # noqa: F401
    TABLE1_SPACE,
    build_segmentation_stage,
    build_workflow,
    run_adaptive_study,
    run_dataset_study,
    run_fleet_study,
    run_study,
    synthetic_tile,
)

"""JAX implementations of the pathology-pipeline operators (paper Fig 1).

The motivating application normalises a whole-slide H&E tile, segments cell
nuclei through a chain of threshold / morphological operators, and compares
each run's mask with the default-parameter mask (Dice). Every operator below
is a pure, jittable function on ``float32``/``bool`` arrays; the propagation
hot-spot (morphological reconstruction, also the engine behind fill-holes and
the watershed flooding) has a Pallas TPU kernel in
``repro.kernels.morph_recon`` — here we call its dispatching wrapper.

Connectivity parameters (FH / RC / WConn in Table I) are 4 or 8 and must be
*static* under jit (they select the structuring element).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import dilate, erode, neighbors as _neighbors, shift2d as _shift

__all__ = [
    "normalize_tile",
    "background_mask",
    "rbc_mask",
    "dilate",
    "erode",
    "morph_reconstruct",
    "fill_holes",
    "label_components",
    "component_sizes",
    "area_filter",
    "distance_transform",
    "watershed_split",
]


@jax.jit
def normalize_tile(rgb: jax.Array) -> jax.Array:
    """Stain/intensity normalisation: per-channel standardisation onto the
    reference mean/std used across the study (shared by every SA run)."""
    x = rgb.astype(jnp.float32)
    mean = jnp.mean(x, axis=(0, 1), keepdims=True)
    std = jnp.std(x, axis=(0, 1), keepdims=True) + 1e-6
    target_mean = jnp.array([200.0, 160.0, 180.0])  # H&E-like reference
    target_std = jnp.array([40.0, 45.0, 40.0])
    return (x - mean) / std * target_std + target_mean


@jax.jit
def background_mask(rgb: jax.Array, b: jax.Array, g: jax.Array, r: jax.Array) -> jax.Array:
    """Background detection (B/G/R thresholds): bright-in-all-channels pixels
    are glass/background. Returns the *foreground* (tissue) mask."""
    bg = (rgb[..., 2] > b) & (rgb[..., 1] > g) & (rgb[..., 0] > r)
    return ~bg


@jax.jit
def rbc_mask(rgb: jax.Array, t1: jax.Array, t2: jax.Array) -> jax.Array:
    """Red-blood-cell detection (T1/T2 ratio thresholds): red-dominant pixels
    with R/G > T1 and R/B > T2 are RBCs, excluded from nuclei candidates."""
    r = rgb[..., 0]
    g = rgb[..., 1] + 1.0
    bl = rgb[..., 2] + 1.0
    return (r / g > t1) & (r / bl > t2)


def morph_reconstruct(
    marker: jax.Array, mask: jax.Array, conn: int = 8, *, use_kernel: bool = True
) -> jax.Array:
    """Grayscale morphological reconstruction by dilation: iterate
    ``marker ← min(dilate(marker), mask)`` to fixpoint. Dispatches to the
    Pallas tile kernel on TPU; pure-XLA loop elsewhere."""
    from repro.kernels import ops as kops

    return kops.morph_reconstruct(marker, mask, conn=conn, use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("conn",))
def fill_holes(mask: jax.Array, conn: int = 4) -> jax.Array:
    """Binary fill-holes via reconstruction of the complement from the border
    (FH parameter selects the propagation neighbourhood)."""
    from repro.kernels import ref as kref

    inv = (~mask).astype(jnp.float32)
    border = jnp.zeros_like(inv)
    border = border.at[0, :].set(inv[0, :])
    border = border.at[-1, :].set(inv[-1, :])
    border = border.at[:, 0].set(inv[:, 0])
    border = border.at[:, -1].set(inv[:, -1])
    outside = kref.morph_reconstruct_ref(border, inv, conn=conn)
    return mask | (outside < 0.5)


@functools.partial(jax.jit, static_argnames=("conn",))
def label_components(mask: jax.Array, conn: int = 8) -> jax.Array:
    """Connected-component labels by iterative min-label propagation.

    Labels are flat pixel indices (stable, deterministic); background = -1.
    The loop runs until fixpoint — bounded by the component diameter.
    """
    h, w = mask.shape
    idx = jnp.arange(h * w, dtype=jnp.int32).reshape(h, w)
    big = jnp.int32(h * w)
    labels = jnp.where(mask, idx, big)

    def body(state):
        lab, _ = state
        new = lab
        for dy, dx in _neighbors(conn):
            new = jnp.minimum(new, _shift(lab, dy, dx, big))
        new = jnp.where(mask, new, big)
        return new, jnp.any(new != lab)

    def cond(state):
        return state[1]

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.bool_(True)))
    return jnp.where(mask, labels, -1)


@jax.jit
def component_sizes(labels: jax.Array) -> jax.Array:
    """Per-pixel size of the component the pixel belongs to (0 for bg)."""
    h, w = labels.shape
    flat = labels.reshape(-1)
    valid = flat >= 0
    counts = jnp.zeros(h * w + 1, dtype=jnp.int32).at[
        jnp.where(valid, flat, h * w)
    ].add(1)
    counts = counts.at[h * w].set(0)
    return counts[jnp.where(valid, flat, h * w)].reshape(h, w)


@functools.partial(jax.jit, static_argnames=("conn",))
def area_filter(
    mask: jax.Array, min_size: jax.Array, max_size: jax.Array, conn: int = 8
) -> jax.Array:
    """Drop components outside [min_size, max_size] (MinSize/MaxSize params)."""
    labels = label_components(mask, conn=conn)
    sizes = component_sizes(labels)
    return mask & (sizes >= min_size) & (sizes <= max_size)


@functools.partial(jax.jit, static_argnames=("conn", "max_iters"))
def distance_transform(mask: jax.Array, conn: int = 4, max_iters: int = 64) -> jax.Array:
    """Chamfer-style distance to background by iterated erosion counting."""
    def body(i, state):
        cur, dist = state
        nxt = erode(cur, conn=conn) * mask.astype(jnp.float32)
        return nxt, dist + nxt

    cur = mask.astype(jnp.float32)
    _, dist = jax.lax.fori_loop(0, max_iters, body, (cur, cur))
    return dist


@functools.partial(jax.jit, static_argnames=("conn",))
def watershed_split(
    mask: jax.Array, min_size_pl: jax.Array, conn: int = 8
) -> jax.Array:
    """Watershed-style splitting of touching nuclei (WConn / MinSizePl).

    Seeds = regional maxima of the distance transform; seeded flood by
    iterative nearest-seed propagation (same engine as the paper's irregular
    wavefront propagation); pixels where two different seeds collide form the
    split lines, which are removed from the mask. Components smaller than
    ``min_size_pl`` are dropped *before* splitting (paper's MinSizePl)."""
    pre = mask & (component_sizes(label_components(mask, conn=conn)) >= min_size_pl)
    dist = distance_transform(pre, conn=4)
    maxima = (dist >= dilate(dist, conn=conn)) & pre & (dist > 1.0)
    h, w = mask.shape
    big = jnp.int32(h * w)
    # merge plateau maxima into one seed per regional maximum
    seed_labels = label_components(maxima, conn=8)
    seeds = jnp.where(maxima, seed_labels, big)

    def body(state):
        """Competitive multi-source BFS: unlabeled pixels take the min
        neighbouring label; labelled pixels never change, so basins stop at
        collision fronts (the watershed lines)."""
        lab, _ = state
        nb = jnp.full_like(lab, big)
        for dy, dx in _neighbors(conn):
            nb = jnp.minimum(nb, _shift(lab, dy, dx, big))
        new = jnp.where((lab == big) & pre, nb, lab)
        return new, jnp.any(new != lab)

    lab, _ = jax.lax.while_loop(lambda s: s[1], body, (seeds, jnp.bool_(True)))
    # split line: a pixel adjacent (4-conn) to a pixel of a different basin
    boundary = jnp.zeros_like(mask)
    for dy, dx in _neighbors(4):
        nb = _shift(lab, dy, dx, big)
        boundary = boundary | ((nb != lab) & (nb != big) & (lab != big))
    return pre & ~boundary

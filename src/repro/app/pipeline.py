"""The paper's motivating application as a :class:`repro.core.Workflow`.

Three coarse stages (Fig 1): **normalization** (parameter-free, hence fully
shared across SA runs), **segmentation** (seven fine-grain tasks Seg0..Seg6,
consuming the Table I parameters in pipeline order) and **comparison** (Dice
vs the default-parameter reference).

The per-task parameter mapping is the contract the reuse trie keys on:

  Seg0 background   (B, G, R)          Seg4 area-pre     (minS, maxS)
  Seg1 rbc          (T1, T2)           Seg5 watershed    (minSPL, WConn)
  Seg2 morph-recon  (G1, RC)           Seg6 area-final   (minSS, maxSS)
  Seg3 threshold+fh (G2, FH)
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.app import ops
from repro.core import ParamSpace, StageSpec, TaskSpec, Workflow, dice
from repro.core.metrics import reuse_factor
from repro.core.params import ParamSet
from repro.engine import (
    ClusterSpec,
    MemoryBudget,
    execute_plan,
    execute_study,
    plan_study,
)

__all__ = [
    "TABLE1_SPACE",
    "synthetic_tile",
    "build_segmentation_stage",
    "build_workflow",
    "run_study",
    "run_dataset_study",
    "run_adaptive_study",
    "run_fleet_study",
]

# --------------------------------------------------------------------------
# Table I of the paper — the application parameter space.
# --------------------------------------------------------------------------

TABLE1_SPACE = ParamSpace.from_dict(
    {
        "B": list(range(210, 241, 10)),
        "G": list(range(210, 241, 10)),
        "R": list(range(210, 241, 10)),
        "T1": [x / 2.0 for x in range(5, 16)],  # 2.5 .. 7.5
        "T2": [x / 2.0 for x in range(5, 16)],
        "G1": list(range(5, 81, 5)),
        "G2": list(range(2, 41, 2)),
        "minS": list(range(2, 41, 2)),
        "maxS": list(range(900, 1501, 50)),
        "minSPL": list(range(5, 81, 5)),
        "minSS": list(range(2, 41, 2)),
        "maxSS": list(range(900, 1501, 50)),
        "FH": [4, 8],
        "RC": [4, 8],
        "WConn": [4, 8],
    }
)


def synthetic_tile(h: int = 256, w: int = 256, *, seed: int = 0) -> np.ndarray:
    """Synthetic H&E-like tile: pink stroma, dark nuclei blobs, red RBCs and
    a bright glass/background band — enough structure for every Table I
    parameter to matter."""
    rng = np.random.default_rng(seed)
    img = np.empty((h, w, 3), np.float32)
    img[..., 0] = 215 + rng.normal(0, 6, (h, w))  # R
    img[..., 1] = 170 + rng.normal(0, 6, (h, w))  # G
    img[..., 2] = 195 + rng.normal(0, 6, (h, w))  # B
    yy, xx = np.mgrid[0:h, 0:w]

    def blobs(n, rmin, rmax, color, jitter=10.0):
        for _ in range(n):
            cy, cx = rng.integers(0, h), rng.integers(0, w)
            rad = rng.uniform(rmin, rmax)
            d2 = (yy - cy) ** 2 + (xx - cx) ** 2
            m = d2 < rad**2
            for c in range(3):
                img[..., c][m] = color[c] + rng.normal(0, jitter)

    blobs(max(4, h * w // 1600), 3.0, 9.0, (110, 70, 150))  # nuclei (purple)
    blobs(max(2, h * w // 6400), 2.0, 6.0, (190, 60, 70))  # RBCs (red)
    img[: h // 8, :, :] = 245 + rng.normal(0, 3, (h // 8, w, 3))  # glass
    return np.clip(img, 0, 255).astype(np.float32)


# --------------------------------------------------------------------------
# Task implementations. State is a dict of arrays flowing down the pipeline.
# --------------------------------------------------------------------------


def _t_background(state, B, G, R):
    rgb = state["rgb"]
    fg = ops.background_mask(rgb, jnp.float32(B), jnp.float32(G), jnp.float32(R))
    return {"rgb": rgb, "fg": fg}


def _t_rbc(state, T1, T2):
    rgb, fg = state["rgb"], state["fg"]
    rbc = ops.rbc_mask(rgb, jnp.float32(T1), jnp.float32(T2))
    keep = fg & ~rbc
    gray = (255.0 - rgb[..., 2]) * keep.astype(jnp.float32)  # hematoxylin proxy
    return {"gray": gray}


def _t_recon(state, G1, RC):
    gray = state["gray"]
    marker = jnp.maximum(gray - jnp.float32(G1), 0.0)
    recon = ops.morph_reconstruct(marker, gray, conn=int(RC), use_kernel=False)
    return {"gray": gray, "residual": gray - recon}


def _t_threshold(state, G2, FH):
    cand = state["residual"] > jnp.float32(G2) * 0.5
    return {"mask": ops.fill_holes(cand, conn=int(FH))}


def _t_area_pre(state, minS, maxS):
    return {"mask": ops.area_filter(state["mask"], jnp.int32(minS), jnp.int32(maxS))}


def _t_watershed(state, minSPL, WConn):
    return {"mask": ops.watershed_split(state["mask"], jnp.int32(minSPL), conn=int(WConn))}


def _t_area_final(state, minSS, maxSS):
    return {"mask": ops.area_filter(state["mask"], jnp.int32(minSS), jnp.int32(maxSS))}


def build_segmentation_stage(
    h: int, w: int, costs: Optional[Dict[str, float]] = None
) -> StageSpec:
    """The Seg0..Seg6 pipeline with byte-exact output sizes for the memory
    model (float32 image payloads dominate; masks are byte-packed)."""
    px = h * w
    costs = costs or {}
    spec = [
        ("seg0_background", ("B", "G", "R"), _t_background, 4 * px * 3 + px),
        ("seg1_rbc", ("T1", "T2"), _t_rbc, 4 * px),
        ("seg2_recon", ("G1", "RC"), _t_recon, 8 * px),
        ("seg3_threshold", ("G2", "FH"), _t_threshold, px),
        ("seg4_area_pre", ("minS", "maxS"), _t_area_pre, px),
        ("seg5_watershed", ("minSPL", "WConn"), _t_watershed, px),
        ("seg6_area_final", ("minSS", "maxSS"), _t_area_final, px),
    ]
    default_cost = {"seg2_recon": 4.0, "seg5_watershed": 3.0}
    tasks = tuple(
        TaskSpec(
            name=n,
            param_names=p,
            fn=f,
            cost=costs.get(n, default_cost.get(n, 1.0)),
            output_bytes=b,
        )
        for n, p, f, b in spec
    )
    return StageSpec(name="segmentation", tasks=tasks)


def _t_normalize(state):
    return {"rgb": ops.normalize_tile(state["raw"])}


def build_workflow(h: int, w: int, costs: Optional[Dict[str, float]] = None) -> Workflow:
    px = h * w
    norm = StageSpec(
        name="normalization",
        tasks=(
            TaskSpec(
                name="normalize",
                param_names=(),
                fn=_t_normalize,
                cost=1.0,
                output_bytes=12 * px,
            ),
        ),
    )
    seg = build_segmentation_stage(h, w, costs)
    return Workflow(stages=(norm, seg))


# --------------------------------------------------------------------------
# SA study drivers: thin callers of the StudyPlanner engine.
# --------------------------------------------------------------------------


def pathology_rpc_build(
    images: Sequence[np.ndarray], costs: Optional[Dict[str, float]] = None
) -> Dict[str, Any]:
    """Spawn-picklable ``build`` for the RPC process backend
    (:class:`repro.runtime.ProcessRpcBackend`): each worker process calls
    this once to construct its own workflow and input states from the tile
    arrays shipped in the build kwargs — inputs ride the spawn boundary
    once, at worker start; results only ever come back as SharedStore keys.
    """
    images = [np.asarray(im) for im in images]
    h, w = images[0].shape[:2]
    return {
        "workflow": build_workflow(h, w, costs),
        "inputs": [{"raw": jnp.asarray(im)} for im in images],
    }


def _backend_for(
    backend: Any,
    images: Sequence[np.ndarray],
    costs: Optional[Dict[str, float]],
    store_dir: Optional[str] = None,
) -> Any:
    """Resolve the app-level ``backend`` spec: ``None``/``"thread"`` pass
    through to the Manager's default; ``"process"`` — optionally with the
    per-optimization flag suffix of DESIGN.md §14, e.g.
    ``"process[-async]"`` or ``"process[none,batch,max_batch=4]"`` (see
    :func:`repro.runtime.transport.process_flag_kwargs`) — builds a
    ProcessRpcBackend whose workers reconstruct this exact study via
    :func:`pathology_rpc_build`; a constructed WorkerBackend passes
    through untouched. ``store_dir`` mounts the workers' stores on a
    caller-owned directory (the adaptive study's persistent pool, so a
    resumed study still rehydrates the workers' task outputs); without it
    the backend owns a throwaway tempdir the caller must ``cleanup()``."""
    if isinstance(backend, str) and backend.startswith("process"):
        from repro.runtime import ProcessRpcBackend
        from repro.runtime.transport import process_flag_kwargs

        return ProcessRpcBackend(
            build=pathology_rpc_build,
            build_kwargs={
                "images": [np.asarray(im) for im in images],
                "costs": costs,
            },
            store_dir=store_dir,
            **process_flag_kwargs(backend),
        )
    if isinstance(backend, str) and backend.startswith("socket"):
        # "socket[...]" (DESIGN.md §16): TCP control plane; workers rebuild
        # this study from the same spawn-picklable build. A store= token in
        # the spec (e.g. store=obj:<root>) overrides store_dir so a fleet
        # can run with no shared filesystem at all.
        from repro.runtime import SocketBackend, socket_flag_kwargs

        kwargs = socket_flag_kwargs(backend)
        kwargs.setdefault("store", store_dir)
        return SocketBackend(
            build=pathology_rpc_build,
            build_kwargs={
                "images": [np.asarray(im) for im in images],
                "costs": costs,
            },
            **kwargs,
        )
    return backend


def _backend_cleanup(spec: Any, backend_obj: Any) -> None:
    """Release a backend `_backend_for` constructed (drop a throwaway
    tempdir store); caller-provided backends are untouched."""
    if (
        isinstance(spec, str)
        and (spec.startswith("process") or spec.startswith("socket"))
        and hasattr(backend_obj, "cleanup")
    ):
        backend_obj.cleanup()


def _round_detail(r: Any) -> Dict[str, Any]:
    """One round's reporting dict, shared by the adaptive and fleet study
    summaries so the two never drift."""
    return {
        "kind": r.kind,
        "n_proposed": r.n_proposed,
        "n_new": r.n_new,
        "planned_tasks": r.planned_tasks,
        "planned_known": r.planned_known,
        "tasks_executed": r.tasks_executed,
        "cache_hits": r.cache_hits,
        "analysis": r.analysis,
        "decision": r.decision,
    }


def _plan_image_study(
    h: int,
    w: int,
    param_sets: Sequence[ParamSet],
    *,
    strategy: str,
    max_bucket_size: Optional[int],
    active_paths: Optional[int],
    costs: Optional[Dict[str, float]],
    n_workers: int,
    memory_budget_bytes: Optional[int],
):
    """Shared planning preamble of the single-tile and dataset drivers:
    build the workflow for the tile shape and plan the study (with the
    headline ``active_paths=4`` default when there is no budget to solve
    against). Returns ``(workflow, plan, cluster)``."""
    wf = build_workflow(h, w, costs)
    memory = MemoryBudget(bytes=memory_budget_bytes)
    cluster = ClusterSpec(n_workers=n_workers)
    if active_paths is None and memory_budget_bytes is None:
        active_paths = 4  # headline depth-first width when nothing to solve
    plan = plan_study(
        wf,
        list(param_sets),
        memory=memory,
        cluster=cluster,
        policy=strategy,
        max_bucket_size=max_bucket_size,
        active_paths=active_paths,
    )
    return wf, plan, cluster


def run_study(
    image: np.ndarray,
    param_sets: Sequence[ParamSet],
    *,
    strategy: str = "rmsr",
    max_bucket_size: Optional[int] = None,
    active_paths: Optional[int] = None,
    reference_params: Optional[ParamSet] = None,
    costs: Optional[Dict[str, float]] = None,
    n_workers: int = 1,
    memory_budget_bytes: Optional[int] = None,
    backend: Any = None,
    hierarchy: Any = None,
) -> Dict[str, Any]:
    """Execute an SA study over one tile and return per-run Dice + counters.

    ``strategy`` is the engine's bucketing policy ∈ {"none", "stage",
    "rtma", "rmsr", "hybrid"}; ``max_bucket_size`` bounds RTMA/hybrid
    merging (default rtma→8; rmsr merges maximally, the paper's headline
    configuration). ``n_workers`` dispatches buckets demand-driven through
    the Manager. ``backend`` picks the session's WorkerBackend —
    ``"thread"`` (default) or ``"process"`` for RPC worker processes
    pooling results through a SharedStore (the reference segmentation stays
    in-process: it is a single run).

    ``tasks_executed`` is the MEASURED count (cache hits subtracted) —
    the same semantics as ``run_dataset_study`` — while
    ``planned_tasks_executed`` / ``reuse_fraction`` report the plan's
    merge-level accounting (the paper's analytic counts).
    """
    h, w = image.shape[:2]
    ref_params = reference_params or TABLE1_SPACE.default()

    t0 = time.perf_counter()
    wf, plan, _cluster = _plan_image_study(
        h, w, param_sets,
        strategy=strategy, max_bucket_size=max_bucket_size,
        active_paths=active_paths, costs=costs, n_workers=n_workers,
        memory_budget_bytes=memory_budget_bytes,
    )
    raw = {"raw": jnp.asarray(image)}
    backend_obj = _backend_for(backend, [image], costs)
    try:
        result = execute_plan(plan, raw, backend=backend_obj, hierarchy=hierarchy)
    finally:
        _backend_cleanup(backend, backend_obj)

    ref_plan = plan_study(wf, [ref_params], policy="rmsr", active_paths=1)
    ref_mask = execute_plan(ref_plan, raw).outputs[0]["mask"]

    dices = [
        float(dice(result.outputs[rid]["mask"], ref_mask))
        for rid in range(len(param_sets))
    ]
    wall = time.perf_counter() - t0
    return {
        "dice": dices,
        "tasks_total": plan.tasks_total,
        "tasks_executed": result.tasks_executed,
        "planned_tasks_executed": plan.tasks_executed,
        "reuse_fraction": plan.reuse_fraction,
        "reuse_factor": reuse_factor(result.tasks_executed, plan.tasks_total),
        "peak_bytes": plan.peak_bytes,
        "wall_seconds": wall,
        "reference_mask": np.asarray(ref_mask),
        "cache_hits": result.cache_hits,
        "cache_misses": result.cache_misses,
        "cache_spills": result.cache_spills,
        "backend": result.backend,
        "dispatch_counts": dict(result.dispatch_counts),
        "cache_flushed": 0,  # no persistent spill store in one-shot mode
        "plan": plan,
    }


def run_dataset_study(
    images: Sequence[np.ndarray],
    param_sets: Sequence[ParamSet],
    *,
    strategy: str = "hybrid",
    max_bucket_size: Optional[int] = None,
    active_paths: Optional[int] = None,
    reference_params: Optional[ParamSet] = None,
    costs: Optional[Dict[str, float]] = None,
    n_workers: int = 2,
    memory_budget_bytes: Optional[int] = None,
    backend: Any = None,
    hierarchy: Any = None,
) -> Dict[str, Any]:
    """Dataset-level SA study: many tiles streamed through ONE plan and one
    persistent Manager session (DESIGN.md §10).

    Plans once, then pipelines every tile concurrently through all stages —
    tile A can be in segmentation while tile B normalizes. Returns per-tile
    Dice lists plus the streaming throughput/parallel-efficiency metrics.
    All tiles must share one shape (the plan's byte model is shape-exact).
    ``backend`` picks the session's WorkerBackend (``"thread"`` default,
    ``"process"`` for RPC worker processes); the single-run reference
    segmentation always executes in-process.
    """
    images = list(images)
    if not images:
        raise ValueError("run_dataset_study needs at least one tile")
    h, w = images[0].shape[:2]
    if any(im.shape[:2] != (h, w) for im in images):
        raise ValueError("all tiles must share one (h, w) shape")
    ref_params = reference_params or TABLE1_SPACE.default()

    t0 = time.perf_counter()
    wf, plan, cluster = _plan_image_study(
        h, w, param_sets,
        strategy=strategy, max_bucket_size=max_bucket_size,
        active_paths=active_paths, costs=costs, n_workers=n_workers,
        memory_budget_bytes=memory_budget_bytes,
    )
    raws = [{"raw": jnp.asarray(im)} for im in images]
    backend_obj = _backend_for(backend, images, costs)
    try:
        stream = execute_study(
            plan, raws, cluster=cluster, backend=backend_obj, hierarchy=hierarchy
        )
    finally:
        _backend_cleanup(backend, backend_obj)

    ref_plan = plan_study(wf, [ref_params], policy="rmsr", active_paths=1)
    ref_stream = execute_study(ref_plan, raws, cluster=cluster)
    ref_masks = [ref_stream.outputs[i][0]["mask"] for i in range(len(images))]

    dices = [
        [
            float(dice(stream.outputs[i][rid]["mask"], ref_masks[i]))
            for rid in range(len(param_sets))
        ]
        for i in range(len(images))
    ]
    return {
        "dice": dices,  # [tile][run]
        "tasks_total": plan.tasks_total * len(images),
        "tasks_executed": stream.tasks_executed,
        "planned_tasks_executed": plan.tasks_executed * len(images),
        "cache_hits": stream.cache_hits,
        "cache_misses": stream.cache_misses,
        "cache_spills": stream.cache_spills,
        "reuse_factor": reuse_factor(
            stream.tasks_executed, plan.tasks_total * len(images)
        ),
        "throughput": stream.throughput,
        "parallel_efficiency": stream.parallel_efficiency,
        "manager_sessions": stream.manager_sessions,
        "backend": stream.backend,
        "dispatch_counts": dict(stream.dispatch_counts),
        "retries": stream.retries,
        "backups_launched": stream.backups_launched,
        "wall_seconds": time.perf_counter() - t0,
        "reference_masks": [np.asarray(m) for m in ref_masks],
        "plan": plan,
        "stream": stream,
    }


def run_adaptive_study(
    images: Sequence[np.ndarray],
    *,
    space: ParamSpace = TABLE1_SPACE,
    max_rounds: int = 4,
    strategy: str = "hybrid",
    n_workers: int = 1,
    seed: int = 0,
    reference_params: Optional[ParamSet] = None,
    n_trajectories: int = 2,
    n_base: int = 4,
    n_boot: int = 16,
    costs: Optional[Dict[str, float]] = None,
    store_dir: Optional[str] = None,
    sa_policy: Optional[Any] = None,
    backend: Any = None,
    hierarchy: Any = None,
) -> Dict[str, Any]:
    """Adaptive MOAT → prune → VBD → refine study over tiles (DESIGN.md §11).

    A thin caller of :class:`repro.study.StudyDriver`: the objective is the
    Dice *difference* (1 − Dice) of each run's segmentation vs the
    default-parameter reference, averaged over tiles; rounds share one
    Manager session, one result cache backed by the persistent store, and
    plan only each round's delta against the cached trie. The summary
    reports the study-wide reuse accounting (``reuse_factor``, cache
    hit/miss/spill counters) alongside the per-round records.
    """
    from repro.study import (
        MoatSampler,
        RefinementSampler,
        SaltelliSampler,
        StudyDriver,
    )

    images = list(images)
    if not images:
        raise ValueError("run_adaptive_study needs at least one tile")
    h, w = images[0].shape[:2]
    if any(im.shape[:2] != (h, w) for im in images):
        raise ValueError("all tiles must share one (h, w) shape")
    wf = build_workflow(h, w, costs)
    cluster = ClusterSpec(n_workers=n_workers)
    raws = [{"raw": jnp.asarray(im)} for im in images]

    ref_params = reference_params or space.default()
    ref_plan = plan_study(wf, [ref_params], policy="rmsr", active_paths=1)
    ref_stream = execute_study(ref_plan, raws, cluster=cluster)
    ref_masks = [ref_stream.outputs[i][0]["mask"] for i in range(len(images))]

    def objective(leaf_state: Any, input_index: int) -> float:
        return 1.0 - float(dice(leaf_state["mask"], ref_masks[input_index]))

    t0 = time.perf_counter()
    driver = StudyDriver(
        wf,
        space,
        raws,
        objective=objective,
        maximize=False,
        seed=seed,
        engine_policy=strategy,
        cluster=cluster,
        sa_policy=sa_policy,
        samplers={
            "moat": MoatSampler(n_trajectories),
            "vbd": SaltelliSampler(n_base),
            "refine": RefinementSampler(),
        },
        n_boot=n_boot,
        input_keys=[f"tile{i}" for i in range(len(images))],
        store_dir=store_dir,
        # the workers' spill stores mount the SAME store_dir as the
        # study state, so a resumed study rehydrates worker-computed task
        # outputs too — without it, backend="process" would silently lose
        # the zero-recompute-resume guarantee (the workers' caches are
        # where the results live in spec mode)
        backend=_backend_for(backend, images, costs, store_dir=store_dir),
        hierarchy=hierarchy,
    )
    try:
        state = driver.run(max_rounds=max_rounds)
        # publish barrier: push the round-persistent cache through to the
        # store's disk tier and report how many entries that persisted. In
        # process-backend mode the leader cache is structurally empty — the
        # workers own the caches and flush them at each round install and
        # again at session shutdown (driver.close below) — so 0 here means
        # the durability lives worker-side, not that results were lost.
        cache_flushed = state.cache.flush()
        summary = driver.summary()
    finally:
        driver.close()
        _backend_cleanup(backend, driver.backend)
    return {
        **summary,
        "cache_flushed": cache_flushed,
        "wall_seconds": time.perf_counter() - t0,
        "rounds_detail": [_round_detail(r) for r in state.rounds],
        "reference_masks": [np.asarray(m) for m in ref_masks],
        "state": state,
    }


def _leader_objective(leaf_state: Any, input_index: int) -> float:
    raise RuntimeError(
        "the fleet leader never evaluates; its objective is a placeholder"
    )


def pathology_fleet_build(
    size: int = 48,
    n_tiles: int = 2,
    seed: int = 0,
    space_dict: Optional[Dict[str, list]] = None,
    costs: Optional[Dict[str, float]] = None,
    leader: bool = False,
) -> Dict[str, Any]:
    """Spawn-picklable fleet ``build`` for the pathology workflow
    (:func:`repro.study.run_fleet_study`): each fleet process calls this
    once to construct its own workflow, tiles, reference masks and Dice
    objective — everything process-local and deterministic, so every
    process computes identical references (tasks are pure and tiles are
    seeded). With ``leader=True`` (the fleet runner passes it for the
    leader, which proposes/analyzes but never evaluates) the expensive
    reference segmentation is skipped and the objective is a placeholder
    that raises if ever called."""
    from repro.core.params import ParamSpace as _ParamSpace

    space = (
        TABLE1_SPACE if space_dict is None else _ParamSpace.from_dict(space_dict)
    )
    wf = build_workflow(size, size, costs)
    tiles = [synthetic_tile(size, size, seed=seed + t) for t in range(n_tiles)]
    raws = [{"raw": jnp.asarray(im)} for im in tiles]
    if leader:
        objective: Any = _leader_objective
    else:
        ref_plan = plan_study(
            wf, [space.default()], policy="rmsr", active_paths=1
        )
        ref_stream = execute_study(ref_plan, raws)
        ref_masks = [ref_stream.outputs[i][0]["mask"] for i in range(len(raws))]

        def objective(leaf_state: Any, input_index: int) -> float:
            return 1.0 - float(dice(leaf_state["mask"], ref_masks[input_index]))

    return {
        "workflow": wf,
        "space": space,
        "inputs": raws,
        "objective": objective,
        "input_keys": [f"tile{i}" for i in range(n_tiles)],
    }


def pathology_service_build(
    size: int = 48,
    n_tiles: int = 2,
    seed: int = 0,
    space_dict: Optional[Dict[str, list]] = None,
    costs: Optional[Dict[str, float]] = None,
) -> Dict[str, Any]:
    """Build mapping for :class:`repro.service.StudyServer` (and the
    ``python -m repro.service serve --build`` entry): the pathology
    workflow, tiles, reference masks and Dice objective, deterministic in
    ``seed`` so a server restart reconstructs byte-identical references.
    Same shape as :func:`pathology_fleet_build` — the service server IS a
    resident fleet leader that also evaluates, so it always wants the real
    objective (no ``leader`` placeholder)."""
    return pathology_fleet_build(
        size=size,
        n_tiles=n_tiles,
        seed=seed,
        space_dict=space_dict,
        costs=costs,
        leader=False,
    )


def run_fleet_study(
    *,
    n_procs: int = 2,
    store_dir: str,
    size: int = 48,
    n_tiles: int = 2,
    space: ParamSpace = TABLE1_SPACE,
    max_rounds: int = 4,
    strategy: str = "hybrid",
    n_workers: int = 1,
    seed: int = 0,
    n_boot: int = 16,
    sa_policy: Optional[Any] = None,
    samplers: Optional[Dict[str, Any]] = None,
    worker_backend: Any = None,
) -> Dict[str, Any]:
    """Adaptive pathology study executed by a fleet of ``n_procs``
    StudyDriver processes pooling one :class:`~repro.runtime.SharedStore`
    on ``store_dir`` (DESIGN.md §12).

    Thin caller of :func:`repro.study.run_fleet_study` with the pathology
    ``build``; the returned summary mirrors :func:`run_adaptive_study` plus
    the fleet's cross-process accounting (``fleet`` key: combined task
    counts, corrupt-entry reads — must be 0 — lock-elided double-writes and
    cross-process store rehydrations)."""
    from repro.study import run_fleet_study as _run_fleet

    t0 = time.perf_counter()
    state, fleet = _run_fleet(
        pathology_fleet_build,
        {
            "size": size,
            "n_tiles": n_tiles,
            "seed": seed,
            "space_dict": {p.name: list(p.values) for p in space.params},
        },
        n_procs=n_procs,
        store_dir=store_dir,
        max_rounds=max_rounds,
        seed=seed,
        engine_policy=strategy,
        cluster=ClusterSpec(n_workers=n_workers),
        sa_policy=sa_policy,
        samplers=samplers,
        n_boot=n_boot,
        worker_backend=worker_backend,
    )
    from repro.core.metrics import reuse_factor as _rf

    return {
        "rounds": len(state.rounds),
        "tasks_requested": state.tasks_requested,
        "tasks_executed": state.tasks_executed,
        "reuse_factor": _rf(state.tasks_executed, state.tasks_requested),
        "active": list(state.active),
        "frozen": dict(state.frozen),
        "phase": state.phase,
        "best": None
        if state.best is None
        else {"params": dict(state.best[0]), "objective": state.best[1]},
        "fleet": fleet,
        "wall_seconds": time.perf_counter() - t0,
        "rounds_detail": [_round_detail(r) for r in state.rounds],
        "state": state,
    }

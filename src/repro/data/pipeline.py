"""Deterministic, resumable, sharded synthetic token pipeline.

Each host materialises only its shard of the global batch (host-sharded
data parallelism); the stream is a counter-based PRNG so that (a) any step's
batch can be regenerated exactly from ``step`` alone — restart-safe without
buffering — and (b) no two hosts ever duplicate data. ``state()`` /
``restore()`` round-trip through the checkpoint manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0
    step: int = 0
    prefetch: int = 2

    def __post_init__(self):
        if self.shape.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = self.shape.global_batch // self.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s = self.host_batch, self.shape.seq_len
        cfg = self.cfg
        if cfg.family == "audio":
            return {
                "frame_embeds": rng.normal(0, 1, (b, s, cfg.d_model)).astype(np.float32),
                "labels": rng.integers(0, cfg.vocab_size, (b, s, cfg.num_codebooks)).astype(np.int32),
            }
        if cfg.family == "vlm":
            st = s - cfg.num_patches
            return {
                "patch_embeds": rng.normal(0, 1, (b, cfg.num_patches, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(0, cfg.vocab_size, (b, st)).astype(np.int32),
                "labels": rng.integers(0, cfg.vocab_size, (b, st)).astype(np.int32),
            }
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    # checkpointable iterator state
    def state(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.seed, "host_id": self.host_id}

    def restore(self, state: Dict[str, Any]) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

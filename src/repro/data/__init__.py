"""Data pipelines (deterministic, resumable, host-sharded)."""

from repro.data.pipeline import TokenPipeline  # noqa: F401

"""Sensitivity-analysis methods (paper §II-A).

* MOAT (Morris One-At-A-Time) screening — elementary effects μ, μ*, σ per
  parameter, from the trajectories produced by
  :func:`repro.core.params.morris_trajectories`.
* VBD (variance-based decomposition / Sobol) — first-order S_i and total S_Ti
  indices via the Saltelli estimator.
* Correlation measures — Pearson and Spearman coefficients between parameter
  values and the output metric.

All methods consume a vector of per-run outputs (here: Dice differences of
each run's segmentation vs the default-parameter segmentation) and return
per-parameter importance indices.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.params import ParamSet, ParamSpace

__all__ = [
    "MoatResult",
    "moat_indices",
    "VbdResult",
    "saltelli_sample",
    "vbd_indices",
    "pearson",
    "spearman",
    "correlation_indices",
]


@dataclasses.dataclass
class MoatResult:
    mu: Dict[str, float]
    mu_star: Dict[str, float]
    sigma: Dict[str, float]

    def ranking(self) -> List[str]:
        return sorted(self.mu_star, key=lambda k: -self.mu_star[k])


def moat_indices(
    space: ParamSpace,
    outputs: Sequence[float],
    moves: Sequence[Sequence[Tuple[int, str]]],
) -> MoatResult:
    """Elementary effects from MOAT trajectories.

    ``moves[t]`` lists (run_index, varied_param) for trajectory t; the
    elementary effect of the k-th move is outputs[i_k] - outputs[i_k - 1].
    """
    effects: Dict[str, List[float]] = {p.name: [] for p in space.params}
    y = np.asarray(outputs, dtype=np.float64)
    for traj in moves:
        for run_idx, pname in traj:
            effects[pname].append(float(y[run_idx] - y[run_idx - 1]))
    mu, mu_star, sigma = {}, {}, {}
    for name, es in effects.items():
        arr = np.asarray(es) if es else np.zeros(1)
        mu[name] = float(arr.mean())
        mu_star[name] = float(np.abs(arr).mean())
        sigma[name] = float(arr.std())
    return MoatResult(mu=mu, mu_star=mu_star, sigma=sigma)


@dataclasses.dataclass
class VbdResult:
    first_order: Dict[str, float]
    total: Dict[str, float]


def saltelli_sample(
    space: ParamSpace, n_base: int, *, seed: int = 0
) -> Tuple[List[ParamSet], int]:
    """Saltelli cross-sampling: A, B and the d A_B^(i) matrices.

    Returns (param_sets, n_base); len(param_sets) == n_base * (dim + 2).
    Run order: [A rows, B rows, A_B^(0) rows, ..., A_B^(d-1) rows].
    """
    rng = np.random.default_rng(seed)
    d = space.dim
    A = rng.random((n_base, d))
    B = rng.random((n_base, d))
    blocks = [A, B]
    for i in range(d):
        AB = A.copy()
        AB[:, i] = B[:, i]
        blocks.append(AB)
    pts = np.concatenate(blocks, axis=0)
    return space.quantise(pts), n_base


def vbd_indices(space: ParamSpace, outputs: Sequence[float], n_base: int) -> VbdResult:
    """Sobol indices with the Jansen estimators."""
    y = np.asarray(outputs, dtype=np.float64)
    d = space.dim
    if len(y) != n_base * (d + 2):
        raise ValueError("outputs length does not match a Saltelli design")
    yA = y[:n_base]
    yB = y[n_base : 2 * n_base]
    var = np.var(np.concatenate([yA, yB])) or 1e-12
    first, total = {}, {}
    for i, p in enumerate(space.params):
        yABi = y[(2 + i) * n_base : (3 + i) * n_base]
        first[p.name] = float(np.mean(yB * (yABi - yA)) / var)
        total[p.name] = float(0.5 * np.mean((yA - yABi) ** 2) / var)
    return VbdResult(first_order=first, total=total)


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xc, yc = x - x.mean(), y - y.mean()
    denom = np.sqrt((xc**2).sum() * (yc**2).sum())
    return float((xc * yc).sum() / denom) if denom > 0 else 0.0


def spearman(x: np.ndarray, y: np.ndarray) -> float:
    rx = np.argsort(np.argsort(x)).astype(np.float64)
    ry = np.argsort(np.argsort(y)).astype(np.float64)
    return pearson(rx, ry)


def correlation_indices(
    space: ParamSpace, param_sets: Sequence[ParamSet], outputs: Sequence[float]
) -> Dict[str, Dict[str, float]]:
    y = np.asarray(outputs, dtype=np.float64)
    out: Dict[str, Dict[str, float]] = {}
    for p in space.params:
        vals = []
        for ps in param_sets:
            v = dict(ps)[p.name]
            vals.append(float(p.values.index(v)) if not isinstance(v, (int, float)) else float(v))
        x = np.asarray(vals)
        out[p.name] = {"pearson": pearson(x, y), "spearman": spearman(x, y)}
    return out

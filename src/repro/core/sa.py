"""Sensitivity-analysis methods (paper §II-A).

* MOAT (Morris One-At-A-Time) screening — elementary effects μ, μ*, σ per
  parameter, from the trajectories produced by
  :func:`repro.core.params.morris_trajectories`.
* VBD (variance-based decomposition / Sobol) — first-order S_i and total S_Ti
  indices via the Saltelli estimator.
* Correlation measures — Pearson and Spearman coefficients between parameter
  values and the output metric.

All methods consume a vector of per-run outputs (here: Dice differences of
each run's segmentation vs the default-parameter segmentation) and return
per-parameter importance indices. Both MOAT and VBD optionally attach
percentile-bootstrap confidence intervals (``n_boot > 0``) — the adaptive
study driver (``repro.study``) prunes on the CI, not the point estimate, so
a noisy-but-possibly-important parameter survives screening.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.params import ParamSet, ParamSpace

__all__ = [
    "MoatResult",
    "moat_indices",
    "VbdResult",
    "saltelli_sample",
    "vbd_indices",
    "pearson",
    "spearman",
    "correlation_indices",
]

CI = Tuple[float, float]


def _percentile_ci(samples: np.ndarray, alpha: float) -> CI:
    lo, hi = np.percentile(samples, [100 * alpha / 2, 100 * (1 - alpha / 2)])
    return float(lo), float(hi)


@dataclasses.dataclass
class MoatResult:
    mu: Dict[str, float]
    mu_star: Dict[str, float]
    sigma: Dict[str, float]
    # percentile-bootstrap CI of mu_star per parameter (None without n_boot)
    mu_star_ci: Optional[Dict[str, CI]] = None

    def ranking(self) -> List[str]:
        return sorted(self.mu_star, key=lambda k: -self.mu_star[k])


def moat_indices(
    space: ParamSpace,
    outputs: Sequence[float],
    moves: Sequence[Sequence[Tuple[int, str]]],
    *,
    n_boot: int = 0,
    seed: int = 0,
    alpha: float = 0.05,
) -> MoatResult:
    """Elementary effects from MOAT trajectories.

    ``moves[t]`` lists (run_index, varied_param) for trajectory t; the
    elementary effect of the k-th move is outputs[i_k] - outputs[i_k - 1].
    With ``n_boot > 0``, each parameter's elementary effects are resampled
    with replacement to attach a percentile CI to μ*.
    """
    effects: Dict[str, List[float]] = {p.name: [] for p in space.params}
    y = np.asarray(outputs, dtype=np.float64)
    for traj in moves:
        for run_idx, pname in traj:
            effects[pname].append(float(y[run_idx] - y[run_idx - 1]))
    mu, mu_star, sigma = {}, {}, {}
    mu_star_ci: Optional[Dict[str, CI]] = {} if n_boot > 0 else None
    rng = np.random.default_rng(seed)
    for name, es in effects.items():
        arr = np.asarray(es) if es else np.zeros(1)
        mu[name] = float(arr.mean())
        mu_star[name] = float(np.abs(arr).mean())
        sigma[name] = float(arr.std())
        if mu_star_ci is not None:
            draws = rng.integers(0, len(arr), size=(n_boot, len(arr)))
            mu_star_ci[name] = _percentile_ci(
                np.abs(arr[draws]).mean(axis=1), alpha
            )
    return MoatResult(mu=mu, mu_star=mu_star, sigma=sigma, mu_star_ci=mu_star_ci)


@dataclasses.dataclass
class VbdResult:
    first_order: Dict[str, float]
    total: Dict[str, float]
    # percentile-bootstrap CIs per parameter (None without n_boot)
    first_order_ci: Optional[Dict[str, CI]] = None
    total_ci: Optional[Dict[str, CI]] = None

    def ranking(self) -> List[str]:
        return sorted(self.total, key=lambda k: -self.total[k])


def saltelli_sample(
    space: ParamSpace, n_base: int, *, seed: int = 0
) -> Tuple[List[ParamSet], int]:
    """Saltelli cross-sampling: A, B and the d A_B^(i) matrices.

    Returns (param_sets, n_base); len(param_sets) == n_base * (dim + 2).
    Run order: [A rows, B rows, A_B^(0) rows, ..., A_B^(d-1) rows].
    """
    rng = np.random.default_rng(seed)
    d = space.dim
    A = rng.random((n_base, d))
    B = rng.random((n_base, d))
    blocks = [A, B]
    for i in range(d):
        AB = A.copy()
        AB[:, i] = B[:, i]
        blocks.append(AB)
    pts = np.concatenate(blocks, axis=0)
    return space.quantise(pts), n_base


def vbd_indices(
    space: ParamSpace,
    outputs: Sequence[float],
    n_base: int,
    *,
    n_boot: int = 0,
    seed: int = 0,
    alpha: float = 0.05,
) -> VbdResult:
    """Sobol indices with the Jansen estimators.

    With ``n_boot > 0``, the ``n_base`` design rows are resampled with
    replacement (keeping each row's A/B/A_B^(i) runs together, so resampled
    estimates stay internally consistent) to attach percentile CIs.
    """
    y = np.asarray(outputs, dtype=np.float64)
    d = space.dim
    if len(y) != n_base * (d + 2):
        raise ValueError("outputs length does not match a Saltelli design")
    yA = y[:n_base]
    yB = y[n_base : 2 * n_base]
    yABs = [y[(2 + i) * n_base : (3 + i) * n_base] for i in range(d)]

    def estimate(rows: np.ndarray) -> Tuple[List[float], List[float]]:
        a, b = yA[rows], yB[rows]
        var = np.var(np.concatenate([a, b])) or 1e-12
        first = [float(np.mean(b * (ab[rows] - a)) / var) for ab in yABs]
        total = [float(0.5 * np.mean((a - ab[rows]) ** 2) / var) for ab in yABs]
        return first, total

    all_rows = np.arange(n_base)
    first, total = estimate(all_rows)
    first_ci = total_ci = None
    if n_boot > 0:
        rng = np.random.default_rng(seed)
        boot_first = np.empty((n_boot, d))
        boot_total = np.empty((n_boot, d))
        for k in range(n_boot):
            boot_first[k], boot_total[k] = estimate(
                rng.integers(0, n_base, size=n_base)
            )
        first_ci = {
            p.name: _percentile_ci(boot_first[:, i], alpha)
            for i, p in enumerate(space.params)
        }
        total_ci = {
            p.name: _percentile_ci(boot_total[:, i], alpha)
            for i, p in enumerate(space.params)
        }
    return VbdResult(
        first_order={p.name: first[i] for i, p in enumerate(space.params)},
        total={p.name: total[i] for i, p in enumerate(space.params)},
        first_order_ci=first_ci,
        total_ci=total_ci,
    )


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xc, yc = x - x.mean(), y - y.mean()
    denom = np.sqrt((xc**2).sum() * (yc**2).sum())
    return float((xc * yc).sum() / denom) if denom > 0 else 0.0


def spearman(x: np.ndarray, y: np.ndarray) -> float:
    rx = np.argsort(np.argsort(x)).astype(np.float64)
    ry = np.argsort(np.argsort(y)).astype(np.float64)
    return pearson(rx, ry)


def correlation_indices(
    space: ParamSpace, param_sets: Sequence[ParamSet], outputs: Sequence[float]
) -> Dict[str, Dict[str, float]]:
    y = np.asarray(outputs, dtype=np.float64)
    out: Dict[str, Dict[str, float]] = {}
    for p in space.params:
        vals = []
        for ps in param_sets:
            v = dict(ps)[p.name]
            vals.append(float(p.values.index(v)) if not isinstance(v, (int, float)) else float(v))
        x = np.asarray(vals)
        out[p.name] = {"pearson": pearson(x, y), "spearman": spearman(x, y)}
    return out

"""Parameter spaces and sampling strategies for sensitivity analysis.

The paper (§II-A) selects parameter-value sets with Monte-Carlo, Latin
hypercube (LHS), or quasi-Monte-Carlo (Halton / Hammersley) sampling, feeding
screening (Morris One-At-A-Time) or variance-based (VBD) SA methods.

Parameters here are *discrete grids* (Table I of the paper): each parameter
has an ordered list of admissible values. Samplers draw points in [0,1)^d and
quantise onto the grid, mirroring how the paper's SA tooling (Dakota-style)
drives a grid-valued application.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Param",
    "ParamSpace",
    "ParamSet",
    "halton_sequence",
    "hammersley_sequence",
    "latin_hypercube",
    "monte_carlo",
    "morris_trajectories",
]


@dataclasses.dataclass(frozen=True)
class Param:
    """A single application parameter with its admissible grid of values."""

    name: str
    values: Tuple[Any, ...]

    def __post_init__(self) -> None:
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has an empty grid")

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def quantise(self, u: float) -> Any:
        """Map u in [0,1) onto the grid."""
        idx = min(int(u * len(self.values)), len(self.values) - 1)
        return self.values[idx]


# A ParamSet is an immutable mapping parameter-name -> chosen value.
ParamSet = Tuple[Tuple[str, Any], ...]


def paramset(d: Dict[str, Any]) -> ParamSet:
    return tuple(sorted(d.items()))


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """An ordered collection of :class:`Param`."""

    params: Tuple[Param, ...]

    @classmethod
    def from_dict(cls, d: Dict[str, Sequence[Any]]) -> "ParamSpace":
        return cls(tuple(Param(k, tuple(v)) for k, v in d.items()))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.params)

    @property
    def dim(self) -> int:
        return len(self.params)

    def quantise(self, u: np.ndarray) -> List[ParamSet]:
        """Quantise an (n, dim) array of unit-cube points onto the grid."""
        if u.ndim != 2 or u.shape[1] != self.dim:
            raise ValueError(f"expected (n, {self.dim}) points, got {u.shape}")
        out: List[ParamSet] = []
        for row in u:
            out.append(
                tuple(
                    sorted(
                        (p.name, p.quantise(float(x)))
                        for p, x in zip(self.params, row)
                    )
                )
            )
        return out

    def default(self) -> ParamSet:
        """The application default: midpoint of every grid (paper §II-A uses
        the default-parameter segmentation as the Dice reference)."""
        return tuple(
            sorted((p.name, p.values[len(p.values) // 2]) for p in self.params)
        )


# ---------------------------------------------------------------------------
# Low-discrepancy / random samplers
# ---------------------------------------------------------------------------

_PRIMES = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67,
    71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113,
]


def _radical_inverse(i: int, base: int) -> float:
    f, inv = 0.0, 1.0 / base
    while i > 0:
        f += (i % base) * inv
        i //= base
        inv /= base
    return f


def halton_sequence(n: int, dim: int, *, skip: int = 20) -> np.ndarray:
    """Halton quasi-Monte-Carlo sequence (the paper's Fig 6 sampling)."""
    if dim > len(_PRIMES):
        raise ValueError(f"halton supports up to {len(_PRIMES)} dims")
    pts = np.empty((n, dim), dtype=np.float64)
    for j in range(dim):
        b = _PRIMES[j]
        for i in range(n):
            pts[i, j] = _radical_inverse(i + 1 + skip, b)
    return pts


def hammersley_sequence(n: int, dim: int) -> np.ndarray:
    """Hammersley set: first coordinate i/n, rest radical inverses."""
    pts = np.empty((n, dim), dtype=np.float64)
    pts[:, 0] = (np.arange(n) + 0.5) / n
    for j in range(1, dim):
        b = _PRIMES[j - 1]
        for i in range(n):
            pts[i, j] = _radical_inverse(i + 1, b)
    return pts


def latin_hypercube(n: int, dim: int, *, seed: int = 0) -> np.ndarray:
    """LHS (McKay et al. 1979): one sample per row/column stratum."""
    rng = np.random.default_rng(seed)
    pts = np.empty((n, dim), dtype=np.float64)
    for j in range(dim):
        perm = rng.permutation(n)
        pts[:, j] = (perm + rng.random(n)) / n
    return pts


def monte_carlo(n: int, dim: int, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((n, dim))


def morris_trajectories(
    space: ParamSpace, n_trajectories: int, *, seed: int = 0
) -> Tuple[List[ParamSet], List[List[Tuple[int, str]]]]:
    """Morris One-At-A-Time (MOAT) screening design.

    Each trajectory starts at a random grid point and perturbs one parameter
    at a time (a random Δ of grid steps), yielding dim+1 runs per trajectory.
    Returns the flat list of param sets plus, per trajectory, the list of
    (run_index, varied_parameter) pairs needed to compute elementary effects.

    MOAT param sets share a (dim)-long prefix of unchanged values between
    consecutive runs — this is precisely why the paper's reuse tree finds so
    much duplicate computation in MOAT studies.
    """
    rng = np.random.default_rng(seed)
    sets: List[ParamSet] = []
    moves: List[List[Tuple[int, str]]] = []
    for _ in range(n_trajectories):
        idx = {p.name: rng.integers(0, p.cardinality) for p in space.params}
        cur = {p.name: p.values[idx[p.name]] for p in space.params}
        sets.append(paramset(cur))
        order = rng.permutation(space.dim)
        traj: List[Tuple[int, str]] = []
        for k in order:
            p = space.params[k]
            if p.cardinality > 1:
                step = int(rng.integers(1, max(2, p.cardinality // 2)))
                new = (idx[p.name] + step) % p.cardinality
                idx[p.name] = new
                cur[p.name] = p.values[new]
            sets.append(paramset(cur))
            traj.append((len(sets) - 1, p.name))
        moves.append(traj)
    return sets, moves

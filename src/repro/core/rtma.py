"""RTMA — Reuse-Tree Merging Algorithm (paper §II-B, Fig 4; baseline from
Barreiros et al., CLUSTER 2017).

RTMA groups stage instances into *buckets* of at most ``MaxBucketSize``; the
instances of a bucket are merged into one coarser stage whose internal task
tree realises the reuse. Because RTMA executes the merged tree with all
branches eligible concurrently, its peak memory grows with the tree *width*
(∝ bucket size), so ``MaxBucketSize`` must be capped to the machine memory —
the limitation RMSR removes.

Bucketing (Fig 4), faithful to the paper:
  1. **prune** — repeatedly, instances whose attach nodes share a parent and
     that suffice to fill a bucket (``MaxBucketSize`` of them, deepest parents
     first so the most-sharing groups are bucketed together) are emitted as a
     bucket and removed.
  2. **move-up** — every remaining instance's attach node moves one level up
     (childless interior nodes conceptually pruned).
  3. Repeat until all instances are assigned; at the root, leftovers form a
     final (possibly under-full) bucket.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.reuse import ReuseNode, ReuseTree, build_reuse_tree
from repro.core.workflow import StageInstance, StageSpec

__all__ = ["Bucket", "rtma_buckets", "bucket_reuse_stats", "max_bucket_for_budget"]


@dataclasses.dataclass
class Bucket:
    """A set of stage instances merged into one coarse stage instance."""

    instances: List[StageInstance]

    def tree(self, stage: StageSpec) -> ReuseTree:
        return build_reuse_tree(stage, self.instances)


def rtma_buckets(
    stage: StageSpec,
    instances: Sequence[StageInstance],
    max_bucket_size: int,
) -> List[Bucket]:
    if max_bucket_size < 1:
        raise ValueError("max_bucket_size must be >= 1")
    tree = build_reuse_tree(stage, instances)

    # Attach each instance at its full-depth leaf node.
    attach: Dict[int, ReuseNode] = {}
    by_run: Dict[int, StageInstance] = {}
    for leaf in tree.leaves():
        for inst in leaf.instances:
            if inst.run_id in attach:
                continue
            attach[inst.run_id] = leaf
            by_run[inst.run_id] = inst

    pending = sorted(attach.keys())
    buckets: List[Bucket] = []

    while pending:
        # --- prune phase: group by parent of attach node, deepest first ---
        groups: Dict[int, List[int]] = {}
        parent_of: Dict[int, Optional[ReuseNode]] = {}
        for rid in pending:
            p = attach[rid].parent
            key = id(p) if p is not None else -1
            groups.setdefault(key, []).append(rid)
            parent_of[key] = p

        emitted = False
        order = sorted(
            groups.items(),
            key=lambda kv: -(parent_of[kv[0]].depth if parent_of[kv[0]] else -1),
        )
        assigned: set = set()
        for key, rids in order:
            rids = [r for r in rids if r not in assigned]
            while len(rids) >= max_bucket_size:
                take, rids = rids[:max_bucket_size], rids[max_bucket_size:]
                buckets.append(Bucket([by_run[r] for r in take]))
                assigned.update(take)
                emitted = True
        pending = [r for r in pending if r not in assigned]
        if not pending:
            break

        # --- move-up phase (or final partial bucket at the root) ---
        at_root = all(attach[r] is tree.root for r in pending)
        if at_root:
            if not emitted:
                for i in range(0, len(pending), max_bucket_size):
                    take = pending[i : i + max_bucket_size]
                    buckets.append(Bucket([by_run[r] for r in take]))
                pending = []
            continue
        for rid in pending:
            node = attach[rid]
            if node is not tree.root and node.parent is not None:
                attach[rid] = node.parent
    return buckets


def bucket_reuse_stats(stage: StageSpec, buckets: Sequence[Bucket]) -> Dict[str, float]:
    """Task-reuse attained by a bucketing: tasks executed = Σ unique trie
    nodes per bucket (reuse never crosses buckets — the paper's limitation)."""
    total = sum(len(b.instances) for b in buckets) * len(stage.tasks)
    unique = sum(b.tree(stage).unique_task_count() for b in buckets)
    return {
        "total_tasks": float(total),
        "unique_tasks": float(unique),
        "reuse_fraction": 1.0 - unique / total if total else 0.0,
    }


def max_bucket_for_budget(
    stage: StageSpec,
    instances: Sequence[StageInstance],
    budget_bytes: int,
    peak_bytes_fn,
) -> int:
    """Largest MaxBucketSize whose *worst bucket* peak memory (under RTMA's
    breadth-eligible execution, computed by ``peak_bytes_fn(tree)``) fits the
    budget. This is how the paper sizes RTMA per machine (Table II)."""
    best = 1
    for b in range(2, len(instances) + 1):
        buckets = rtma_buckets(stage, instances, b)
        worst = max(peak_bytes_fn(bk.tree(stage)) for bk in buckets)
        if worst <= budget_bytes:
            best = b
        else:
            break
    return best

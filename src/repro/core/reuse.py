"""Multi-level computation reuse (paper §II-B).

Two granularities:

* **Stage-level (coarse)** — stage instances whose *entire* parameter set (as
  consumed by the stage) is identical are executed once
  (:func:`stage_level_dedup`).

* **Task-level (fine)** — instances with overlapping-but-unequal parameters
  are merged: a **reuse tree** (trie) is built whose level *d* is keyed by
  the parameter values consumed by task *d* of the stage pipeline. Two
  instances share the computation of tasks 0..d iff they lie on the same
  trie path down to depth d. The number of trie nodes == number of task
  executions after perfect merging.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.workflow import StageInstance, StageSpec, task_key

__all__ = [
    "ReuseNode",
    "ReuseTree",
    "stage_level_dedup",
    "build_reuse_tree",
    "reuse_stats",
]


@dataclasses.dataclass
class ReuseNode:
    """One merged task execution.

    ``key``     — (task param values) trie key at this level,
    ``depth``   — task index in the stage pipeline (root has depth -1),
    ``children``— next-task nodes keyed by their task key,
    ``instances`` — stage instances whose path passes through this node.
    """

    key: Tuple[Any, ...]
    depth: int
    parent: Optional["ReuseNode"] = None
    children: Dict[Tuple[Any, ...], "ReuseNode"] = dataclasses.field(default_factory=dict)
    instances: List[StageInstance] = dataclasses.field(default_factory=list)
    uid: int = -1

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def path(self) -> List["ReuseNode"]:
        node, out = self, []
        while node is not None and node.depth >= 0:
            out.append(node)
            node = node.parent
        return out[::-1]


@dataclasses.dataclass
class ReuseTree:
    """Trie over the per-task parameter values of a set of stage instances."""

    stage: StageSpec
    root: ReuseNode
    n_instances: int
    _uid: int = 0

    def nodes(self) -> List[ReuseNode]:
        out: List[ReuseNode] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.depth >= 0:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def leaves(self) -> List[ReuseNode]:
        return [n for n in self.nodes() if n.is_leaf]

    def unique_task_count(self) -> int:
        return len(self.nodes())


def stage_level_dedup(
    instances: Sequence[StageInstance],
) -> Tuple[List[StageInstance], Dict[int, int]]:
    """Coarse-grain reuse: one representative per distinct consumed-parameter
    signature. Returns (representatives, run_id -> representative index)."""
    reps: List[StageInstance] = []
    sig_to_rep: Dict[Tuple[Any, ...], int] = {}
    mapping: Dict[int, int] = {}
    for inst in instances:
        sig = inst.task_keys()
        if sig not in sig_to_rep:
            sig_to_rep[sig] = len(reps)
            reps.append(inst)
        mapping[inst.run_id] = sig_to_rep[sig]
    return reps, mapping


def build_reuse_tree(
    stage: StageSpec, instances: Sequence[StageInstance]
) -> ReuseTree:
    """Insert every instance as a root→leaf path; shared prefixes share nodes."""
    root = ReuseNode(key=(), depth=-1)
    tree = ReuseTree(stage=stage, root=root, n_instances=len(instances))
    for inst in instances:
        node = root
        for d, task in enumerate(stage.tasks):
            k = task_key(task, inst.params)
            child = node.children.get(k)
            if child is None:
                child = ReuseNode(key=k, depth=d, parent=node, uid=tree._uid)
                tree._uid += 1
                node.children[k] = child
            child.instances.append(inst)
            node = child
    return tree


def reuse_stats(
    stage: StageSpec, instances: Sequence[StageInstance]
) -> Dict[str, float]:
    """Reuse accounting for a perfectly-merged stage family (upper bound on
    what any bucketing can attain). ``reuse_fraction`` matches the paper's
    Table II "Reuse" column: fraction of task executions eliminated."""
    tree = build_reuse_tree(stage, instances)
    total = len(instances) * len(stage.tasks)
    unique = tree.unique_task_count()
    return {
        "total_tasks": float(total),
        "unique_tasks": float(unique),
        "reuse_fraction": 1.0 - unique / total if total else 0.0,
    }

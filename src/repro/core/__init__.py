"""Core library: the paper's contribution (multi-level computation reuse for
parameter sensitivity analysis) as composable modules.

Pipeline: sample parameter sets (``params``) → instantiate the hierarchical
workflow (``workflow``) → stage-level dedup + reuse trie (``reuse``) → bucket
merging (``rtma``) → memory-bounded depth-first scheduling + execution
(``rmsr``) → difference metrics (``metrics``) → SA indices (``sa``).

These are composable primitives; the composition point is
``repro.engine.plan_study`` / ``execute_plan`` (DESIGN.md §3) — application
code should call the engine rather than re-wiring these modules.
"""

from repro.core.params import (  # noqa: F401
    Param,
    ParamSpace,
    halton_sequence,
    hammersley_sequence,
    latin_hypercube,
    monte_carlo,
    morris_trajectories,
    paramset,
)
from repro.core.workflow import StageInstance, StageSpec, TaskSpec, Workflow  # noqa: F401
from repro.core.reuse import build_reuse_tree, reuse_stats, stage_level_dedup  # noqa: F401
from repro.core.rtma import Bucket, bucket_reuse_stats, max_bucket_for_budget, rtma_buckets  # noqa: F401
from repro.core.rmsr import (  # noqa: F401
    execute_merged_stage,
    min_active_paths,
    rmsr_schedule,
    simulate_execution,
    tree_peak_bytes,
)
from repro.core.sa import (  # noqa: F401
    MoatResult,
    VbdResult,
    correlation_indices,
    moat_indices,
    saltelli_sample,
    vbd_indices,
)
from repro.core.metrics import (  # noqa: F401
    dice,
    jaccard,
    parallel_efficiency,
    reuse_factor,
    throughput,
)

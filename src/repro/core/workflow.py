"""Hierarchical workflow abstraction (paper §II): coarse-grain *stages*, each
an internal pipeline of fine-grain *tasks*, each task parameterised by a
subset of the application parameters.

A :class:`StageSpec` is a linear chain of :class:`TaskSpec` (the paper's
Fig 1/Fig 5 segmentation stage: Seg0..Seg6). When several stage *instances*
(stage + bound parameter set) are merged for computation reuse, the chain
becomes a tree (trie over per-task parameter values) — see ``reuse.py``.

Tasks carry two cost annotations used by the schedulers:
  * ``cost``         — relative compute cost (seconds or abstract units),
  * ``output_bytes`` — size of the task's output buffer, used by the RMSR
                       liveness/memory model.
Both may be callables of the bound parameter values, supporting
heterogeneous-memory tasks (a beyond-paper generalisation; the paper assumes
homogeneous tasks, §III last paragraph).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.params import ParamSet

__all__ = ["TaskSpec", "StageSpec", "StageInstance", "Workflow", "task_key"]


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """A fine-grain task inside a stage.

    ``fn(state, **params) -> state`` is the actual computation (a JAX-jittable
    transformation of the inter-task payload). ``param_names`` is the subset
    of application parameters this task consumes — the reuse trie keys each
    tree level by the values of exactly these parameters.
    """

    name: str
    param_names: Tuple[str, ...]
    fn: Optional[Callable[..., Any]] = None
    cost: Any = 1.0  # float | Callable[[Dict[str, Any]], float]
    output_bytes: Any = 0  # int | Callable[[Dict[str, Any]], int]

    def bound_cost(self, params: Dict[str, Any]) -> float:
        return float(self.cost(params) if callable(self.cost) else self.cost)

    def bound_bytes(self, params: Dict[str, Any]) -> int:
        ob = self.output_bytes
        return int(ob(params) if callable(ob) else ob)


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """A coarse-grain stage: an ordered pipeline of tasks."""

    name: str
    tasks: Tuple[TaskSpec, ...]

    @property
    def param_names(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for t in self.tasks:
            for p in t.param_names:
                if p not in seen:
                    seen.append(p)
        return tuple(seen)


def task_key(task: TaskSpec, params: ParamSet) -> Tuple[Any, ...]:
    """The reuse key of a task instance: the values of the parameters the
    task consumes (paper §II-B: tasks are duplicates iff their consumed
    parameter values coincide — upstream agreement is enforced by trie
    position, see ``reuse.py``)."""
    d = dict(params)
    return tuple((n, d[n]) for n in task.param_names if n in d)


@dataclasses.dataclass(frozen=True)
class StageInstance:
    """A stage bound to one parameter set (one SA run of that stage)."""

    stage: StageSpec
    params: ParamSet
    run_id: int  # which SA run (parameter set index) this instance belongs to

    def task_keys(self) -> Tuple[Tuple[Any, ...], ...]:
        return tuple(task_key(t, self.params) for t in self.stage.tasks)


@dataclasses.dataclass
class Workflow:
    """An application workflow: ordered stages + the instances of an SA study.

    ``instantiate`` expands (stages × parameter sets) into stage instances;
    downstream reuse analysis operates per stage family (instances of the
    same StageSpec are candidates for dedup/merging; paper §II-B).
    """

    stages: Tuple[StageSpec, ...]

    def instantiate(self, param_sets: Sequence[ParamSet]) -> Dict[str, List[StageInstance]]:
        out: Dict[str, List[StageInstance]] = {s.name: [] for s in self.stages}
        for run_id, ps in enumerate(param_sets):
            for s in self.stages:
                out[s.name].append(StageInstance(s, ps, run_id))
        return out

    def total_task_count(self, n_runs: int) -> int:
        return n_runs * sum(len(s.tasks) for s in self.stages)

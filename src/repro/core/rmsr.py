"""RMSR — Runtime Memory-Efficient Scheduler for Reuse (paper §III, Alg. 1).

The paper's insight: execute a merged stage's task tree **depth-first with at
most ``active_paths`` concurrently-active root→leaf paths**, so peak memory is
bounded by ``active_paths`` (× path-local state) *independently* of how many
stage instances were merged (``MaxBucketSize``). Arbitrarily aggressive
merging — hence maximal computation reuse — becomes feasible under a fixed
memory budget.

TPU adaptation (see DESIGN.md §2): XLA programs are static, so the paper's
run-time worklist (stack + dependency counters, Alg. 1) is executed
*ahead-of-time* here to produce a static schedule with an exact liveness
proof. The same traversal, parameterised by queue discipline, also models
RTMA's execution (breadth-eligible ⇒ width-proportional memory), which gives
a single engine for the paper's Fig 6/7 comparisons:

  * ``discipline="lifo"``  — RMSR: LIFO stack ⇒ depth-first (Alg. 1 line 6).
  * ``discipline="fifo"``  — RTMA: level-order ⇒ the whole frontier is live.

Liveness rule: a node's output buffer becomes live when the node executes and
is freed once its last child has executed (children consume the parent output
as input); leaf outputs are reduced (Dice) / emitted immediately.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.reuse import ReuseNode, ReuseTree

__all__ = [
    "ScheduleResult",
    "rmsr_schedule",
    "simulate_execution",
    "tree_peak_bytes",
    "min_active_paths",
    "replay_schedule",
    "execute_merged_stage",
]


def _node_bytes(node: ReuseNode, tree: ReuseTree) -> int:
    task = tree.stage.tasks[node.depth]
    params = dict(node.instances[0].params)
    return task.bound_bytes(params)


def _node_cost(node: ReuseNode, tree: ReuseTree) -> float:
    task = tree.stage.tasks[node.depth]
    params = dict(node.instances[0].params)
    return task.bound_cost(params)


@dataclasses.dataclass
class ScheduleResult:
    order: List[ReuseNode]
    peak_bytes: int
    peak_paths: int
    makespan: float
    total_cost: float


def _children_sorted(node: ReuseNode) -> List[ReuseNode]:
    return [node.children[k] for k in sorted(node.children.keys(), key=repr)]


def simulate_execution(
    tree: ReuseTree,
    workers: int,
    *,
    discipline: str = "lifo",
    cost_fn: Optional[Callable[[ReuseNode], float]] = None,
    bytes_fn: Optional[Callable[[ReuseNode], int]] = None,
) -> ScheduleResult:
    """Discrete-event simulation of Alg. 1 with ``workers`` threads/paths.

    Emits the execution order, exact peak live bytes, peak concurrently-open
    paths, and the makespan under the per-task costs — used both as the AOT
    schedule compiler (order) and as the Fig 6/7 performance model.
    """
    if discipline not in ("lifo", "fifo"):
        raise ValueError(discipline)
    cost_fn = cost_fn or (lambda n: _node_cost(n, tree))
    bytes_fn = bytes_fn or (lambda n: _node_bytes(n, tree))

    ready: List[ReuseNode] = _children_sorted(tree.root)[::-1]
    running: List[Tuple[float, int, ReuseNode]] = []  # (finish_time, tiebreak, node)
    executed_children: Dict[int, int] = {}
    live: Dict[int, int] = {}
    order: List[ReuseNode] = []
    t = 0.0
    live_bytes = 0
    peak_bytes = 0
    peak_paths = 0
    total_cost = 0.0
    tiebreak = 0

    def _start(node: ReuseNode) -> None:
        nonlocal live_bytes, peak_bytes, total_cost, tiebreak
        order.append(node)
        b = bytes_fn(node)
        live[node.uid] = b
        live_bytes += b
        # the parent's buffer is also live while this node runs; it already is.
        peak_bytes = max(peak_bytes, live_bytes)
        c = cost_fn(node)
        total_cost += c
        tiebreak += 1
        heapq.heappush(running, (t + c, tiebreak, node))

    def _finish(node: ReuseNode) -> None:
        nonlocal live_bytes
        parent = node.parent
        if parent is not None and parent.depth >= 0:
            executed_children[parent.uid] = executed_children.get(parent.uid, 0) + 1
            if executed_children[parent.uid] == len(parent.children):
                live_bytes -= live.pop(parent.uid)
        if node.is_leaf:
            live_bytes -= live.pop(node.uid)
        else:
            kids = _children_sorted(node)
            if discipline == "lifo":
                ready.extend(kids[::-1])
            else:
                ready.extend(kids)

    while ready or running:
        while ready and len(running) < workers:
            node = ready.pop() if discipline == "lifo" else ready.pop(0)
            _start(node)
            peak_paths = max(peak_paths, len(running))
        if not running:
            break
        t, _, node = heapq.heappop(running)
        _finish(node)

    return ScheduleResult(
        order=order,
        peak_bytes=peak_bytes,
        peak_paths=peak_paths,
        makespan=t,
        total_cost=total_cost,
    )


def rmsr_schedule(tree: ReuseTree, active_paths: int = 1) -> ScheduleResult:
    """The RMSR static schedule (Alg. 1, AOT): depth-first, ≤ active_paths."""
    return simulate_execution(tree, active_paths, discipline="lifo")


def tree_peak_bytes(tree: ReuseTree, *, discipline: str = "fifo", workers: int = 10**9) -> int:
    """Peak memory of executing a merged tree under RTMA semantics (all
    branches eligible): this is what limits MaxBucketSize in the paper."""
    return simulate_execution(tree, workers, discipline=discipline).peak_bytes


def min_active_paths(tree: ReuseTree, budget_bytes: int) -> Optional[int]:
    """Largest active_paths whose RMSR peak fits the budget (None if even a
    single path exceeds it).

    Peak bytes is monotone non-decreasing in active_paths (more concurrently
    open root→leaf paths can only add live buffers), so a doubling probe
    followed by a binary search over the last gap finds the exact maximum —
    not just the last fitting power of two. active_paths beyond the leaf
    count cannot open further paths, so the search is capped there.
    """
    leaves = max(1, len(tree.leaves()))

    def fits(p: int) -> bool:
        return simulate_execution(tree, p, discipline="lifo").peak_bytes <= budget_bytes

    if not fits(1):
        return None
    lo = 1  # largest known to fit
    hi: Optional[int] = None  # smallest known not to fit
    probe = 2
    while hi is None and probe < leaves:
        if fits(probe):
            lo = probe
            probe *= 2
        else:
            hi = probe
    if hi is None:
        if fits(leaves):
            return leaves
        hi = leaves
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid
    return lo


# ---------------------------------------------------------------------------
# Real executor: walks a frozen schedule calling the (jitted) task functions.
# ---------------------------------------------------------------------------

def replay_schedule(
    tree: ReuseTree,
    order: Sequence[ReuseNode],
    input_state: Any,
    *,
    lookup: Optional[Callable[[Tuple], Tuple[bool, Any]]] = None,
    store: Optional[Callable[[Tuple, Any, Any, Dict[str, Any]], None]] = None,
) -> Tuple[Dict[int, Any], int, int]:
    """Replay a frozen schedule over a merged task tree.

    Each trie node runs ``task.fn(parent_output, **bound_params)`` exactly
    once — this *is* the computation reuse. Buffers are dropped per the
    liveness rule (a parent output dies with its last child), so the
    Python-side peak matches the schedule's proof.

    ``lookup(path_key) -> (hit, value)`` / ``store(path_key, value, task,
    params)`` optionally plug a result cache in (the engine's run-level
    cache); the path key is the tuple of trie keys from the root.

    Returns ``({run_id: leaf output}, tasks executed, cache hits)``.
    """
    outputs: Dict[int, Any] = {}
    results: Dict[int, Any] = {}
    remaining: Dict[int, int] = {}
    path_keys: Dict[int, Tuple] = {}
    executed = 0
    hits = 0
    for node in order:
        task = tree.stage.tasks[node.depth]
        parent = node.parent
        at_root = parent is None or parent.depth < 0
        pk = (path_keys[parent.uid] if not at_root else ()) + (node.key,)
        path_keys[node.uid] = pk
        params = {
            k: v for k, v in dict(node.instances[0].params).items()
            if k in task.param_names
        }
        hit = False
        out = None
        if lookup is not None:
            hit, out = lookup(pk)
        if hit:
            hits += 1
        else:
            src = input_state if at_root else outputs[parent.uid]
            out = task.fn(src, **params) if task.fn is not None else src
            executed += 1
            if store is not None:
                store(pk, out, task, params)
        if node.is_leaf:
            for inst in node.instances:
                results[inst.run_id] = out
        else:
            outputs[node.uid] = out
            remaining[node.uid] = len(node.children)
        if not at_root:
            remaining[parent.uid] -= 1
            if remaining[parent.uid] == 0:
                del outputs[parent.uid]  # liveness: parent freed
    return results, executed, hits


def execute_merged_stage(
    tree: ReuseTree,
    input_state: Any,
    *,
    active_paths: int = 1,
    collect: str = "leaf",
) -> Dict[int, Any]:
    """Execute a merged stage's task tree with RMSR's depth-first order.

    ``input_state`` is the stage input (e.g. the normalised image tile).
    Returns {run_id: leaf output} for every merged stage instance.
    """
    results, _, _ = replay_schedule(
        tree, rmsr_schedule(tree, active_paths).order, input_state
    )
    return results

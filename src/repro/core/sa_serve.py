"""The paper's reuse machinery as a first-class LM-serving feature.

An SA study over a *serving pipeline's* parameters — which system prompt,
which decoding controls, which post-hoc acceptance threshold — re-executes
the same pipeline for every parameter set, exactly like the pathology SA.
The pipeline is expressed as a 3-task stage:

    prefill   (prompt_id)            tokens → KV cache          [expensive]
    generate  (rep_penalty, top_k)   cache  → generated ids     [expensive]
    score     (threshold)            ids    → acceptance metric [cheap]

so the reuse trie shares one prefill across every parameter set with the
same prompt (== prefix caching, derived rather than hand-built), shares
generation across sets differing only in the threshold, and RMSR's
activePaths bound caps how many KV caches are live against the HBM budget —
the exact mechanism the paper uses to decouple merge size from memory.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.params import ParamSet
from repro.core.workflow import StageSpec, TaskSpec, Workflow
from repro.models import decode_step, init_cache, prefill

__all__ = ["build_serve_stage", "run_sa_serve"]


def _cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    leaves = jax.eval_shape(lambda: init_cache(cfg, batch, max_len)).values()
    total = 0
    for leaf in jax.tree.leaves(list(leaves)):
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


def build_serve_stage(
    cfg: ModelConfig,
    params,
    prompts: Dict[int, np.ndarray],
    *,
    gen_len: int = 8,
    max_len: int = 64,
) -> StageSpec:
    """Build the serve pipeline stage over a given model + prompt library."""

    def t_prefill(state, prompt_id):
        toks = jnp.asarray(prompts[int(prompt_id)])
        logits, cache, ln = prefill(cfg, params, {"tokens": toks}, max_len=max_len)
        return {"cache": cache, "len": ln, "last_logits": logits,
                "tokens": toks}

    def t_generate(state, rep_penalty, top_k):
        cache, ln = state["cache"], state["len"]
        logits = state["last_logits"]
        b = logits.shape[0]
        out_ids: List[jax.Array] = []
        confidences: List[jax.Array] = []
        seen = jnp.zeros((b, cfg.padded_vocab), jnp.float32)
        for i in range(gen_len):
            adj = logits - jnp.log(jnp.float32(rep_penalty)) * seen
            kv, ki = jax.lax.top_k(adj, int(top_k))
            nxt = ki[:, 0]  # argmax within the top-k after penalty
            probs = jax.nn.softmax(adj, axis=-1)
            confidences.append(jnp.take_along_axis(probs, nxt[:, None], 1)[:, 0])
            seen = seen.at[jnp.arange(b), nxt].add(1.0)
            out_ids.append(nxt)
            logits, cache = decode_step(
                cfg, params, {"tokens": nxt[:, None]}, cache, jnp.int32(ln + i)
            )
        return {
            "ids": jnp.stack(out_ids, 1),
            "conf": jnp.stack(confidences, 1),
        }

    def t_score(state, threshold):
        return {"accept_rate": jnp.mean((state["conf"] > threshold).astype(jnp.float32))}

    any_prompt = next(iter(prompts.values()))
    cache_b = _cache_bytes(cfg, any_prompt.shape[0], max_len)
    return StageSpec(
        name="sa_serve",
        tasks=(
            TaskSpec("prefill", ("prompt_id",), t_prefill,
                     cost=float(any_prompt.shape[1]), output_bytes=cache_b),
            TaskSpec("generate", ("rep_penalty", "top_k"), t_generate,
                     cost=float(gen_len), output_bytes=cache_b // 8),
            TaskSpec("score", ("threshold",), t_score, cost=0.05,
                     output_bytes=64),
        ),
    )


def run_sa_serve(
    cfg: ModelConfig,
    params,
    prompts: Dict[int, np.ndarray],
    param_sets: Sequence[ParamSet],
    *,
    gen_len: int = 8,
    max_len: int = 64,
    hbm_budget_bytes: Optional[int] = None,
    policy: str = "rmsr",
    n_workers: int = 1,
) -> Dict[str, Any]:
    """Execute the SA-serve study through the StudyPlanner engine.

    The default ``"rmsr"`` policy merges maximally and solves activePaths
    against the HBM budget; ``"hybrid"`` additionally buckets for
    multi-worker dispatch. Returns per-run accept rates plus the
    reuse/scheduling accounting."""
    from repro.engine import ClusterSpec, MemoryBudget, execute_plan, plan_study

    stage = build_serve_stage(cfg, params, prompts, gen_len=gen_len, max_len=max_len)
    wf = Workflow(stages=(stage,))
    plan = plan_study(
        wf,
        list(param_sets),
        memory=MemoryBudget(bytes=hbm_budget_bytes),
        cluster=ClusterSpec(n_workers=n_workers),
        policy=policy,
    )
    result = execute_plan(plan, {})
    return {
        "accept_rate": {
            rid: float(res["accept_rate"]) for rid, res in result.outputs.items()
        },
        "tasks_total": plan.tasks_total,
        # measured count (cache hits subtracted) — same semantics as the
        # pathology drivers; the plan's analytic count rides alongside
        "tasks_executed": result.tasks_executed,
        "planned_tasks_executed": plan.tasks_executed,
        "reuse_fraction": plan.reuse_fraction,
        "active_paths": plan.active_paths,
        "peak_bytes": plan.peak_bytes,
        "cache_hits": result.cache_hits,
    }

"""Output-difference metrics (paper §II-A): Dice and Jaccard coefficients
between a run's segmentation mask and the default-parameter reference mask,
implemented as fused jnp reductions (one pass over the masks) — plus the
execution-side throughput/parallel-efficiency accounting the streaming
dataset executor and the cluster simulator report (paper §IV-D)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dice",
    "jaccard",
    "throughput",
    "parallel_efficiency",
    "reuse_factor",
]


def throughput(n_items: int, wall_seconds: float) -> float:
    """Completed work items (tiles, batches) per second of wall-clock."""
    return n_items / wall_seconds if wall_seconds > 0 else 0.0


def reuse_factor(tasks_executed: int, tasks_requested: int) -> float:
    """How many requested task executions each actual execution amortised.

    ``tasks_requested`` is the study's naive task count (runs × tasks,
    summed over rounds for adaptive studies); ``tasks_executed`` the
    measured count after dedup, trie merging and result-cache/-store hits.
    1.0 means no reuse; the paper's Table II "Reuse" column is the same
    quantity expressed as a fraction, ``1 - 1/reuse_factor``.
    """
    if tasks_executed <= 0:
        return float("inf") if tasks_requested > 0 else 1.0
    return tasks_requested / tasks_executed


def parallel_efficiency(
    busy_seconds: float, wall_seconds: float, n_workers: int
) -> float:
    """Useful-work fraction of the worker-seconds the run occupied — the
    paper's busy/(makespan × workers) definition (≈0.92 at 256 nodes)."""
    denom = wall_seconds * max(1, n_workers)
    return busy_seconds / denom if denom > 0 else 0.0


@jax.jit
def dice(a: jax.Array, b: jax.Array) -> jax.Array:
    """Dice coefficient of two boolean/binary masks. Returns 1.0 when both
    masks are empty (identical-by-vacuity), matching common practice."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    inter = jnp.sum(a * b)
    sizes = jnp.sum(a) + jnp.sum(b)
    return jnp.where(sizes > 0, 2.0 * inter / jnp.maximum(sizes, 1e-9), 1.0)


@jax.jit
def jaccard(a: jax.Array, b: jax.Array) -> jax.Array:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    inter = jnp.sum(a * b)
    union = jnp.sum(jnp.maximum(a, b))
    return jnp.where(union > 0, inter / jnp.maximum(union, 1e-9), 1.0)

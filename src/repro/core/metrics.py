"""Output-difference metrics (paper §II-A): Dice and Jaccard coefficients
between a run's segmentation mask and the default-parameter reference mask.
Implemented as fused jnp reductions (one pass over the masks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dice", "jaccard"]


@jax.jit
def dice(a: jax.Array, b: jax.Array) -> jax.Array:
    """Dice coefficient of two boolean/binary masks. Returns 1.0 when both
    masks are empty (identical-by-vacuity), matching common practice."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    inter = jnp.sum(a * b)
    sizes = jnp.sum(a) + jnp.sum(b)
    return jnp.where(sizes > 0, 2.0 * inter / jnp.maximum(sizes, 1e-9), 1.0)


@jax.jit
def jaccard(a: jax.Array, b: jax.Array) -> jax.Array:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    inter = jnp.sum(a * b)
    union = jnp.sum(jnp.maximum(a, b))
    return jnp.where(union > 0, inter / jnp.maximum(union, 1e-9), 1.0)

"""StudyDriver — the adaptive multi-round science loop above the engine
(DESIGN.md §11).

One round = **propose → evaluate → analyze → decide**:

1. a pluggable :mod:`sampler <repro.study.samplers>` proposes the round's
   run-list (MOAT trajectories, Saltelli matrices, refinement grids over
   the currently-active parameters);
2. the driver *evaluates* it incrementally — proposals whose objective a
   prior round already produced are recalled from the
   :class:`~repro.study.StudyState` evaluated map; only the **delta** is
   planned (``plan_study(..., ledger=state.ledger)``) and streamed through
   the study's single persistent Manager session with the round-shared,
   store-backed result cache, so shared trie prefixes from *any* prior
   round are cache/store hits rather than recomputation;
3. the analyzer turns the objective vector into indices (``core.sa``) with
   bootstrap confidence intervals;
4. a pluggable :mod:`policy <repro.study.policies>` prunes parameters whose
   CI says they cannot matter, advances the phase (screen → VBD → refine),
   or declares convergence.

``tune`` reuses the same loop for importance-guided coordinate descent on
the objective (e.g. Dice vs a reference segmentation), where the
one-coordinate-at-a-time proposals make cross-round trie reuse maximal.

Reuse is an optimization, never an approximation: tasks are pure functions
of ``(input, params)``, so an adaptive study's indices are bit-identical to
running every round as an independent one-shot study — the tests assert
exactly that against a one-shot oracle.
"""

from __future__ import annotations

import multiprocessing
import os
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.params import ParamSet, ParamSpace, paramset
from repro.core.sa import moat_indices, vbd_indices
from repro.core.workflow import Workflow
from repro.engine import ClusterSpec, MemoryBudget, execute_study, plan_study
from repro.engine.types import CACHING_POLICIES
from repro.runtime.manager import Manager
from repro.study.policies import Decision, ScreenThenRefinePolicy
from repro.study.samplers import (
    MoatSampler,
    RefinementSampler,
    SaltelliSampler,
    active_space,
)
from repro.study.state import RoundRecord, StudyState, _ps_from_json, _ps_to_json

__all__ = ["StudyDriver", "run_fleet_study"]

# objective(final_stage_output, input_index) -> scalar; the driver averages
# it over inputs to get one y per run.
Objective = Callable[[Any, int], float]


class StudyDriver:
    """Run an adaptive SA study over ``workflow`` × ``space`` on ``inputs``.

    The driver owns a :class:`StudyState` (pass one to resume) and keeps one
    Manager session alive across every round; ``close()`` (or use as a
    context manager) retires it. ``engine_policy`` is the engine's bucketing
    policy for every delta plan — it must be a caching policy
    (rtma/rmsr/hybrid) for cross-round task reuse to engage.
    """

    def __init__(
        self,
        workflow: Workflow,
        space: ParamSpace,
        inputs: Sequence[Any],
        *,
        objective: Objective,
        maximize: bool = False,
        state: Optional[StudyState] = None,
        seed: int = 0,
        engine_policy: str = "hybrid",
        max_bucket_size: Optional[int] = None,
        active_paths: Optional[int] = 4,
        memory: Optional[MemoryBudget] = None,
        cluster: Optional[ClusterSpec] = None,
        sa_policy: Optional[ScreenThenRefinePolicy] = None,
        samplers: Optional[Dict[str, Any]] = None,
        n_boot: int = 32,
        input_keys: Optional[Sequence[Any]] = None,
        store_dir: Optional[str] = None,
        backend: Any = None,
        hierarchy: Any = None,
        evaluate_delta: Optional[
            Callable[
                [Sequence[ParamSet]],
                Tuple[Dict[ParamSet, float], Dict[str, int]],
            ]
        ] = None,
    ):
        self.workflow = workflow
        self.inputs = list(inputs)
        self.objective = objective
        self.maximize = maximize
        self.state = state or StudyState(space, seed=seed, store_dir=store_dir)
        if tuple(self.state.space.names) != tuple(space.names):
            raise ValueError("resumed StudyState belongs to a different space")
        if engine_policy not in CACHING_POLICIES:
            raise ValueError(
                f"engine_policy {engine_policy!r} disables the result cache; "
                f"adaptive cross-round reuse needs one of {CACHING_POLICIES} "
                "(use app.run_study for non-caching baselines)"
            )
        self.engine_policy = engine_policy
        self.max_bucket_size = max_bucket_size
        self.active_paths = active_paths
        self.memory = memory or MemoryBudget()
        self.cluster = cluster or ClusterSpec()
        self.sa_policy = sa_policy or ScreenThenRefinePolicy()
        self.samplers = samplers or {
            "moat": MoatSampler(),
            "vbd": SaltelliSampler(),
            "refine": RefinementSampler(),
        }
        self.n_boot = n_boot
        # WorkerBackend spec for the study's persistent Manager session:
        # None/"thread" (in-process Workers) or a constructed
        # ProcessRpcBackend whose build() produces this study's workflow
        # and inputs in each worker process (DESIGN.md §13).
        self.backend = backend
        # Scheduler topology spec for the session (DESIGN.md §15):
        # None/"flat" for the single-pump Manager, int/"auto"/"fanout=N,..."
        # for hierarchical sub-manager pumps.
        self.hierarchy = hierarchy
        # Optional out-of-process evaluation hook (the fleet runner): given
        # the round's delta, returns (ParamSet -> objective, counter stats).
        # The hook owns planning/execution/state-merge; the driver keeps the
        # science loop (propose/analyze/decide) and best-point tracking.
        self._evaluate_delta = evaluate_delta
        self.input_keys = (
            list(input_keys) if input_keys is not None else list(range(len(inputs)))
        )
        if self.state.input_keys is None:
            self.state.input_keys = list(self.input_keys)
        elif self.state.input_keys != self.input_keys:
            raise ValueError(
                "resumed StudyState was built over inputs "
                f"{self.state.input_keys!r}, not {self.input_keys!r}: its "
                "evaluated objectives and stored results would be about "
                "different data"
            )

    # ------------------------------------------------------------------
    # Incremental evaluation (the delta path)
    # ------------------------------------------------------------------
    def _ensure_manager(self) -> Manager:
        st = self.state
        if st.manager is None or not st.manager.is_running:
            st.manager = Manager(
                backend=self.backend,
                max_attempts=self.cluster.max_attempts,
                heartbeat_timeout=self.cluster.heartbeat_timeout,
                straggler_factor=self.cluster.straggler_factor,
                enable_backup_tasks=self.cluster.enable_backup_tasks,
                hierarchy=self.hierarchy,
            )
            st.manager.start(self.cluster.n_workers)
        return st.manager

    def evaluate(
        self, param_sets: Sequence[ParamSet]
    ) -> Tuple[List[float], Dict[str, int]]:
        """Objective per proposed ParamSet, computing only the delta.

        Already-evaluated proposals (any prior round, or duplicates within
        this list) are recalled from the state; the rest are planned against
        the cached trie and streamed through the persistent session/cache.
        Returns ``(y, stats)`` with y aligned 1:1 to ``param_sets``.
        """
        st = self.state
        delta: List[ParamSet] = []
        seen = set()
        for ps in param_sets:
            if ps not in st.evaluated and ps not in seen:
                seen.add(ps)
                delta.append(ps)
        n_inputs = len(self.inputs)
        stats = {
            "n_new": len(delta),
            "tasks_requested": self.workflow.total_task_count(len(param_sets))
            * n_inputs,
            "planned_tasks": 0,
            "planned_known": 0,
            "tasks_executed": 0,
            "cache_hits": 0,
        }
        if delta and self._evaluate_delta is not None:
            y_by_ps, hook_stats = self._evaluate_delta(delta)
            for ps in delta:
                y = float(y_by_ps[ps])
                st.evaluated[ps] = y
                st.record_best(ps, y, maximize=self.maximize)
            for k in ("planned_tasks", "planned_known", "tasks_executed",
                      "cache_hits"):
                stats[k] = int(hook_stats.get(k, 0))
        elif delta:
            plan = plan_study(
                self.workflow,
                delta,
                memory=self.memory,
                cluster=self.cluster,
                policy=self.engine_policy,
                max_bucket_size=self.max_bucket_size,
                active_paths=self.active_paths,
                ledger=st.ledger,
            )
            st.epoch += 1
            stream = execute_study(
                plan,
                self.inputs,
                cluster=self.cluster,
                cache=st.cache,
                manager=self._ensure_manager(),
                input_keys=self.input_keys,
                key_prefix=f"r{st.epoch}:",
            )
            # execution succeeded: only now do the plan's new trie paths
            # become "known" (i.e. resolvable through the result store)
            st.ledger.add_all(plan.ledger_pending or ())
            for rid, ps in enumerate(delta):
                vals = [
                    float(self.objective(stream.outputs[i][rid], i))
                    for i in range(n_inputs)
                ]
                y = sum(vals) / len(vals)
                st.evaluated[ps] = y
                st.record_best(ps, y, maximize=self.maximize)
            stats.update(
                planned_tasks=plan.tasks_executed * n_inputs,
                planned_known=plan.tasks_known * n_inputs,
                tasks_executed=stream.tasks_executed,
                cache_hits=stream.cache_hits,
            )
        return [st.evaluated[ps] for ps in param_sets], stats

    # ------------------------------------------------------------------
    # The round loop
    # ------------------------------------------------------------------
    def _analyze(self, record: RoundRecord) -> Dict[str, Any]:
        st = self.state
        sub = active_space(st)
        y = record.outputs
        if record.meta.get("method") == "moat":
            moves = [[(int(i), p) for i, p in traj] for traj in record.meta["moves"]]
            res = moat_indices(sub, y, moves, n_boot=self.n_boot, seed=st.seed)
            return {
                "mu": res.mu,
                "mu_star": res.mu_star,
                "sigma": res.sigma,
                "mu_star_ci": res.mu_star_ci,
                "ranking": res.ranking(),
            }
        if record.meta.get("method") == "vbd":
            res = vbd_indices(
                sub, y, record.meta["n_base"], n_boot=self.n_boot, seed=st.seed
            )
            return {
                "first_order": res.first_order,
                "total": res.total,
                "first_order_ci": res.first_order_ci,
                "total_ci": res.total_ci,
                "ranking": res.ranking(),
            }
        return {}

    def run_round(self, sampler: Any) -> RoundRecord:
        """Execute one full propose → evaluate → analyze → decide round."""
        st = self.state
        prev_best = None if st.best is None else st.best[1]
        proposed, meta = sampler.propose(st, len(st.rounds))
        t0 = time.perf_counter()
        y, stats = self.evaluate(proposed)
        record = RoundRecord(
            index=len(st.rounds),
            kind=sampler.name,
            param_sets=list(proposed),
            outputs=y,
            meta=meta,
            n_proposed=len(proposed),
            wall_seconds=time.perf_counter() - t0,
            **stats,
        )
        record.analysis = self._analyze(record)
        if sampler.name in ("refine", "tune"):
            new_best = st.best[1] if st.best else None
            if prev_best is None:
                improved = float("inf")
            else:
                improved = (
                    (new_best - prev_best) if self.maximize else (prev_best - new_best)
                )
            record.analysis = {"improved": max(0.0, improved)}
        st.rounds.append(record)
        decision = self.sa_policy.decide(st, record)
        record.decision = decision.to_json()
        st.freeze(decision.prune)
        st.phase = decision.next_phase
        return record

    def run(self, *, max_rounds: int = 6) -> StudyState:
        """Drive rounds until the policy stops the study (or the budget
        runs out), picking each round's sampler by the current phase."""
        while len(self.state.rounds) < max_rounds and self.state.phase != "stop":
            sampler = self.samplers.get(self.state.phase)
            if sampler is None:
                break
            self.run_round(sampler)
        return self.state

    # ------------------------------------------------------------------
    # Importance-guided tuning (coordinate descent on the objective)
    # ------------------------------------------------------------------
    def _importance_order(self) -> List[str]:
        for record in reversed(self.state.rounds):
            ranking = record.analysis.get("ranking")
            if ranking:
                return [n for n in ranking if n in self.state.active]
        return list(self.state.active)

    def tune(
        self, *, max_sweeps: int = 2, improve_tol: float = 1e-4
    ) -> Tuple[ParamSet, float]:
        """Importance-guided coordinate descent: sweep the active parameters
        in importance order, evaluating each one's full grid with every
        other parameter pinned at the incumbent — the classic post-SA
        tuning mode (Barreiros & Teodoro 1811.11653). One-coordinate
        proposals share the incumbent's trie prefix, so each sweep is
        almost entirely served by the persistent store."""
        st = self.state
        if st.best is None:
            self.evaluate([st.space.default()])
        for _ in range(max_sweeps):
            t0 = time.perf_counter()
            prev_best = st.best[1]
            sweep_sets: List[ParamSet] = []
            sweep_stats = {
                "n_new": 0, "tasks_requested": 0, "planned_tasks": 0,
                "planned_known": 0, "tasks_executed": 0, "cache_hits": 0,
            }
            for name in self._importance_order():
                anchor = dict(st.best[0])
                param = next(p for p in st.space.params if p.name == name)
                candidates = []
                for v in param.values:
                    d = dict(anchor)
                    d[name] = v
                    candidates.append(paramset(d))
                _, stats = self.evaluate(candidates)
                for k in sweep_stats:
                    sweep_stats[k] += stats[k]
                sweep_sets.extend(candidates)
            improved = (
                (st.best[1] - prev_best) if self.maximize else (prev_best - st.best[1])
            )
            y = [st.evaluated[ps] for ps in sweep_sets]
            record = RoundRecord(
                index=len(st.rounds),
                kind="tune",
                param_sets=sweep_sets,
                outputs=y,
                meta={"method": "tune"},
                n_proposed=len(sweep_sets),
                wall_seconds=time.perf_counter() - t0,
                analysis={"improved": max(0.0, improved)},
                **sweep_stats,
            )
            st.rounds.append(record)
            record.decision = Decision(
                prune=[],
                next_phase="stop" if improved <= improve_tol else "tune",
                reason="tune sweep",
                converged=improved <= improve_tol,
            ).to_json()
            if improved <= improve_tol:
                break
        return st.best

    # ------------------------------------------------------------------
    # Lifecycle / reporting
    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        st = self.state
        if st.manager is not None:
            backend_name = st.manager.backend_name
            dispatch = dict(st.manager.dispatch_counts)
        else:  # fleet leader (evaluate_delta hook) or nothing evaluated yet
            backend_name = None
            dispatch = {}
        return {
            **st.counters(),
            "active": list(st.active),
            "frozen": dict(st.frozen),
            "phase": st.phase,
            "backend": backend_name,
            "dispatch_counts": dispatch,
            "best": None if st.best is None else {"params": dict(st.best[0]), "objective": st.best[1]},
        }

    def save(self, path: str) -> None:
        self.state.save(path)

    def close(self) -> None:
        self.state.close()

    def __enter__(self) -> "StudyDriver":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Fleet execution: N StudyDriver processes pooling ONE SharedStore
# ---------------------------------------------------------------------------
#
# ``run_fleet_study`` shards each adaptive round's delta run-list across K
# worker *processes* (``multiprocessing.get_context("spawn")``), every one
# mounting the same :class:`~repro.runtime.SharedStore` directory. The
# leader keeps the science loop — its StudyDriver proposes, analyzes and
# decides exactly as single-process — and its ``evaluate_delta`` hook farms
# the execution out; after each round the workers' evaluated objectives and
# committed ledger keys are unioned back (``StudyState.merge_fleet``), so
# round N+1 plans against everything ANY process computed. Tasks are pure
# functions of (input, params): sharding cannot change an objective value,
# so the fleet's SA indices are bit-identical to the single-process run.
#
# ``build`` must be a module-level (spawn-picklable) callable returning a
# mapping with "workflow", "space", "inputs", "objective" and optionally
# "input_keys" — each process calls it once to construct its own (process-
# local, unpicklable) task functions and inputs.

FleetBuild = Callable[..., Mapping[str, Any]]

_FLEET_WORKER: Dict[str, Any] = {}  # per-process singleton driver (spawn init)


def _fleet_worker_init(
    build: FleetBuild,
    build_kwargs: Optional[Dict[str, Any]],
    store_dir: str,
    store_ram_bytes: int,
    seed: int,
    engine_policy: str,
    cluster: Optional[ClusterSpec],
    cache_bytes: Optional[int],
    worker_backend: Any = None,
) -> None:
    """Pool initializer (runs once per spawned worker): build the workflow
    in-process, mount the SharedStore, and keep one StudyDriver — with its
    persistent Manager session and store-backed cache — alive across every
    round this worker serves."""
    from repro.engine.types import DEFAULT_CACHE_BYTES
    from repro.runtime.storage import mount_store

    # a raising Pool initializer makes the pool respawn workers forever;
    # park the failure and surface it on the first shard instead
    try:
        spec = build(**(build_kwargs or {}))
        # store_dir is a SPEC: plain directory → flocked SharedStore,
        # "obj:<root>" → object-store tier (no shared filesystem needed)
        store = mount_store(
            store_dir, store_ram_bytes, writer_id=f"fleetw{os.getpid()}"
        )
        state = StudyState(
            spec["space"],
            seed=seed,
            cache_bytes=cache_bytes or DEFAULT_CACHE_BYTES,
            store=store,
        )
        _FLEET_WORKER["driver"] = StudyDriver(
            spec["workflow"],
            spec["space"],
            spec["inputs"],
            objective=spec["objective"],
            state=state,
            seed=seed,
            engine_policy=engine_policy,
            cluster=cluster,
            input_keys=spec.get("input_keys"),
            # the fleet's execution path flows through the same
            # WorkerBackend API as every other Manager session
            backend=worker_backend,
        )
    except BaseException as e:  # noqa: BLE001
        _FLEET_WORKER["init_error"] = e


def _fleet_worker_eval(args: Tuple[List[Any], List[str]]) -> Dict[str, Any]:
    """Evaluate one shard of a round's delta: seed the ledger with the
    fleet-wide union (so the delta plan knows every process's committed
    keys), execute through the shared store, then flush the cache to the
    store's disk tier — the publish point peers rehydrate from."""
    shard_json, ledger_entries = args
    if "init_error" in _FLEET_WORKER:
        raise RuntimeError(
            "fleet worker failed to initialise"
        ) from _FLEET_WORKER["init_error"]
    drv: StudyDriver = _FLEET_WORKER["driver"]
    st = drv.state
    st.ledger.merge(ledger_entries)
    known = set(st.ledger.to_list())
    shard = [_ps_from_json(ps) for ps in shard_json]
    # store counters are worker-lifetime; the leader sums per-shard deltas
    before = (st.store.corrupt, st.store.dedup_writes, st.store.disk_hits)
    y, stats = drv.evaluate(shard)
    stats["cache_flushed"] = st.cache.flush()
    return {
        "evaluated": [[_ps_to_json(ps), y_i] for ps, y_i in zip(shard, y)],
        # only the entries THIS shard added: the leader already holds the
        # union it sent, so shipping the whole ledger back every round
        # would grow the IPC payload with total study size
        "ledger": sorted(set(st.ledger.to_list()) - known),
        "stats": stats,
        "corrupt": st.store.corrupt - before[0],
        "dedup_writes": st.store.dedup_writes - before[1],
        "store_disk_hits": st.store.disk_hits - before[2],
    }


def run_fleet_study(
    build: FleetBuild,
    build_kwargs: Optional[Dict[str, Any]] = None,
    *,
    n_procs: int = 2,
    store_dir: str,
    max_rounds: int = 4,
    seed: int = 0,
    engine_policy: str = "hybrid",
    cluster: Optional[ClusterSpec] = None,
    sa_policy: Optional[ScreenThenRefinePolicy] = None,
    samplers: Optional[Dict[str, Any]] = None,
    n_boot: int = 32,
    store_ram_bytes: int = 256 << 20,
    cache_bytes: Optional[int] = None,
    mp_context: str = "spawn",
    worker_backend: Any = None,
) -> Tuple[StudyState, Dict[str, Any]]:
    """Run one adaptive study as a fleet of ``n_procs`` StudyDriver worker
    processes pooling a single :class:`~repro.runtime.SharedStore` on
    ``store_dir``. Returns ``(leader StudyState, fleet stats)``.

    The leader's state carries the merged evaluated map, ledger union and
    per-round records (stats summed across shards); ``fleet_stats`` reports
    the cross-process accounting — combined tasks executed, corrupt-entry
    reads observed anywhere in the fleet (must stay 0), double-writes the
    per-key locks elided, and cross-process store rehydrations.
    """
    if n_procs < 1:
        raise ValueError("run_fleet_study needs n_procs >= 1")
    # worker_backend crosses the spawn boundary via Pool initargs, so it
    # must be a picklable SPEC — None/"thread", or a module-level zero-arg
    # factory returning a WorkerBackend. A constructed backend instance
    # holds locks/pipes and cannot be shipped; reject it here instead of
    # failing deep inside Pool creation.
    if not (
        worker_backend is None
        or isinstance(worker_backend, str)
        or (callable(worker_backend) and not hasattr(worker_backend, "offer"))
    ):
        raise ValueError(
            "worker_backend must be None, a backend spec string ('thread', "
            "'process[...]', 'socket[...]'), or a spawn-picklable factory "
            "callable returning a WorkerBackend; a constructed backend "
            "instance cannot cross the fleet's spawn boundary"
        )
    # the leader never evaluates (its evaluate_delta hook farms every delta
    # out), so a build that offers a ``leader`` flag may skip constructing
    # the objective's heavy parts (e.g. reference segmentations)
    import inspect

    leader_kwargs = dict(build_kwargs or {})
    if "leader" in inspect.signature(build).parameters:
        leader_kwargs["leader"] = True
    spec = build(**leader_kwargs)
    from repro.engine.types import DEFAULT_CACHE_BYTES
    from repro.runtime.storage import mount_store

    store = mount_store(store_dir, store_ram_bytes, writer_id="fleet-leader")
    state = StudyState(
        spec["space"],
        seed=seed,
        cache_bytes=cache_bytes or DEFAULT_CACHE_BYTES,
        store=store,
    )
    fleet_stats: Dict[str, Any] = {
        "n_procs": n_procs,
        "shards_dispatched": 0,
        "corrupt": 0,
        "dedup_writes": 0,
        "store_disk_hits": 0,
        "cache_flushed": 0,  # entries the workers' publish flushes persisted
        "worker_backend": worker_backend if isinstance(worker_backend, str)
        else ("thread" if worker_backend is None else "factory"),
    }
    # `pool` is assigned below, after the driver is built — creating the
    # worker processes last means a bad driver argument cannot leak a
    # spawned pool. The closure only runs inside driver.run().
    pool = None
    # ledger entries already broadcast to the pool: each round ships only
    # the union's delta, keeping per-round IPC proportional to new work
    # instead of total study size. (A worker idle for a round misses that
    # round's delta, which can only undercount its known_nodes STATS — the
    # store serves the values regardless of ledger annotations, so results
    # and reuse are unaffected.)
    broadcast: set = set()

    def fleet_evaluate(
        delta: Sequence[ParamSet],
    ) -> Tuple[Dict[ParamSet, float], Dict[str, int]]:
        # contiguous block shards: samplers emit structurally-related runs
        # adjacently (a MOAT trajectory, a Saltelli radial block), so blocks
        # keep deep shared prefixes on ONE worker — the cross-worker overlap
        # left is mostly roots, which the SharedStore dedups
        chunk = (len(delta) + n_procs - 1) // n_procs
        shards = [list(delta[i * chunk:(i + 1) * chunk]) for i in range(n_procs)]
        shards = [s for s in shards if s]
        ledger_entries = sorted(set(state.ledger.to_list()) - broadcast)
        broadcast.update(ledger_entries)
        payloads = pool.map(
            _fleet_worker_eval,
            [
                ([_ps_to_json(ps) for ps in shard], ledger_entries)
                for shard in shards
            ],
            chunksize=1,
        )
        state.merge_fleet(payloads)
        y_by_ps: Dict[ParamSet, float] = {}
        agg = {"planned_tasks": 0, "planned_known": 0, "tasks_executed": 0,
               "cache_hits": 0}
        for shard, p in zip(shards, payloads):
            for ps, (_ps_j, y) in zip(shard, p["evaluated"]):
                y_by_ps[ps] = float(y)
            for k in agg:
                agg[k] += int(p["stats"].get(k, 0))
            fleet_stats["corrupt"] += int(p["corrupt"])
            fleet_stats["dedup_writes"] += int(p["dedup_writes"])
            fleet_stats["store_disk_hits"] += int(p["store_disk_hits"])
            fleet_stats["cache_flushed"] += int(p["stats"].get("cache_flushed", 0))
        fleet_stats["shards_dispatched"] += len(shards)
        return y_by_ps, agg

    driver = StudyDriver(
        spec["workflow"],
        spec["space"],
        spec["inputs"],
        objective=spec["objective"],
        state=state,
        seed=seed,
        engine_policy=engine_policy,
        cluster=cluster,
        sa_policy=sa_policy,
        samplers=samplers,
        n_boot=n_boot,
        input_keys=spec.get("input_keys"),
        evaluate_delta=fleet_evaluate,
    )
    pool = multiprocessing.get_context(mp_context).Pool(
        n_procs,
        initializer=_fleet_worker_init,
        initargs=(
            build,
            build_kwargs,
            store.disk_dir,
            store_ram_bytes,
            seed,
            engine_policy,
            cluster,
            cache_bytes,
            worker_backend,
        ),
    )
    try:
        driver.run(max_rounds=max_rounds)
    finally:
        pool.close()
        pool.join()
        driver.close()
    fleet_stats["corrupt"] += state.store.corrupt
    fleet_stats["tasks_executed"] = state.tasks_executed
    fleet_stats["tasks_requested"] = state.tasks_requested
    fleet_stats["committed_keys"] = len(store.committed_keys())
    return state, fleet_stats

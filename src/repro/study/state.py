"""Round-persistent state of an adaptive SA study (DESIGN.md §11).

A :class:`StudyState` is everything the :class:`~repro.study.StudyDriver`
carries *between* rounds — the reason round *N+1* is incremental instead of
a from-scratch study:

* the **evaluated map** ``ParamSet → objective`` — proposals a prior round
  already produced are recalled, never re-planned;
* the engine's :class:`~repro.engine.TrieLedger` — the "cached trie" the
  delta plan is annotated against;
* the **persistent result store** — a
  :class:`~repro.runtime.HierarchicalStore` (RAM tier + content-addressed
  npz disk tier) backing the round-shared
  :class:`~repro.engine.ResultCache`, so evicted and prior-round task
  outputs are spilled and rehydrated instead of recomputed;
* one live Manager session (not persisted) spanning every round;
* the science bookkeeping: active/frozen parameters, phase, best point,
  and one :class:`RoundRecord` per completed round.

``save``/``load`` serialise the state to JSON next to the store's disk
directory. Everything in the checkpoint is process-independent — ParamSets,
ledger entries and store keys use deterministic serialisations — so a
resumed study on a fresh process recomputes **zero** already-cached tasks.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, List, Optional, Tuple

from repro.core.params import ParamSet, ParamSpace
from repro.engine.executor import ResultCache
from repro.engine.planner import TrieLedger
from repro.engine.types import DEFAULT_CACHE_BYTES
from repro.runtime.manager import Manager
from repro.runtime.storage import HierarchicalStore

__all__ = ["RoundRecord", "StudyState"]

STATE_VERSION = 1


def _ps_to_json(ps: ParamSet) -> List[List[Any]]:
    return [[k, v] for k, v in ps]


def _ps_from_json(obj: List[List[Any]]) -> ParamSet:
    return tuple((str(k), v) for k, v in obj)


@dataclasses.dataclass
class RoundRecord:
    """One completed round: what was proposed, what it cost, what it found,
    and what the policy decided. Everything here is JSON-serialisable, and
    ``param_sets`` + ``meta`` are sufficient to replay the round as an
    independent one-shot study (the bit-identicality oracle in tests)."""

    index: int
    kind: str  # sampler name: "moat" | "vbd" | "refine" | "tune"
    param_sets: List[ParamSet]  # the full proposed run-list, in order
    outputs: List[float]  # objective per proposed run (computed or recalled)
    meta: Dict[str, Any]  # sampler metadata (moves / n_base / axis)
    n_proposed: int = 0
    n_new: int = 0  # the delta actually planned this round
    tasks_requested: int = 0  # naive count: proposed runs × workflow tasks
    planned_tasks: int = 0  # delta plan's merged-task count
    planned_known: int = 0  # …of which the ledger already held
    tasks_executed: int = 0  # measured (cache/store hits subtracted)
    cache_hits: int = 0
    wall_seconds: float = 0.0
    analysis: Dict[str, Any] = dataclasses.field(default_factory=dict)
    decision: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["param_sets"] = [_ps_to_json(ps) for ps in self.param_sets]
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "RoundRecord":
        d = dict(d)
        d["param_sets"] = [_ps_from_json(ps) for ps in d["param_sets"]]
        return cls(**d)


class StudyState:
    """Cross-round memory of an adaptive study; see module docstring."""

    def __init__(
        self,
        space: ParamSpace,
        *,
        seed: int = 0,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        store: Optional[HierarchicalStore] = None,
        store_dir: Optional[str] = None,
        store_ram_bytes: int = 256 << 20,
    ):
        self.space = space
        self.seed = seed
        self.cache_bytes = int(cache_bytes)
        self.active: List[str] = list(space.names)
        self.frozen: Dict[str, Any] = {}
        self.phase = "moat"
        self.evaluated: Dict[ParamSet, float] = {}
        self.best: Optional[Tuple[ParamSet, float]] = None
        self.rounds: List[RoundRecord] = []
        self.epoch = 0  # evaluate() calls ever made; prefixes Manager keys
        # The identities of the study's inputs (the cache's input-scope
        # segment). Set by the driver on first use and checked on resume:
        # a state resumed over different/reordered inputs would otherwise
        # silently serve the old inputs' cached results.
        self.input_keys: Optional[List[Any]] = None
        # --- runtime (rebuilt on load, never serialised) ---
        if store is not None:
            self.store = store
        elif store_dir is not None and str(store_dir).startswith("obj:"):
            # "obj:<root>" mounts the object-store tier (§16); ``save``
            # records ``store.disk_dir`` — the spec itself — so a resumed
            # study remounts the same object root with zero recompute
            from repro.runtime.storage import mount_store

            self.store = mount_store(store_dir, store_ram_bytes, writer_id="study")
        else:
            self.store = HierarchicalStore(store_ram_bytes, disk_dir=store_dir)
        self.cache = ResultCache(self.cache_bytes, spill_store=self.store)
        self.ledger = TrieLedger()
        self.manager: Optional[Manager] = None

    # ------------------------------------------------------------------
    # Science bookkeeping
    # ------------------------------------------------------------------
    def record_best(self, ps: ParamSet, y: float, *, maximize: bool) -> bool:
        """Track the incumbent objective; returns True if ``ps`` took it."""
        if self.best is None:
            improved = True
        else:
            improved = y > self.best[1] if maximize else y < self.best[1]
        if improved:
            self.best = (ps, y)
        return improved

    def freeze(self, names: List[str]) -> None:
        """Prune parameters: drop from the active set, pinning each at its
        value in the incumbent best point (an already-evaluated coordinate,
        maximising later trie-prefix overlap) or the space default."""
        anchor = dict(self.best[0]) if self.best else dict(self.space.default())
        for name in names:
            if name in self.active:
                self.active.remove(name)
                self.frozen[name] = anchor[name]

    def merge_fleet(self, payloads: List[Dict[str, Any]]) -> None:
        """Fold fleet-worker round payloads (``repro.study.run_fleet_study``)
        into this state — the fleet-merge path: each worker evaluated a
        shard of the round's delta against the shared store, and the union
        of their evaluated objectives and committed ledger keys is what
        round N+1 proposes and plans against. Objectives are pure functions
        of (input, params), so merge order cannot change a value."""
        for p in payloads:
            for ps_json, y in p.get("evaluated", ()):
                self.evaluated.setdefault(_ps_from_json(ps_json), float(y))
            self.ledger.merge(p.get("ledger", ()))

    @property
    def tasks_requested(self) -> int:
        return sum(r.tasks_requested for r in self.rounds)

    @property
    def tasks_executed(self) -> int:
        return sum(r.tasks_executed for r in self.rounds)

    @property
    def cache_hits(self) -> int:
        return sum(r.cache_hits for r in self.rounds)

    def counters(self) -> Dict[str, Any]:
        """The study-wide reuse accounting reported by summaries."""
        from repro.core.metrics import reuse_factor

        return {
            "rounds": len(self.rounds),
            "tasks_requested": self.tasks_requested,
            "tasks_executed": self.tasks_executed,
            "reuse_factor": reuse_factor(self.tasks_executed, self.tasks_requested),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_spills": self.cache.spills,
            "cache_rehydrations": self.cache.rehydrations,
            "store_disk_hits": self.store.disk_hits,
            "ledger_paths": len(self.ledger),
        }

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def close(self) -> None:
        if self.manager is not None and self.manager.is_running:
            self.manager.close()
        self.manager = None

    # ------------------------------------------------------------------
    # Persistence
    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        """Checkpoint to JSON; flushes the result cache through to the
        store's disk tier first, so a resumed study rehydrates everything
        this one computed."""
        self.cache.flush()
        # async-commit backends (DESIGN.md §14) ack completions ahead of
        # their disk commit; the barrier makes everything staged durable so
        # a checkpoint never references results newer than the store
        if self.manager is not None and self.manager.is_running:
            barrier = getattr(self.manager.backend, "barrier", None)
            if barrier is not None:
                barrier()
        payload = {
            "version": STATE_VERSION,
            "seed": self.seed,
            "cache_bytes": self.cache_bytes,
            "space": [[p.name, list(p.values)] for p in self.space.params],
            "active": list(self.active),
            "frozen": [[k, v] for k, v in self.frozen.items()],
            "phase": self.phase,
            "epoch": self.epoch,
            "input_keys": self.input_keys,
            "best": None
            if self.best is None
            else [_ps_to_json(self.best[0]), self.best[1]],
            "evaluated": [[_ps_to_json(ps), y] for ps, y in self.evaluated.items()],
            "rounds": [r.to_json() for r in self.rounds],
            "ledger": self.ledger.to_list(),
            "store_dir": self.store.disk_dir,
        }
        p = pathlib.Path(path)
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps(payload, indent=1))
        tmp.replace(p)

    @classmethod
    def load(cls, path: str, *, store_dir: Optional[str] = None) -> "StudyState":
        """Rebuild a state from a checkpoint. The result store is re-opened
        on its (content-addressed) disk directory — pass ``store_dir`` to
        override, e.g. after moving the checkpoint."""
        d = json.loads(pathlib.Path(path).read_text())
        if d.get("version") != STATE_VERSION:
            raise ValueError(f"unsupported StudyState version {d.get('version')!r}")
        space = ParamSpace.from_dict({name: vals for name, vals in d["space"]})
        st = cls(
            space,
            seed=d["seed"],
            cache_bytes=d["cache_bytes"],
            store_dir=store_dir or d["store_dir"],
        )
        st.active = list(d["active"])
        st.frozen = {k: v for k, v in d["frozen"]}
        st.phase = d["phase"]
        st.epoch = d["epoch"]
        st.input_keys = d.get("input_keys")
        if d["best"] is not None:
            st.best = (_ps_from_json(d["best"][0]), d["best"][1])
        st.evaluated = {_ps_from_json(ps): y for ps, y in d["evaluated"]}
        st.rounds = [RoundRecord.from_json(r) for r in d["rounds"]]
        st.ledger = TrieLedger.from_list(d["ledger"])
        return st

"""Samplers — the "propose" half of the adaptive study round loop.

Contract (DESIGN.md §11): a sampler is an object with a ``name`` and

    propose(state, round_index) -> (param_sets, meta)

where ``param_sets`` is the round's full proposed run-list over the *whole*
parameter space (pruned parameters completed with their frozen values, so
cross-round trie prefixes stay shareable) and ``meta`` carries whatever the
analyzer needs to turn the objective vector back into indices (MOAT's
``moves``, Saltelli's ``n_base``). Samplers must be deterministic functions
of ``(state.seed, round_index, state.active)`` — the driver's
reproducibility and the tests' one-shot oracle both rely on it.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

from repro.core.params import (
    ParamSet,
    ParamSpace,
    morris_trajectories,
    paramset,
)
from repro.core.sa import saltelli_sample
from repro.study.state import StudyState

__all__ = [
    "active_space",
    "complete",
    "MoatSampler",
    "SaltelliSampler",
    "RefinementSampler",
]


def active_space(state: StudyState) -> ParamSpace:
    """The sub-space of still-active parameters, in original order."""
    return ParamSpace(
        tuple(p for p in state.space.params if p.name in state.active)
    )


def complete(sub: ParamSet, state: StudyState) -> ParamSet:
    """Extend an active-subspace ParamSet with the frozen values of every
    pruned parameter (canonical sorted-tuple form)."""
    d = dict(sub)
    d.update(state.frozen)
    return paramset(d)


class MoatSampler:
    """Morris One-At-A-Time trajectories over the active sub-space (the
    screening phase). ``meta['moves']`` indexes into the proposed list."""

    name = "moat"

    def __init__(self, n_trajectories: int = 2):
        self.n_trajectories = n_trajectories

    def propose(
        self, state: StudyState, round_index: int
    ) -> Tuple[List[ParamSet], Dict[str, Any]]:
        sub = active_space(state)
        sets, moves = morris_trajectories(
            sub, self.n_trajectories, seed=state.seed + round_index
        )
        return [complete(s, state) for s in sets], {
            "method": "moat",
            "moves": [[[int(i), p] for i, p in traj] for traj in moves],
        }


class SaltelliSampler:
    """Saltelli A/B/A_B^(i) cross-sampling over the active sub-space (the
    VBD phase on screening survivors)."""

    name = "vbd"

    def __init__(self, n_base: int = 8):
        self.n_base = n_base

    def propose(
        self, state: StudyState, round_index: int
    ) -> Tuple[List[ParamSet], Dict[str, Any]]:
        sub = active_space(state)
        sets, n_base = saltelli_sample(
            sub, self.n_base, seed=state.seed + round_index
        )
        return [complete(s, state) for s in sets], {
            "method": "vbd",
            "n_base": n_base,
        }


class RefinementSampler:
    """Grid densification around the incumbent best point: one-at-a-time
    sweeps of each active parameter over its grid neighbourhood (±``radius``
    steps), every other parameter held at the incumbent value.

    Because each proposal differs from the (already-evaluated) incumbent in
    exactly one coordinate, proposals share the incumbent's trie prefix up
    to that coordinate's task — the refinement phase is where cross-round
    incremental reuse pays the most.
    """

    name = "refine"

    def __init__(self, radius: int = 1):
        self.radius = radius

    def propose(
        self, state: StudyState, round_index: int
    ) -> Tuple[List[ParamSet], Dict[str, Any]]:
        anchor = dict(state.best[0]) if state.best else dict(state.space.default())
        sets: List[ParamSet] = [paramset(anchor)]
        for p in state.space.params:
            if p.name not in state.active:
                continue
            cur = p.values.index(anchor[p.name])
            for step in range(-self.radius, self.radius + 1):
                idx = cur + step
                if step == 0 or idx < 0 or idx >= p.cardinality:
                    continue
                d = dict(anchor)
                d[p.name] = p.values[idx]
                sets.append(paramset(d))
        return sets, {"method": "refine", "anchor": [[k, v] for k, v in sorted(anchor.items())]}

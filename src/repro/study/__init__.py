"""Adaptive multi-round SA driver — the science loop above the engine
(DESIGN.md §11).

``StudyDriver`` runs rounds of propose → evaluate → analyze → decide over a
round-persistent ``StudyState``: a pluggable sampler proposes ParamSets,
the engine executes only the round's *delta* (incremental planning against
the cached trie, one persistent Manager session, a store-backed result
cache that survives eviction and process restarts), ``core.sa`` computes
indices with bootstrap CIs, and a pluggable policy prunes / refines /
stops. The canonical workflow is MOAT screening → VBD on the survivors →
grid refinement, plus a coordinate-descent ``tune`` mode.

``run_fleet_study`` scales the same loop across worker *processes*: each
round's delta is sharded over a spawn pool whose members all mount one
crash-safe :class:`~repro.runtime.SharedStore` directory, and the leader
plans round N+1 against the union of every process's committed keys
(DESIGN.md §12) — bit-identical indices, pooled reuse.
"""

from repro.study.driver import StudyDriver, run_fleet_study  # noqa: F401
from repro.study.policies import Decision, ScreenThenRefinePolicy  # noqa: F401
from repro.study.samplers import (  # noqa: F401
    MoatSampler,
    RefinementSampler,
    SaltelliSampler,
    active_space,
    complete,
)
from repro.study.state import RoundRecord, StudyState  # noqa: F401

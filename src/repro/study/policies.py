"""Policies — the "decide" half of the adaptive study round loop.

Contract (DESIGN.md §11): a policy is an object with

    decide(state, record) -> Decision

inspecting the round's analysis (indices + bootstrap CIs) and the study
history, and returning what happens next: which parameters to prune
(``Decision.prune``), which phase runs next (``"moat"`` | ``"vbd"`` |
``"refine"`` | ``"stop"``), and why. The driver applies the decision —
policies never mutate state, which keeps them unit-testable on synthetic
records.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.study.state import RoundRecord, StudyState

__all__ = ["Decision", "ScreenThenRefinePolicy"]


@dataclasses.dataclass
class Decision:
    prune: List[str]
    next_phase: str  # "moat" | "vbd" | "refine" | "stop"
    reason: str
    converged: bool = False

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class ScreenThenRefinePolicy:
    """The canonical adaptive workflow (Teodoro et al. 1612.03413; Barreiros
    & Teodoro 1811.11653): MOAT screening prunes unimportant parameters,
    VBD quantifies the survivors, then grid refinement densifies around the
    important region until improvements dry up.

    Pruning is CI-aware: a parameter is pruned after MOAT only when the
    *upper* end of its bootstrapped μ* interval falls below
    ``mu_star_rel`` × the best μ* point estimate — i.e. when even an
    optimistic read says it does not matter. After VBD the same rule runs
    on S_Ti with ``total_rel``. Without CIs (``n_boot=0``) the point
    estimates are compared directly. At least ``min_active`` parameters
    always survive (the top of the ranking is exempt from pruning).

    Refinement stops — and the study converges — when a refinement round
    improves the incumbent objective by less than ``improve_tol``
    (relative), or after ``max_refine_rounds`` refinements.
    """

    def __init__(
        self,
        *,
        mu_star_rel: float = 0.1,
        total_rel: float = 0.05,
        min_active: int = 2,
        max_refine_rounds: int = 1,
        improve_tol: float = 1e-3,
    ):
        self.mu_star_rel = mu_star_rel
        self.total_rel = total_rel
        self.min_active = min_active
        self.max_refine_rounds = max_refine_rounds
        self.improve_tol = improve_tol

    def _prunable(
        self,
        point: Dict[str, float],
        upper: Dict[str, float],
        rel_threshold: float,
        keep: int,
    ) -> List[str]:
        """Names whose optimistic (CI-upper) index stays below the relative
        threshold, never pruning into the top-``keep`` of the ranking."""
        if not point:
            return []
        ranking = sorted(point, key=lambda k: -point[k])
        protected = set(ranking[: max(0, keep)])
        cutoff = rel_threshold * max(max(point.values()), 1e-12)
        return [
            name
            for name in ranking
            if name not in protected and upper.get(name, point[name]) < cutoff
        ]

    def decide(self, state: StudyState, record: RoundRecord) -> Decision:
        analysis = record.analysis
        if record.kind == "moat":
            point = analysis.get("mu_star", {})
            # analysis stores ci=None when n_boot=0: fall back to points
            upper = {
                k: hi for k, (_, hi) in (analysis.get("mu_star_ci") or {}).items()
            }
            prune = self._prunable(point, upper, self.mu_star_rel, self.min_active)
            if len(prune) >= len(state.active):
                # never prune to zero: spare the top-ranked name (prunable
                # names come back most-important-first)
                prune = prune[1:]
            return Decision(
                prune=prune,
                next_phase="vbd",
                reason=(
                    f"MOAT screen: pruned {len(prune)}/{len(state.active)} "
                    f"params below {self.mu_star_rel:.0%} of max mu*"
                ),
            )
        if record.kind == "vbd":
            point = analysis.get("total", {})
            upper = {
                k: hi for k, (_, hi) in (analysis.get("total_ci") or {}).items()
            }
            prune = self._prunable(point, upper, self.total_rel, self.min_active)
            return Decision(
                prune=prune,
                next_phase="refine",
                reason=(
                    f"VBD: pruned {len(prune)} params below "
                    f"{self.total_rel:.0%} of max S_Ti; refining around best"
                ),
            )
        if record.kind in ("refine", "tune"):
            n_refines = sum(1 for r in state.rounds if r.kind == record.kind)
            improved = record.analysis.get("improved", 0.0)
            scale = abs(state.best[1]) if state.best else 1.0
            if improved <= self.improve_tol * max(scale, 1e-12):
                return Decision(
                    prune=[],
                    next_phase="stop",
                    reason=f"converged: refinement improved {improved:.2e}",
                    converged=True,
                )
            if n_refines >= self.max_refine_rounds:
                return Decision(
                    prune=[],
                    next_phase="stop",
                    reason=f"refine budget exhausted ({n_refines} rounds)",
                    converged=False,
                )
            return Decision(
                prune=[], next_phase="refine", reason="refinement still improving"
            )
        return Decision(prune=[], next_phase="stop", reason=f"unknown round kind {record.kind!r}")

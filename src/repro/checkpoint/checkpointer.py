"""Sharded, atomic, async checkpointing with mesh-agnostic restore.

Layout:  <dir>/step_<N>/
            manifest.json        — pytree structure, shapes, dtypes, step
            <leaf-key>.npy       — one file per leaf (host-local full array
                                   on this container; per-shard files when
                                   jax.process_count() > 1)

Properties a 1000-node run needs:
  * atomic — written to ``step_<N>.tmp`` then os.rename'd; a crashed writer
    never leaves a readable-but-corrupt checkpoint;
  * async — ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a background thread, overlapping I/O with the next steps;
  * mesh-agnostic restore — leaves are stored unsharded (or as
    process-shards + manifest), so a surviving sub-mesh can reload and
    reshard after an elastic down-size (dist/sharding.py respecifies);
  * data-iterator state — the manifest carries arbitrary metadata (seed,
    step, iterator offsets) for exact resume.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out, jax.tree.structure(tree)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, metadata: Optional[Dict] = None) -> pathlib.Path:
        self.wait()
        snapshot = [(k, np.asarray(v)) for k, v in _flatten(tree)[0]]
        return self._write(step, snapshot, metadata or {})

    def save_async(self, step: int, tree: Any, *, metadata: Optional[Dict] = None) -> None:
        self.wait()
        snapshot = [(k, np.asarray(v)) for k, v in _flatten(tree)[0]]  # sync copy

        def _bg():
            self._write(step, snapshot, metadata or {})

        self._thread = threading.Thread(target=_bg, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, snapshot, metadata: Dict) -> pathlib.Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "metadata": metadata, "leaves": []}
        for i, (key, arr) in enumerate(snapshot):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_????????"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(self.dir.glob("step_????????"))
        if not steps:
            return None
        return int(steps[-1].name.split("_")[1])

    def restore(self, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like`` (shapes must match;
        sharding is re-applied by the caller via device_put)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
        flat, treedef = jax.tree.flatten(tree_like)
        if len(flat) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, expected {len(flat)}"
            )
        return jax.tree.unflatten(treedef, arrays), manifest["metadata"]

"""Fault-tolerant checkpointing (atomic, async, mesh-agnostic restore)."""

from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401

"""Driver for the static-analysis suite: load sources, run the four passes,
apply inline suppressions and the findings baseline, report.

Programmatic entry point (used by ``__main__``, the self-tests, and
``benchmarks/analysis.py``)::

    report = run_paths([pathlib.Path("src/repro")])
    assert report.ok, report.render()
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from . import blocking, frames, locks, ordering, spawn
from .core import (
    Baseline,
    Finding,
    SourceFile,
    dedupe,
    is_suppressed,
    iter_py_files,
    load_source,
)
from .lockmodel import collect_module

__all__ = ["Report", "run_paths", "run_sources", "default_root", "default_baseline_path"]


def default_root() -> pathlib.Path:
    """The ``src`` directory this package is installed under."""
    return pathlib.Path(__file__).resolve().parents[2]


def default_target() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]  # src/repro


def default_baseline_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent / "baseline.json"


@dataclasses.dataclass
class Report:
    findings: List[Finding]  # unsuppressed, non-baselined
    suppressed: int  # waived by inline ``# analysis: ok[...]``
    baselined: List[Finding]
    stale: List[str]  # baseline fingerprints that no longer fire
    files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    @property
    def strict_ok(self) -> bool:
        return not self.findings and not self.stale

    def render(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(f.render())
        for fp in self.stale:
            lines.append(f"stale baseline entry (no longer fires): {fp}")
        lines.append(
            f"analysis: {self.files} files, {len(self.findings)} findings, "
            f"{self.suppressed} suppressed inline, "
            f"{len(self.baselined)} baselined, {len(self.stale)} stale"
        )
        return "\n".join(lines)


def run_sources(
    sources: Sequence[SourceFile],
    baseline: Optional[Baseline] = None,
) -> Report:
    baseline = baseline or Baseline()
    mods = []
    raw: List[Finding] = []
    by_rel: Dict[str, SourceFile] = {}
    for src in sources:
        by_rel[src.rel] = src
        mod = collect_module(src)
        mods.append(mod)
        raw.extend(locks.run(src, mod))
        raw.extend(blocking.run(src, mod))
        raw.extend(spawn.run(src))
    raw.extend(ordering.run_project(mods))
    raw.extend(frames.run(sources))
    raw = dedupe(raw)

    kept: List[Finding] = []
    suppressed = 0
    for f in raw:
        src = by_rel.get(f.path)
        if src is not None and is_suppressed(src, f):
            suppressed += 1
        else:
            kept.append(f)
    fresh, known, stale = baseline.split(kept)
    return Report(
        findings=fresh,
        suppressed=suppressed,
        baselined=known,
        stale=stale,
        files=len(sources),
    )


def run_paths(
    paths: Optional[Sequence[pathlib.Path]] = None,
    baseline_path: Optional[pathlib.Path] = None,
    root: Optional[pathlib.Path] = None,
) -> Report:
    paths = list(paths) if paths else [default_target()]
    root = root or default_root().parent
    baseline = Baseline.load(baseline_path or default_baseline_path())
    sources = [load_source(p, root) for p in iter_py_files(paths)]
    return run_sources(sources, baseline)

"""repro.analysis — the concurrency & protocol static-analysis suite
(DESIGN.md §17).

Four AST passes, each targeting a bug class this repo has actually
shipped and fixed by hand in an earlier PR:

* ``locks``     — lock discipline (``# guard:`` declarations + inference)
                  and, project-wide, the lock-acquisition-ordering graph.
* ``blocking``  — file/socket I/O, store commits, ``time.sleep`` inside a
                  held-lock region, one call level deep.
* ``frames``    — wire-frame tag/field conformance between every
                  ``_send_frame`` producer and consumer site.
* ``spawn``     — spawn-boundary picklability and result-key/recipe
                  determinism.

Run ``python -m repro.analysis --strict`` (the CI gate), or
``repro.analysis.runner.run_paths()`` programmatically.  Pure stdlib: safe
to run without jax installed.
"""

from .core import Baseline, Finding, SourceFile, source_from_text
from .runner import Report, run_paths, run_sources

__all__ = [
    "Baseline",
    "Finding",
    "Report",
    "SourceFile",
    "run_paths",
    "run_sources",
    "source_from_text",
]

"""Pass 4 — spawn picklability & determinism.

Spawn side: lambdas and closure-local functions flowing into spawn-boundary
call sites (``build=`` / ``initializer=`` keywords anywhere, ``target=`` /
``args=`` / ``initargs=`` on ``Process``/``Pool``-like constructors) cross
a pickle boundary and fail at runtime on spawn start — flag them at the
call site.  Lambda default values on ``build``/``initializer`` parameters
are the same bug one step removed.

Determinism side: result keys and recipe keys must be stable across
processes and runs — inside derivation functions (name matches
``key``/``keys``/``recipe``), flag wall-clock reads, ``random``/``uuid``,
salted ``hash()``/``id()``, ``os.getpid``/``urandom``, unsorted dict
iteration, and ``json.dumps`` without ``sort_keys=True``.

Codes:
  S601  lambda at a spawn boundary
  S602  closure-local function at a spawn boundary
  S603  lambda default on a spawn-boundary parameter
  S611  nondeterministic call in a key/recipe derivation function
  S612  unsorted dict iteration in a key/recipe derivation function
  S613  json.dumps without sort_keys=True in a derivation function
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from .core import Finding, SourceFile, parent_map

__all__ = ["run"]

PASS_ID = "spawn"

_SPAWN_KW_ANY = {"build", "initializer"}
_SPAWN_KW_PROC = {"target", "args", "initargs"}
_PROC_CTOR_RE = re.compile(r"(Process|Pool|Executor)")
_KEY_FN_RE = re.compile(r"(^|_)(key|keys|recipe)(_|$)")
_ORDER_SAFE_WRAPPERS = {"sorted", "set", "frozenset", "min", "max", "sum", "len"}

_TIME_ATTRS = {"time", "time_ns", "monotonic", "monotonic_ns", "perf_counter"}


def _fn_name_of_call(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _closure_fn_names(
    node: ast.AST, parents: Dict[ast.AST, ast.AST]
) -> Set[str]:
    """Names of functions defined inside any enclosing function of ``node``."""
    names: Set[str] = set()
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(cur):
                if (
                    isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and sub is not cur
                ):
                    names.add(sub.name)
        cur = parents.get(cur)
    return names


def _flag_value(
    value: ast.expr,
    closure_names: Set[str],
    src: SourceFile,
    where: str,
    kw: str,
    findings: List[Finding],
) -> None:
    for sub in ast.walk(value):
        if isinstance(sub, ast.Lambda):
            findings.append(
                Finding(
                    PASS_ID,
                    "S601",
                    src.rel,
                    sub.lineno,
                    f"lambda passed to spawn-boundary {kw}= in {where} — "
                    f"not picklable under the spawn start method",
                    f"{where}:{kw}:lambda",
                )
            )
        elif isinstance(sub, ast.Name) and sub.id in closure_names:
            findings.append(
                Finding(
                    PASS_ID,
                    "S602",
                    src.rel,
                    sub.lineno,
                    f"closure-local function {sub.id!r} passed to "
                    f"spawn-boundary {kw}= in {where} — not picklable",
                    f"{where}:{kw}:{sub.id}",
                )
            )


def _enclosing_fn(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> str:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur.name
        cur = parents.get(cur)
    return "<module>"


def _check_spawn(src: SourceFile, parents: Dict[ast.AST, ast.AST]) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call):
            ctor = _fn_name_of_call(node)
            is_proc = bool(_PROC_CTOR_RE.search(ctor))
            closure_names = _closure_fn_names(node, parents)
            where = _enclosing_fn(node, parents)
            for kw in node.keywords:
                if kw.arg in _SPAWN_KW_ANY or (
                    is_proc and kw.arg in _SPAWN_KW_PROC
                ):
                    _flag_value(
                        kw.value, closure_names, src, where, kw.arg, findings
                    )
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            named = args.posonlyargs + args.args + args.kwonlyargs
            defaults = (
                [None] * (len(args.posonlyargs) + len(args.args) - len(args.defaults))
                + list(args.defaults)
                + list(args.kw_defaults)
            )
            for a, d in zip(named, defaults):
                if (
                    d is not None
                    and isinstance(d, ast.Lambda)
                    and a.arg in _SPAWN_KW_ANY
                ):
                    findings.append(
                        Finding(
                            PASS_ID,
                            "S603",
                            src.rel,
                            d.lineno,
                            f"lambda default for spawn-boundary parameter "
                            f"{a.arg!r} of {node.name}() — not picklable",
                            f"{node.name}:{a.arg}:lambda-default",
                        )
                    )
    return findings


def _order_safe(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> bool:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, ast.Call):
            f = cur.func
            if isinstance(f, ast.Name) and f.id in _ORDER_SAFE_WRAPPERS:
                return True
        if isinstance(cur, (ast.stmt, ast.FunctionDef)):
            break
        cur = parents.get(cur)
    return False


def _nondet_desc(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        if f.id in ("hash", "id"):
            return f"{f.id}() (process-salted / address-based)"
        return None
    if not isinstance(f, ast.Attribute):
        return None
    base = f.value.id if isinstance(f.value, ast.Name) else None
    if base == "time" and f.attr in _TIME_ATTRS:
        return f"time.{f.attr}()"
    if base == "random":
        return f"random.{f.attr}()"
    if base == "uuid":
        return f"uuid.{f.attr}()"
    if base == "os" and f.attr in ("urandom", "getpid"):
        return f"os.{f.attr}()"
    return None


def _check_determinism(
    src: SourceFile, parents: Dict[ast.AST, ast.AST]
) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ast.walk(src.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _KEY_FN_RE.search(fn.name):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            desc = _nondet_desc(node)
            if desc:
                findings.append(
                    Finding(
                        PASS_ID,
                        "S611",
                        src.rel,
                        node.lineno,
                        f"nondeterministic {desc} inside key/recipe "
                        f"derivation {fn.name}()",
                        f"{fn.name}:{desc}",
                    )
                )
                continue
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in ("items", "keys", "values")
                and not node.args
                and not _order_safe(node, parents)
            ):
                findings.append(
                    Finding(
                        PASS_ID,
                        "S612",
                        src.rel,
                        node.lineno,
                        f"unsorted .{f.attr}() iteration inside key/recipe "
                        f"derivation {fn.name}() — dict order is "
                        f"insertion-dependent",
                        f"{fn.name}:{f.attr}",
                    )
                )
            if (
                isinstance(f, ast.Attribute)
                and f.attr == "dumps"
                and isinstance(f.value, ast.Name)
                and f.value.id == "json"
            ):
                has_sort = any(
                    kw.arg == "sort_keys"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    for kw in node.keywords
                )
                if not has_sort:
                    findings.append(
                        Finding(
                            PASS_ID,
                            "S613",
                            src.rel,
                            node.lineno,
                            f"json.dumps without sort_keys=True inside "
                            f"key/recipe derivation {fn.name}()",
                            f"{fn.name}:json.dumps",
                        )
                    )
    return findings


def run(src: SourceFile) -> List[Finding]:
    parents = parent_map(src.tree)
    return _check_spawn(src, parents) + _check_determinism(src, parents)

"""Pass 3 — wire-frame conformance.

The socket and process transports exchange length-prefixed pickle frames:
plain dicts tagged by a ``"t"`` key.  This pass extracts, from every
producer site (a dict literal whose ``"t"`` is a string constant, with
``**base`` splats resolved against same-function dict assignments) and
every consumer site (an ``if kind == "tag":`` branch over a variable bound
from ``msg.get("t")``/``msg["t"]``, following the message one call level
deep, plus explicit ``# frame-consumer: tag via msg`` annotations), the
frame tags and field sets in play — then cross-checks sender/receiver
agreement so schema drift between backends is a lint error, not a fleet
hang.

Field requirement rules: ``msg["f"]`` at a consumer's top level (outside
any further ``if``) is *required*; ``msg.get("f")`` or conditional access
is *optional*.  Producers containing unresolvable ``**splats`` are *open*
(tag registration only, no field check).

Codes:
  W501  frame tag produced but never consumed
  W502  frame tag consumed but never produced
  W503  consumer requires a field a closed producer never sends
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile, frame_consumer_comments, parent_map

__all__ = ["collect", "check", "run", "Producer", "Consumer"]

PASS_ID = "frames"


@dataclasses.dataclass
class Producer:
    tag: str
    keys: Set[str]
    closed: bool
    rel: str
    line: int
    where: str


@dataclasses.dataclass
class Consumer:
    tag: str
    required: Set[str]
    optional: Set[str]
    rel: str
    line: int
    where: str


def _dict_info(
    d: ast.Dict, env: Dict[str, Tuple[Set[str], bool, Optional[str]]]
) -> Tuple[Set[str], bool, Optional[str]]:
    keys: Set[str] = set()
    closed = True
    tag: Optional[str] = None
    for k, v in zip(d.keys, d.values):
        if k is None:  # **splat
            if isinstance(v, ast.Name) and v.id in env:
                ks, cl, tg = env[v.id]
                keys |= ks
                closed = closed and cl
                tag = tag or tg
            else:
                closed = False
        elif isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.add(k.value)
            if k.value == "t" and isinstance(v, ast.Constant) and isinstance(
                v.value, str
            ):
                tag = v.value
        else:
            closed = False
    return keys, closed, tag


def _functions(tree: ast.Module) -> List[ast.FunctionDef]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append(node)
    return out


def _collect_producers(src: SourceFile) -> List[Producer]:
    producers: List[Producer] = []
    for fn in _functions(src.tree) + [src.tree]:  # type: ignore[list-item]
        env: Dict[str, Tuple[Set[str], bool, Optional[str]]] = {}
        body_nodes = []
        for node in ast.walk(fn):
            if node is fn:
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and not isinstance(
                fn, ast.Module
            ):
                continue
            body_nodes.append(node)
        # resolve dict-literal assignments in source order
        assigns = [
            n
            for n in body_nodes
            if isinstance(n, ast.Assign)
            and isinstance(n.value, ast.Dict)
            and len(n.targets) == 1
            and isinstance(n.targets[0], ast.Name)
        ]
        for n in sorted(assigns, key=lambda a: a.lineno):
            env[n.targets[0].id] = _dict_info(n.value, env)
        where = getattr(fn, "name", "<module>")
        for node in body_nodes:
            if not isinstance(node, ast.Dict):
                continue
            keys, closed, tag = _dict_info(node, env)
            if tag is not None:
                producers.append(
                    Producer(tag, keys, closed, src.rel, node.lineno, where)
                )
    # module-level scan skipped above for nested fns: dedupe by (line, tag)
    seen = set()
    uniq = []
    for p in producers:
        k = (p.line, p.tag)
        if k not in seen:
            seen.add(k)
            uniq.append(p)
    return uniq


def _field_accesses(
    fn: ast.AST, var: str, parents: Dict[ast.AST, ast.AST], root: ast.AST
) -> Tuple[Set[str], Set[str]]:
    """(required, optional) fields accessed on ``var`` within ``fn``.

    Required: ``var["f"]`` not nested under any If/IfExp/While below
    ``root``.  Optional: ``var.get("f")`` or conditionally-reached
    subscripts.
    """
    required: Set[str] = set()
    optional: Set[str] = set()

    def conditional(node: ast.AST) -> bool:
        cur = parents.get(node)
        while cur is not None and cur is not root:
            if isinstance(cur, (ast.If, ast.IfExp, ast.While)):
                return True
            cur = parents.get(cur)
        return False

    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and node.value.id == var
        ):
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                (optional if conditional(node) else required).add(sl.value)
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "get"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == var
            and node.args
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)
        ):
            optional.add(node.args[0].value)
    required.discard("t")
    optional.discard("t")
    return required, optional


def _tag_expr_var(test: ast.expr) -> Optional[Tuple[str, str]]:
    """Match ``k == "tag"`` or ``msg.get("t") == "tag"`` -> (var, tag)."""
    if not (
        isinstance(test, ast.Compare)
        and len(test.ops) == 1
        and isinstance(test.ops[0], ast.Eq)
        and len(test.comparators) == 1
        and isinstance(test.comparators[0], ast.Constant)
        and isinstance(test.comparators[0].value, str)
    ):
        return None
    tag = test.comparators[0].value
    left = test.left
    if isinstance(left, ast.Name):
        return left.id, tag
    if (
        isinstance(left, ast.Call)
        and isinstance(left.func, ast.Attribute)
        and left.func.attr == "get"
        and isinstance(left.func.value, ast.Name)
        and left.args
        and isinstance(left.args[0], ast.Constant)
        and left.args[0].value == "t"
    ):
        return f"@{left.func.value.id}", tag  # direct msg.get("t") compare
    return None


def _collect_consumers(src: SourceFile) -> List[Consumer]:
    consumers: List[Consumer] = []
    parents = parent_map(src.tree)
    fns = _functions(src.tree)
    by_name: Dict[str, ast.FunctionDef] = {f.name: f for f in fns}
    class_methods: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            for m in node.body:
                if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    class_methods.setdefault(m.name, m)

    for fn in fns:
        # tag variables: k = msg.get("t") / k = msg["t"]
        tagvars: Dict[str, str] = {}
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
            ):
                continue
            v = node.value
            msgvar = None
            if (
                isinstance(v, ast.Call)
                and isinstance(v.func, ast.Attribute)
                and v.func.attr == "get"
                and isinstance(v.func.value, ast.Name)
                and v.args
                and isinstance(v.args[0], ast.Constant)
                and v.args[0].value == "t"
            ):
                msgvar = v.func.value.id
            elif (
                isinstance(v, ast.Subscript)
                and isinstance(v.value, ast.Name)
                and isinstance(v.slice, ast.Constant)
                and v.slice.value == "t"
            ):
                msgvar = v.value.id
            if msgvar:
                tagvars[node.targets[0].id] = msgvar

        for node in ast.walk(fn):
            if not isinstance(node, ast.If):
                continue
            hit = _tag_expr_var(node.test)
            if hit is None:
                continue
            var, tag = hit
            if var.startswith("@"):
                msgvar = var[1:]
            elif var in tagvars:
                msgvar = tagvars[var]
            else:
                continue
            required: Set[str] = set()
            optional: Set[str] = set()
            branch = ast.Module(body=node.body, type_ignores=[])
            for stmt in node.body:
                r, o = _field_accesses(stmt, msgvar, parents, node)
                required |= r
                optional |= o
                # follow the message one call level deep
                for sub in ast.walk(stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    try:
                        idx = next(
                            i
                            for i, a in enumerate(sub.args)
                            if isinstance(a, ast.Name) and a.id == msgvar
                        )
                    except StopIteration:
                        continue
                    target = None
                    f = sub.func
                    if isinstance(f, ast.Name):
                        target = by_name.get(f.id)
                    elif (
                        isinstance(f, ast.Attribute)
                        and isinstance(f.value, ast.Name)
                        and f.value.id == "self"
                    ):
                        target = class_methods.get(f.attr)
                    if target is None:
                        continue
                    params = [a.arg for a in target.args.args]
                    if params and params[0] == "self":
                        params = params[1:]
                    if idx >= len(params):
                        continue
                    pname = params[idx]
                    tparents = parent_map(target)
                    r2, o2 = _field_accesses(target, pname, tparents, target)
                    required |= r2
                    optional |= o2
            consumers.append(
                Consumer(tag, required, optional, src.rel, node.lineno, fn.name)
            )

        # explicit annotations
        for tags, var in frame_consumer_comments(src, fn):
            fparents = parent_map(fn)
            r, o = _field_accesses(fn, var, fparents, fn)
            if len(tags) > 1:
                # fields can't be attributed to a single tag: register only
                o |= r
                r = set()
            for tag in tags:
                consumers.append(
                    Consumer(tag, set(r), set(o), src.rel, fn.lineno, fn.name)
                )
    return consumers


def collect(src: SourceFile) -> Tuple[List[Producer], List[Consumer]]:
    return _collect_producers(src), _collect_consumers(src)


def check(
    producers: Sequence[Producer], consumers: Sequence[Consumer]
) -> List[Finding]:
    findings: List[Finding] = []
    if not producers and not consumers:
        return findings
    prod_tags = {p.tag for p in producers}
    cons_tags = {c.tag for c in consumers}
    for p in producers:
        if p.tag not in cons_tags:
            findings.append(
                Finding(
                    PASS_ID,
                    "W501",
                    p.rel,
                    p.line,
                    f"frame tag {p.tag!r} produced in {p.where}() but no "
                    f"consumer branch/annotation handles it",
                    f"unconsumed:{p.tag}",
                )
            )
    for c in consumers:
        if c.tag not in prod_tags:
            findings.append(
                Finding(
                    PASS_ID,
                    "W502",
                    c.rel,
                    c.line,
                    f"frame tag {c.tag!r} consumed in {c.where}() but never "
                    f"produced",
                    f"unproduced:{c.tag}",
                )
            )
    for c in consumers:
        for p in producers:
            if p.tag != c.tag or not p.closed:
                continue
            missing = c.required - p.keys
            if missing:
                findings.append(
                    Finding(
                        PASS_ID,
                        "W503",
                        c.rel,
                        c.line,
                        f"consumer {c.where}() of frame {c.tag!r} requires "
                        f"{sorted(missing)} but producer at {p.rel}:{p.line} "
                        f"({p.where}) sends only {sorted(p.keys)}",
                        f"missing:{c.tag}:{','.join(sorted(missing))}",
                    )
                )
    return findings


def run(sources: Sequence[SourceFile]) -> List[Finding]:
    producers: List[Producer] = []
    consumers: List[Consumer] = []
    for src in sources:
        p, c = collect(src)
        producers.extend(p)
        consumers.extend(c)
    return check(producers, consumers)

"""Shared infrastructure for the repro static-analysis suite (DESIGN.md §17).

The suite is pure stdlib (``ast`` + ``tokenize``-free line scanning): it must
run in the leanest CI job and inside ``benchmarks/run.py`` without importing
jax or the runtime under analysis.

Three cross-cutting conventions live here:

``# guard: <lock>``
    On an attribute assignment (normally in ``__init__``): declares that the
    attribute is protected by the named lock attribute of the same class (or,
    at module scope, by the named module-level lock).  A class with at least
    one declaration runs the lock-discipline pass in *declared* mode —
    inference is off and exactly the declared set is checked.

``# holds: <lock>``
    On a ``def`` line: the function is only ever called with that lock held
    (the repo-wide ``*_locked`` naming convention is recognised implicitly
    and means "all locks of the owning class").

``# analysis: ok[<pass-or-code>, ...] <reason>``
    Inline suppression.  Placed on the flagged line (or on a pure-comment
    line directly above it) it waives the finding; ``ok[all]`` waives every
    pass.  Deliberate design points (e.g. the frame-send serialization lock)
    are suppressed inline so the baseline file stays empty of routine
    entries.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "SourceFile",
    "Baseline",
    "load_source",
    "source_from_text",
    "iter_py_files",
    "is_suppressed",
    "parent_map",
    "guard_comment",
    "holds_comment",
    "frame_consumer_comments",
]

_SUPPRESS_RE = re.compile(r"#\s*analysis:\s*ok\[([A-Za-z0-9_,\- ]+)\]")
_GUARD_RE = re.compile(r"#\s*guard:\s*([A-Za-z_][A-Za-z0-9_]*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_][A-Za-z0-9_]*)")
_CONSUMER_RE = re.compile(
    r"#\s*frame-consumer:\s*([A-Za-z0-9_,\- ]+?)\s+via\s+([A-Za-z_][A-Za-z0-9_]*)"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic. ``subject`` is the line-drift-tolerant identity used
    for baselining; ``line`` is presentation only."""

    pass_id: str  # locks | ordering | blocking | frames | spawn
    code: str  # e.g. L201
    path: str  # repo-relative posix path of the analyzed file
    line: int
    message: str
    subject: str

    @property
    def fingerprint(self) -> str:
        return f"{self.pass_id}:{self.path}:{self.code}:{self.subject}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{self.pass_id}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    path: Optional[pathlib.Path]
    rel: str  # stable identity used in findings/baseline
    text: str
    lines: List[str]
    tree: ast.Module


def source_from_text(text: str, rel: str = "<fixture>") -> SourceFile:
    """Build a SourceFile from an in-memory snippet (self-test fixtures)."""
    return SourceFile(
        path=None,
        rel=rel,
        text=text,
        lines=text.splitlines(),
        tree=ast.parse(text),
    )


def load_source(path: pathlib.Path, root: Optional[pathlib.Path]) -> SourceFile:
    text = path.read_text()
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix() if root else None
    except ValueError:
        rel = None
    return SourceFile(
        path=path,
        rel=rel or path.as_posix(),
        text=text,
        lines=text.splitlines(),
        tree=ast.parse(text, filename=str(path)),
    )


def iter_py_files(paths: Sequence[pathlib.Path]) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    seen: Set[pathlib.Path] = set()
    uniq = []
    for p in out:
        rp = p.resolve()
        if rp not in seen:
            seen.add(rp)
            uniq.append(p)
    return uniq


def _line(src: SourceFile, lineno: int) -> str:
    if 1 <= lineno <= len(src.lines):
        return src.lines[lineno - 1]
    return ""


def is_suppressed(src: SourceFile, finding: Finding) -> bool:
    """True when the flagged line (or the contiguous pure-comment block
    right above it) carries an ``# analysis: ok[...]`` waiver naming the
    pass or code."""

    def waives(text: str) -> bool:
        m = _SUPPRESS_RE.search(text)
        if not m:
            return False
        names = {t.strip() for t in m.group(1).split(",")}
        return "all" in names or finding.pass_id in names or finding.code in names

    if waives(_line(src, finding.line)):
        return True
    lineno = finding.line - 1
    while lineno >= 1:
        text = _line(src, lineno)
        if not text.strip() or not text.lstrip().startswith("#"):
            break
        if waives(text):
            return True
        lineno -= 1
    return False


def guard_comment(src: SourceFile, lineno: int) -> Optional[str]:
    m = _GUARD_RE.search(_line(src, lineno))
    return m.group(1) if m else None


def holds_comment(src: SourceFile, lineno: int) -> Optional[str]:
    m = _HOLDS_RE.search(_line(src, lineno))
    return m.group(1) if m else None


def frame_consumer_comments(src: SourceFile, fn: ast.AST) -> List[Tuple[List[str], str]]:
    """``frame-consumer: tag1,tag2 via msg`` comment annotations attached
    to a function: searched on the def line and every line of the body."""
    out: List[Tuple[List[str], str]] = []
    end = getattr(fn, "end_lineno", fn.lineno)
    for lineno in range(fn.lineno, end + 1):
        m = _CONSUMER_RE.search(_line(src, lineno))
        if m:
            tags = [t.strip() for t in m.group(1).split(",") if t.strip()]
            out.append((tags, m.group(2)))
    return out


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


class Baseline:
    """The findings baseline: fingerprints of known, justified findings.

    Every entry must carry a non-empty ``reason`` — the loader rejects
    unexplained entries, which is how "the baseline ships empty of
    unexplained entries" is enforced mechanically rather than by review.
    """

    def __init__(self, entries: Optional[Dict[str, str]] = None) -> None:
        self.entries: Dict[str, str] = dict(entries or {})

    @classmethod
    def load(cls, path: pathlib.Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text() or "{}")
        entries: Dict[str, str] = {}
        for row in data.get("entries", []):
            fp = row.get("fingerprint", "")
            reason = (row.get("reason") or "").strip()
            if not fp:
                raise ValueError(f"baseline {path}: entry without fingerprint: {row!r}")
            if not reason:
                raise ValueError(
                    f"baseline {path}: unexplained entry (empty reason): {fp}"
                )
            entries[fp] = reason
        return cls(entries)

    def dump(self, path: pathlib.Path) -> None:
        rows = [
            {"fingerprint": fp, "reason": reason}
            for fp, reason in sorted(self.entries.items())
        ]
        path.write_text(json.dumps({"entries": rows}, indent=1) + "\n")

    def split(
        self, findings: Sequence[Finding]
    ) -> Tuple[List[Finding], List[Finding], List[str]]:
        """-> (unsuppressed, baselined, stale_fingerprints)."""
        seen: Set[str] = set()
        fresh: List[Finding] = []
        known: List[Finding] = []
        for f in findings:
            if f.fingerprint in self.entries:
                seen.add(f.fingerprint)
                known.append(f)
            else:
                fresh.append(f)
        stale = sorted(set(self.entries) - seen)
        return fresh, known, stale


def dedupe(findings: Iterable[Finding]) -> List[Finding]:
    """Collapse repeated fingerprints, keeping the earliest line."""
    best: Dict[str, Finding] = {}
    for f in findings:
        cur = best.get(f.fingerprint)
        if cur is None or f.line < cur.line:
            best[f.fingerprint] = f
    return sorted(best.values(), key=lambda f: (f.path, f.line, f.code))

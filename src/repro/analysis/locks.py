"""Pass 1a — lock discipline.

For every class, determine which attributes are guarded by which lock —
either *declared* via the ``# guard: _lock`` annotation convention (any
declaration switches the class to declared mode, inference off) or
*inferred* from dominant ``with self._lock:`` usage — then flag every
unguarded read/write of a guarded field.

Codes:
  L101  guard annotation names an unknown lock
  L201  write to a guarded field outside its lock
  L202  read of a guarded field outside its lock
  L211  write outside the lock that guards this field (inferred)
  L212  read outside the lock that guards this field (inferred)
"""

from __future__ import annotations

import ast
import collections
from typing import Dict, FrozenSet, List, Optional, Tuple

from .core import Finding, SourceFile
from .lockmodel import ClassModel, HeldWalker, ModuleModel, collect_module

__all__ = ["run"]

PASS_ID = "locks"

# inference: an attribute with >= MIN_SITES accesses, >= RATIO of them under
# one dominant lock (and at least one held write), is treated as guarded
_MIN_SITES = 4
_RATIO = 0.75

# a call to one of these on a guarded container IS a write, even though the
# attribute itself is only loaded (``self._queue.pop()``): the historical
# dequeue/lease race was exactly this shape
_MUTATOR_ATTRS = {
    "append", "extend", "insert", "pop", "popitem", "remove", "clear",
    "add", "discard", "update", "setdefault", "appendleft", "popleft",
    "move_to_end", "sort",
}


def _fn_qual(cls: Optional[ClassModel], fn: ast.FunctionDef) -> str:
    return f"{cls.name}.{fn.name}" if cls else fn.name


def _self_accesses(
    mod: ModuleModel, cls: ClassModel, fn: ast.FunctionDef
) -> List[Tuple[str, bool, FrozenSet[str], int]]:
    """(attr, is_write, held, lineno) for every ``self.X`` access."""
    out = []
    w = HeldWalker(mod, cls, fn)
    mutated_loads = set()
    for node, _held in w.walk():
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _MUTATOR_ATTRS
        ):
            target = node.func.value
            if (
                isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"
            ):
                mutated_loads.add(id(target))
    w = HeldWalker(mod, cls, fn)
    for node, held in w.walk():
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            is_write = (
                isinstance(node.ctx, (ast.Store, ast.Del))
                or id(node) in mutated_loads
            )
            out.append((node.attr, is_write, held, node.lineno))
    return out


def _check_class(mod: ModuleModel, cls: ClassModel, findings: List[Finding]) -> None:
    src = mod.src
    for lineno, bad in cls.guard_errors:
        findings.append(
            Finding(
                PASS_ID,
                "L101",
                src.rel,
                lineno,
                f"{cls.name}: '# guard: {bad}' names no known lock attribute "
                f"(locks: {sorted(set(cls.locks)) or 'none'})",
                f"{cls.name}:badguard:{bad}",
            )
        )

    if cls.declared:
        guards = dict(cls.guards)
        codes = ("L201", "L202")
    else:
        guards = _infer_guards(mod, cls)
        codes = ("L211", "L212")
    if not guards:
        return

    for name, fn in cls.methods.items():
        if name == "__init__":
            continue
        for attr, is_write, held, lineno in _self_accesses(mod, cls, fn):
            lock = guards.get(attr)
            if lock is None:
                continue
            lock_id = f"{cls.name}.{lock}"
            if lock_id in held:
                continue
            kind = "write to" if is_write else "read of"
            code = codes[0] if is_write else codes[1]
            how = "declared" if cls.declared else "inferred"
            findings.append(
                Finding(
                    PASS_ID,
                    code,
                    src.rel,
                    lineno,
                    f"{kind} {cls.name}.{attr} outside {cls.name}.{lock} "
                    f"({how} guard) in {_fn_qual(cls, fn)}()",
                    f"{cls.name}.{attr}:{fn.name}:{'w' if is_write else 'r'}",
                )
            )


def _infer_guards(mod: ModuleModel, cls: ClassModel) -> Dict[str, str]:
    if not cls.locks:
        return {}
    stats: Dict[str, collections.Counter] = collections.defaultdict(
        collections.Counter
    )
    total: collections.Counter = collections.Counter()
    held_write: collections.Counter = collections.Counter()
    for name, fn in cls.methods.items():
        if name == "__init__":
            continue
        for attr, is_write, held, _ in _self_accesses(mod, cls, fn):
            if attr in cls.locks:
                continue
            total[attr] += 1
            for lid in held:
                if lid.startswith(f"{cls.name}."):
                    stats[attr][lid.split(".", 1)[1]] += 1
                    if is_write:
                        held_write[attr] += 1
    guards: Dict[str, str] = {}
    for attr, n in total.items():
        if n < _MIN_SITES or not stats[attr]:
            continue
        lock, held_n = stats[attr].most_common(1)[0]
        if held_n / n >= _RATIO and held_write[attr] > 0:
            guards[attr] = lock
    return guards


def _check_module_guards(mod: ModuleModel, findings: List[Finding]) -> None:
    src = mod.src
    for lineno, bad in mod.guard_errors:
        findings.append(
            Finding(
                PASS_ID,
                "L101",
                src.rel,
                lineno,
                f"module-level '# guard: {bad}' names no module-level lock",
                f"module:badguard:{bad}",
            )
        )
    if not mod.guards:
        return
    fns: List[Tuple[Optional[ClassModel], ast.FunctionDef]] = [
        (None, fn) for fn in mod.functions.values()
    ]
    for cls in mod.classes.values():
        fns.extend((cls, m) for m in cls.methods.values())
    for cls, fn in fns:
        w = HeldWalker(mod, cls, fn)
        for node, held in w.walk():
            if not (isinstance(node, ast.Name) and node.id in mod.guards):
                continue
            lock = mod.guards[node.id]
            if f"mod.{lock}" in held:
                continue
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            findings.append(
                Finding(
                    PASS_ID,
                    "L201" if is_write else "L202",
                    src.rel,
                    node.lineno,
                    f"{'write to' if is_write else 'read of'} module-level "
                    f"{node.id} outside {lock} in {_fn_qual(cls, fn)}()",
                    f"module.{node.id}:{_fn_qual(cls, fn)}:"
                    f"{'w' if is_write else 'r'}",
                )
            )


def run(src: SourceFile, mod: Optional[ModuleModel] = None) -> List[Finding]:
    mod = mod or collect_module(src)
    findings: List[Finding] = []
    for cls in mod.classes.values():
        _check_class(mod, cls, findings)
    _check_module_guards(mod, findings)
    return findings

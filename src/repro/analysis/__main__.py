"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Exit status: 0 when clean; 1 on unsuppressed findings (always) or on stale
baseline entries (``--strict`` only — strict is the CI gate and insists the
baseline stays minimal).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from .core import Baseline
from .runner import default_baseline_path, default_target, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro concurrency/protocol static-analysis suite "
        "(DESIGN.md §17)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        type=pathlib.Path,
        help=f"files/dirs to analyze (default: {default_target()})",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="also fail on stale baseline entries (the CI gate)",
    )
    ap.add_argument(
        "--baseline",
        type=pathlib.Path,
        default=None,
        help=f"findings baseline (default: {default_baseline_path()})",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite the baseline to exactly today's findings "
        "(reasons must then be filled in by hand — entries are written "
        "with reason 'TODO: justify' and strict mode rejects them)",
    )
    args = ap.parse_args(argv)

    baseline_path = args.baseline or default_baseline_path()
    report = run_paths(args.paths or None, baseline_path=baseline_path)

    if args.write_baseline:
        old = Baseline.load(baseline_path)
        entries = {}
        for f in report.findings + report.baselined:
            entries[f.fingerprint] = old.entries.get(f.fingerprint, "TODO: justify")
        Baseline(entries).dump(baseline_path)
        print(f"wrote {len(entries)} entries to {baseline_path}")
        return 0

    out = report.render()
    print(out)
    if report.findings:
        return 1
    if args.strict and not report.strict_ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

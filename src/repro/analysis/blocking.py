"""Pass 2 — blocking calls inside a held-lock region.

Flags file/socket I/O, ``os.replace``/``fsync``, store commits,
``time.sleep``, thread joins, and ``Connection.send/recv``-style calls that
are reachable while a lock is syntactically held — either directly or one
call level deep (``with self._lock: self._spill(...)`` where ``_spill``
performs the I/O).

Codes:
  B401  blocking call directly inside a held-lock region
  B402  call inside a held-lock region reaches a blocking call (1 level)
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .core import Finding, SourceFile
from .lockmodel import ClassModel, HeldWalker, ModuleModel, collect_module

__all__ = ["run"]

PASS_ID = "blocking"

_OS_ATTRS = {
    "replace", "fsync", "link", "rename", "fdopen", "open",
    "remove", "unlink", "makedirs", "urandom",
}
_CONN_ATTRS = {
    "send_bytes", "recv_bytes", "sendall", "recv", "send",
    "accept", "connect", "listen",
}
_PATH_ATTRS = {
    "read_bytes", "read_text", "write_text", "write_bytes",
    "mkdir", "iterdir", "rmdir", "touch", "unlink", "glob", "rglob",
}
_COMMIT_ATTRS = {"persist", "persist_all", "flush", "commit", "barrier"}
_NP_ATTRS = {"load", "save", "savez", "savez_compressed"}
_BARE_NAMES = {"_send_frame", "_recv_frame", "sleep"}


def blocking_desc(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        if fn.id == "open":
            return "open()"
        if fn.id in _BARE_NAMES:
            return f"{fn.id}()"
        return None
    if not isinstance(fn, ast.Attribute):
        return None
    attr = fn.attr
    base = fn.value
    base_name = base.id if isinstance(base, ast.Name) else None
    if base_name == "time" and attr in ("sleep",):
        return "time.sleep()"
    if base_name == "os" and attr in _OS_ATTRS:
        return f"os.{attr}()"
    if base_name == "select" and attr == "select":
        return "select.select()"
    if base_name == "fcntl" and attr in ("flock", "lockf"):
        return f"fcntl.{attr}()"
    if base_name in ("np", "numpy") and attr in _NP_ATTRS:
        return f"{base_name}.{attr}()"
    if attr == "sleep":
        return f".{attr}()"
    if attr in _CONN_ATTRS:
        return f".{attr}()"
    if attr in _PATH_ATTRS:
        return f".{attr}()"
    if attr in _COMMIT_ATTRS:
        return f".{attr}()"
    if attr == "join" and not isinstance(base, ast.Constant):
        # thread/process join; "sep".join(...) has a Constant base
        return ".join()"
    return None


def _iter_skip_defs(node: ast.AST):
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        yield child
        yield from _iter_skip_defs(child)


def _callee_blocking(
    target: ast.FunctionDef,
) -> Optional[Tuple[str, int]]:
    """First direct blocking call in a function body (nested defs skipped)."""
    for stmt in target.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in _iter_skip_defs(stmt):
            if isinstance(node, ast.Call):
                desc = blocking_desc(node)
                if desc:
                    return desc, node.lineno
        if isinstance(stmt, ast.Call):  # bare expression call
            desc = blocking_desc(stmt)
            if desc:
                return desc, stmt.lineno
    return None


def _resolve_local_call(
    mod: ModuleModel, cls: Optional[ClassModel], call: ast.Call
) -> Optional[Tuple[str, ast.FunctionDef]]:
    fn = call.func
    if isinstance(fn, ast.Name) and fn.id in mod.functions:
        return fn.id, mod.functions[fn.id]
    if (
        isinstance(fn, ast.Attribute)
        and isinstance(fn.value, ast.Name)
        and fn.value.id == "self"
        and cls is not None
        and fn.attr in cls.methods
    ):
        return f"{cls.name}.{fn.attr}", cls.methods[fn.attr]
    return None


def run(src: SourceFile, mod: Optional[ModuleModel] = None) -> List[Finding]:
    mod = mod or collect_module(src)
    findings: List[Finding] = []
    fns: List[Tuple[Optional[ClassModel], ast.FunctionDef]] = [
        (None, fn) for fn in mod.functions.values()
    ]
    for cls in mod.classes.values():
        fns.extend((cls, m) for m in cls.methods.values())

    for cls, fn in fns:
        where = f"{cls.name}.{fn.name}" if cls else fn.name
        walker = HeldWalker(mod, cls, fn)
        if walker.exempt:
            continue
        seen: set = set()
        for node, held in walker.walk():
            if not held or not isinstance(node, ast.Call) or id(node) in seen:
                continue
            seen.add(id(node))
            lock = sorted(held)[0]
            desc = blocking_desc(node)
            if desc:
                findings.append(
                    Finding(
                        PASS_ID,
                        "B401",
                        src.rel,
                        node.lineno,
                        f"blocking call {desc} while holding {lock} in {where}()",
                        f"{where}:{desc}",
                    )
                )
                continue
            resolved = _resolve_local_call(mod, cls, node)
            if resolved is None:
                continue
            tname, target = resolved
            # a callee that itself acquires the lock is a lock-region, not a
            # blocking leaf — still scanned: its body I/O is under its lock
            inner = _callee_blocking(target)
            if inner:
                idesc, iline = inner
                findings.append(
                    Finding(
                        PASS_ID,
                        "B402",
                        src.rel,
                        node.lineno,
                        f"{tname}() called while holding {lock} in {where}() "
                        f"reaches blocking {idesc} (line {iline})",
                        f"{where}:{tname}:{idesc}",
                    )
                )
    return findings

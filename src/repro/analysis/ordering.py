"""Pass 1b — lock-acquisition ordering.

Builds the directed lock-acquisition graph across every analyzed class
(Manager/_SubPump/ResultCache/SharedStore/SocketBackend/…): an edge
``A -> B`` means some code path acquires ``B`` while holding ``A``, either
lexically (``with A: ... with B:``) or one call level deep (``with A:
self.x.m()`` where ``m`` acquires ``B`` — ``self.x``'s class resolved from
its constructor assignment).  Any cycle in the graph is a potential
deadlock and is reported.

Codes:
  O301  lock-ordering cycle
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .core import Finding, SourceFile
from .lockmodel import ClassModel, HeldWalker, ModuleModel, collect_module

__all__ = ["run", "build_edges"]

PASS_ID = "ordering"


@dataclasses.dataclass(frozen=True)
class Edge:
    src_lock: str
    dst_lock: str
    rel: str
    line: int
    where: str


def _direct_acquisitions(
    mod: ModuleModel, cls: Optional[ClassModel], fn: ast.FunctionDef
) -> Set[str]:
    """Locks this function itself acquires via ``with`` (class/module locks
    only — heuristic local/obj locks don't participate in the graph)."""
    w = HeldWalker(mod, cls, fn)
    for _ in w.walk():
        pass
    return {
        lid
        for _, lid, _ in w.acquisitions
        if not lid.startswith(("local.", "obj."))
    }


def _resolve_call(
    mod: ModuleModel,
    cls: Optional[ClassModel],
    call: ast.Call,
    registry: Dict[str, Tuple[ModuleModel, ClassModel]],
) -> Optional[Tuple[ModuleModel, Optional[ClassModel], ast.FunctionDef]]:
    fn = call.func
    if isinstance(fn, ast.Name):
        target = mod.functions.get(fn.id)
        if target is not None:
            return mod, None, target
        return None
    if not isinstance(fn, ast.Attribute):
        return None
    base = fn.value
    if isinstance(base, ast.Name) and base.id == "self" and cls is not None:
        target = cls.methods.get(fn.attr)
        if target is not None:
            return mod, cls, target
        return None
    # self.X.m() with self.X = ClassName(...) and ClassName analyzed
    if (
        isinstance(base, ast.Attribute)
        and isinstance(base.value, ast.Name)
        and base.value.id == "self"
        and cls is not None
    ):
        type_name = cls.attr_types.get(base.attr)
        if type_name and type_name in registry:
            tmod, tcls = registry[type_name]
            target = tcls.methods.get(fn.attr)
            if target is not None:
                return tmod, tcls, target
    return None


def build_edges(
    mods: List[ModuleModel],
    registry: Optional[Dict[str, Tuple[ModuleModel, ClassModel]]] = None,
) -> List[Edge]:
    if registry is None:
        registry = {}
        for m in mods:
            for cls in m.classes.values():
                registry.setdefault(cls.name, (m, cls))
    edges: List[Edge] = []

    def record(held: FrozenSet[str], lid: str, rel: str, line: int, where: str) -> None:
        for h in held:
            if h.startswith(("local.", "obj.")) or lid.startswith(("local.", "obj.")):
                continue
            if h != lid:
                edges.append(Edge(h, lid, rel, line, where))

    for mod in mods:
        fns: List[Tuple[Optional[ClassModel], ast.FunctionDef]] = [
            (None, fn) for fn in mod.functions.values()
        ]
        for cls in mod.classes.values():
            fns.extend((cls, m) for m in cls.methods.values())
        for cls, fn in fns:
            where = f"{cls.name}.{fn.name}" if cls else fn.name
            w = HeldWalker(mod, cls, fn)
            calls: List[Tuple[ast.Call, FrozenSet[str]]] = []
            for node, held in w.walk():
                if isinstance(node, ast.Call) and held:
                    calls.append((node, held))
            for held, lid, node in w.acquisitions:
                record(held, lid, mod.src.rel, node.lineno, where)
            for call, held in calls:
                resolved = _resolve_call(mod, cls, call, registry)
                if resolved is None:
                    continue
                tmod, tcls, target = resolved
                for lid in _direct_acquisitions(tmod, tcls, target):
                    record(held, lid, mod.src.rel, call.lineno, where)
    return edges


def _cycles(edges: List[Edge]) -> List[List[str]]:
    graph: Dict[str, Set[str]] = {}
    for e in edges:
        graph.setdefault(e.src_lock, set()).add(e.dst_lock)
        graph.setdefault(e.dst_lock, set())
    # Tarjan SCC
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    onstack: Set[str] = set()
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    def strongconnect(v: str) -> None:
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        onstack.add(v)
        for wnode in graph[v]:
            if wnode not in index:
                strongconnect(wnode)
                low[v] = min(low[v], low[wnode])
            elif wnode in onstack:
                low[v] = min(low[v], index[wnode])
        if low[v] == index[v]:
            comp = []
            while True:
                wnode = stack.pop()
                onstack.discard(wnode)
                comp.append(wnode)
                if wnode == v:
                    break
            sccs.append(comp)

    for v in sorted(graph):
        if v not in index:
            strongconnect(v)
    out = []
    for comp in sccs:
        if len(comp) > 1:
            out.append(sorted(comp))
        elif comp[0] in graph[comp[0]]:  # self-loop: re-acquire A under A
            out.append(comp)
    return out


def run_project(mods: List[ModuleModel]) -> List[Finding]:
    edges = build_edges(mods)
    findings: List[Finding] = []
    for cycle in _cycles(edges):
        members = set(cycle)
        sites = [
            e for e in edges if e.src_lock in members and e.dst_lock in members
        ]
        site = min(sites, key=lambda e: (e.rel, e.line))
        detail = "; ".join(
            f"{e.src_lock}->{e.dst_lock} at {e.rel}:{e.line} ({e.where})"
            for e in sites[:4]
        )
        findings.append(
            Finding(
                PASS_ID,
                "O301",
                site.rel,
                site.line,
                f"lock-ordering cycle {' -> '.join(cycle + [cycle[0]])}: {detail}",
                "cycle:" + "->".join(cycle),
            )
        )
    return findings


def run(src: SourceFile, mod: Optional[ModuleModel] = None) -> List[Finding]:
    return run_project([mod or collect_module(src)])

"""Lock/guard model shared by the lock-discipline, ordering, and blocking
passes: which attributes are locks, which Condition aliases which Lock,
which fields are declared guarded, and — per AST node — which locks are
syntactically held.

Lock identities are strings:

* ``Class.attr``   — an instance lock attribute (Condition aliases resolve
  to the canonical underlying Lock attribute).
* ``mod.NAME``     — a module-level lock.
* ``local.NAME`` / ``obj.x.attr`` — heuristically lock-shaped with-targets
  (a ``lock`` parameter, a per-handle ``send_lock``); used by the blocking
  pass only, never for guard checking.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from .core import SourceFile, guard_comment, holds_comment

__all__ = ["ClassModel", "ModuleModel", "collect_module", "HeldWalker"]

_LOCKISH_RE = re.compile(r"(lock|_cond|_mutex)$")

_THREADING_LOCK_CTORS = {"Lock", "RLock"}
_THREADING_COND_CTORS = {"Condition"}


def _ctor_name(call: ast.AST) -> Optional[str]:
    """'Lock' for threading.Lock() / Lock(); None otherwise."""
    if not isinstance(call, ast.Call):
        return None
    fn = call.func
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
        if fn.value.id in ("threading", "th", "mp", "multiprocessing"):
            return fn.attr
        return None
    if isinstance(fn, ast.Name):
        return fn.id
    return None


@dataclasses.dataclass
class ClassModel:
    name: str
    node: ast.ClassDef
    # raw lock attr -> canonical lock attr (Condition(self._lock) -> _lock)
    locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    # guarded attr -> canonical lock attr
    guards: Dict[str, str] = dataclasses.field(default_factory=dict)
    declared: bool = False
    methods: Dict[str, ast.FunctionDef] = dataclasses.field(default_factory=dict)
    # attr -> ClassName for ``self.X = ClassName(...)`` (ordering pass)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    # (lineno, bad_guard_name) for annotations naming unknown locks
    guard_errors: List[Tuple[int, str]] = dataclasses.field(default_factory=list)

    def lock_id(self, attr: str) -> str:
        return f"{self.name}.{self.locks.get(attr, attr)}"

    def all_lock_ids(self) -> Set[str]:
        return {f"{self.name}.{c}" for c in set(self.locks.values())}


@dataclasses.dataclass
class ModuleModel:
    src: SourceFile
    classes: Dict[str, ClassModel] = dataclasses.field(default_factory=dict)
    functions: Dict[str, ast.FunctionDef] = dataclasses.field(default_factory=dict)
    # module-level lock name -> canonical name
    locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    # module-level guarded name -> canonical lock name
    guards: Dict[str, str] = dataclasses.field(default_factory=dict)
    guard_errors: List[Tuple[int, str]] = dataclasses.field(default_factory=list)


def _iter_methods(cls: ast.ClassDef) -> Iterator[ast.FunctionDef]:
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield stmt  # type: ignore[misc]


def _self_attr_targets(stmt: ast.stmt) -> List[str]:
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, ast.AnnAssign):
        targets = [stmt.target]
    out = []
    for t in targets:
        if (
            isinstance(t, ast.Attribute)
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
        ):
            out.append(t.attr)
    return out


def collect_module(src: SourceFile) -> ModuleModel:
    mod = ModuleModel(src=src)
    tree = src.tree

    # module-level locks + guards + functions
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mod.functions[stmt.name] = stmt  # type: ignore[assignment]
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = (
                stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            )
            names = [t.id for t in targets if isinstance(t, ast.Name)]
            value = stmt.value
            ctor = _ctor_name(value) if value is not None else None
            if names and ctor in _THREADING_LOCK_CTORS | _THREADING_COND_CTORS:
                for n in names:
                    mod.locks[n] = n
            else:
                g = guard_comment(src, stmt.lineno)
                if g and names:
                    for n in names:
                        mod.guards[n] = g
    for name, lock in list(mod.guards.items()):
        if lock not in mod.locks:
            mod.guard_errors.append((1, lock))
            del mod.guards[name]

    for stmt in tree.body:
        if not isinstance(stmt, ast.ClassDef):
            continue
        cm = ClassModel(name=stmt.name, node=stmt)
        raw_conds: Dict[str, Optional[str]] = {}
        for meth in _iter_methods(stmt):
            cm.methods[meth.name] = meth
            for node in ast.walk(meth):
                if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                    continue
                attrs = _self_attr_targets(node)
                if not attrs:
                    continue
                value = node.value
                ctor = _ctor_name(value) if value is not None else None
                if ctor in _THREADING_LOCK_CTORS:
                    for a in attrs:
                        cm.locks[a] = a
                elif ctor in _THREADING_COND_CTORS:
                    arg = value.args[0] if getattr(value, "args", None) else None
                    alias = (
                        arg.attr
                        if isinstance(arg, ast.Attribute)
                        and isinstance(arg.value, ast.Name)
                        and arg.value.id == "self"
                        else None
                    )
                    for a in attrs:
                        raw_conds[a] = alias
                elif ctor and ctor[0].isupper():
                    for a in attrs:
                        cm.attr_types[a] = ctor
                # guard annotation may sit on any line of the statement
                g = None
                for ln in range(node.lineno, getattr(node, "end_lineno", node.lineno) + 1):
                    g = guard_comment(src, ln)
                    if g:
                        break
                if g:
                    for a in attrs:
                        cm.guards[a] = g
        for a, alias in raw_conds.items():
            cm.locks[a] = alias if (alias and alias in cm.locks) else a
        # canonicalise guards; drop ones naming unknown locks (reported)
        for a, g in list(cm.guards.items()):
            if g in cm.locks:
                cm.guards[a] = cm.locks[g]
            else:
                cm.guard_errors.append((stmt.lineno, g))
                del cm.guards[a]
        cm.declared = bool(cm.guards)
        mod.classes[stmt.name] = cm
    return mod


class HeldWalker:
    """Yield ``(node, held)`` for every node in a function body, where
    ``held`` is the frozenset of lock ids syntactically held at that node.

    Conventions honoured:

    * ``with self._lock:`` / ``with self._cond:``  — acquires the canonical
      class lock (Condition aliases resolve).
    * methods named ``*_locked``                   — hold every class lock
      on entry (the repo-wide caller-holds convention).
    * ``# holds: _lock`` on the ``def`` line       — holds that lock.
    * nested ``def``/``lambda`` bodies reset ``held`` to the function's
      entry set minus with-acquired locks (a closure does not inherit the
      lexical lock region it was created in).

    ``acquisitions`` records ``(held_before, lock_id, node)`` for every
    with-acquisition — the ordering pass's edge source.
    """

    def __init__(
        self,
        mod: ModuleModel,
        cls: Optional[ClassModel],
        fn: ast.FunctionDef,
    ) -> None:
        self.mod = mod
        self.cls = cls
        self.fn = fn
        self.acquisitions: List[Tuple[FrozenSet[str], str, ast.AST]] = []
        self.exempt = fn.name == "__init__"

    def lock_id_for_expr(self, expr: ast.expr) -> Optional[str]:
        if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
            base, attr = expr.value.id, expr.attr
            if base == "self" and self.cls is not None:
                if attr in self.cls.locks:
                    return self.cls.lock_id(attr)
                if _LOCKISH_RE.search(attr):
                    return f"{self.cls.name}.{attr}"
                return None
            if _LOCKISH_RE.search(attr):
                return f"obj.{base}.{attr}"
            return None
        if isinstance(expr, ast.Name):
            if expr.id in self.mod.locks:
                return f"mod.{self.mod.locks[expr.id]}"
            if _LOCKISH_RE.search(expr.id):
                return f"local.{expr.id}"
        return None

    def initial_held(self) -> FrozenSet[str]:
        held: Set[str] = set()
        if self.cls is not None and self.fn.name.endswith("_locked"):
            held |= self.cls.all_lock_ids()
        h = holds_comment(self.mod.src, self.fn.lineno)
        if h is None and self.fn.lineno > 1:
            h = holds_comment(self.mod.src, self.fn.lineno - 1)
        if h:
            if self.cls is not None and h in self.cls.locks:
                held.add(self.cls.lock_id(h))
            elif h in self.mod.locks:
                held.add(f"mod.{self.mod.locks[h]}")
            else:
                held.add(f"local.{h}")
        return frozenset(held)

    def walk(self) -> Iterator[Tuple[ast.AST, FrozenSet[str]]]:
        entry = self.initial_held()
        yield from self._visit_body(self.fn.body, entry)

    def _visit_body(
        self, body: List[ast.stmt], held: FrozenSet[str]
    ) -> Iterator[Tuple[ast.AST, FrozenSet[str]]]:
        for stmt in body:
            yield from self._visit(stmt, held)

    def _visit(
        self, node: ast.AST, held: FrozenSet[str]
    ) -> Iterator[Tuple[ast.AST, FrozenSet[str]]]:
        yield node, held
        if isinstance(node, ast.With):
            acquired: Set[str] = set()
            for item in node.items:
                yield from self._walk_expr(item.context_expr, held)
                lid = self.lock_id_for_expr(item.context_expr)
                if lid is not None and lid not in held:
                    self.acquisitions.append((held, lid, node))
                    acquired.add(lid)
            inner = held | acquired
            yield from self._visit_body(node.body, frozenset(inner))
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def is a closure that may run later, without the
            # lexical lock; lambdas (sort keys etc.) are treated as inline
            for stmt in node.body:
                yield from self._visit(stmt, frozenset())
            return
        for child in ast.iter_child_nodes(node):
            yield from self._visit(child, held)

    def _walk_expr(
        self, expr: ast.expr, held: FrozenSet[str]
    ) -> Iterator[Tuple[ast.AST, FrozenSet[str]]]:
        for sub in ast.walk(expr):
            yield sub, held

"""Pallas TPU kernel: FlashAttention-2 (causal, sliding-window, GQA).

Blocked streaming softmax: grid = (batch, q-head, q-block parallel;
k-block sequential). The fp32 running max / sum / accumulator live in VMEM
scratch across the sequential k dimension. Block sizes default to 128×128 —
MXU-aligned and ≤ a few hundred KiB of VMEM per buffer.

Masking is positional (causal + optional window), computed from block
indices; fully-masked k-blocks are skipped via ``pl.when`` on the block
bounds, so causal/windowed FLOPs are ~halved vs dense (exactly the HLO-level
waste the pure-XLA fallback suffers — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int], block_q: int,
    block_k: int, sk_valid: int, q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * block_q + q_offset          # absolute position of q block
    k_lo = ik * block_k

    # block-level skip: any work in [k_lo, k_hi) for queries [q_lo, q_hi)?
    q_hi = q_lo + block_q - 1
    needed = k_lo <= q_hi if causal else True
    if window is not None:
        needed = jnp.logical_and(needed, (k_lo + block_k) > (q_lo - window + 1))
    needed = jnp.logical_and(needed, k_lo < sk_valid)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = q @ k.T                                    # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < sk_valid
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        logits = jnp.where(mask, logits, _NEG)
        m_prev, l_prev, acc = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc * corr + p @ v

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "q_offset"),
)
def flash_attention_pallas(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sqp = -(-sq // bq) * bq
    skp = -(-sk // bk) * bk
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    if skp != sk:
        k = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))

    kernel = functools.partial(
        _fa_kernel,
        scale=1.0 / (d**0.5),
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        sk_valid=sk,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sqp // bq, skp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec(
                (1, bk, 1, d), lambda ib, ih, iq, ik, rep=rep: (ib, ik, ih // rep, 0)
            ),
            pl.BlockSpec(
                (1, bk, 1, d), lambda ib, ih, iq, ik, rep=rep: (ib, ik, ih // rep, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sqp, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]

"""Pure-jnp oracles for every Pallas kernel in this package, plus the shared
morphology helpers (shift / dilate / erode) used by the application layer.

These are the correctness references: kernel tests sweep shapes/dtypes and
``assert_allclose`` against the functions here.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "neighbors",
    "shift2d",
    "dilate",
    "erode",
    "morph_reconstruct_ref",
    "attention_ref",
    "ssm_scan_ref",
]


def neighbors(conn: int) -> Tuple[Tuple[int, int], ...]:
    if conn == 4:
        return ((1, 0), (-1, 0), (0, 1), (0, -1))
    if conn == 8:
        return ((1, 0), (-1, 0), (0, 1), (0, -1), (1, 1), (1, -1), (-1, 1), (-1, -1))
    raise ValueError(f"connectivity must be 4 or 8, got {conn}")


def shift2d(x: jax.Array, dy: int, dx: int, fill) -> jax.Array:
    """Shift a 2D array by (dy, dx), filling vacated cells with ``fill``."""
    out = jnp.roll(x, (dy, dx), axis=(0, 1))
    if dy > 0:
        out = out.at[:dy, :].set(fill)
    elif dy < 0:
        out = out.at[dy:, :].set(fill)
    if dx > 0:
        out = out.at[:, :dx].set(fill)
    elif dx < 0:
        out = out.at[:, dx:].set(fill)
    return out


@functools.partial(jax.jit, static_argnames=("conn",))
def dilate(x: jax.Array, conn: int = 8) -> jax.Array:
    out = x
    for dy, dx in neighbors(conn):
        out = jnp.maximum(out, shift2d(x, dy, dx, -jnp.inf))
    return out


@functools.partial(jax.jit, static_argnames=("conn",))
def erode(x: jax.Array, conn: int = 8) -> jax.Array:
    out = x
    for dy, dx in neighbors(conn):
        out = jnp.minimum(out, shift2d(x, dy, dx, jnp.inf))
    return out


@functools.partial(jax.jit, static_argnames=("conn",))
def morph_reconstruct_ref(marker: jax.Array, mask: jax.Array, conn: int = 8) -> jax.Array:
    """Grayscale reconstruction by dilation, iterated to the global fixpoint.

    Invariants: marker ≤ mask is enforced on entry; the result r satisfies
    marker ≤ r ≤ mask and r is the largest such fixpoint of
    ``r = min(dilate(r), mask)``.
    """
    marker = jnp.minimum(marker.astype(jnp.float32), mask.astype(jnp.float32))
    mask = mask.astype(jnp.float32)

    def body(state):
        m, _ = state
        new = jnp.minimum(dilate(m, conn=conn), mask)
        return new, jnp.any(new != m)

    out, _ = jax.lax.while_loop(lambda s: s[1], body, (marker, jnp.bool_(True)))
    return out


# ---------------------------------------------------------------------------
# Attention oracle (for kernels/flash_attention.py)
# ---------------------------------------------------------------------------

def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Dense reference attention. Shapes: q (B, Sq, H, D); k/v (B, Sk, Hkv, D)
    with H a multiple of Hkv (GQA by repetition). ``window`` is a sliding
    window size (attend to keys within [i-window+1, i])."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # decode alignment
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    logits = jnp.where(m[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked linear-attention / SSM scan oracle (for kernels/ssm_scan.py)
# ---------------------------------------------------------------------------

def ssm_scan_ref(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array]:
    """Diagonal-gated linear recurrence (the common core of Mamba2 / RWKV6):

        h_t = a_t ⊙ h_{t-1} + b_t ⊗ x_t          (state: (N, P) per head)
        y_t = h_t^T · c_t

    Shapes: x (B, S, H, P) values; a (B, S, H) scalar-per-head decay (Mamba2)
    or (B, S, H, N) per-channel decay (RWKV6), in (0,1]; b/c (B, S, H, N)
    input/output projections; h (B, H, N, P). Returns (y, h_final).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if a.ndim == 3:
        a = jnp.broadcast_to(a[..., None], (bsz, s, h, n))
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), dtype=jnp.float32)

    def step(hprev, t):
        xt, at, bt, ct = t
        hnew = at[..., None] * hprev + bt[..., None] * xt[..., None, :]
        yt = jnp.einsum("bhnp,bhn->bhp", hnew, ct)
        return hnew, yt

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    hf, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hf


def ssm_scan_xla(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    h0: jax.Array | None = None, *, chunk: int = 64, unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked XLA implementation of the same recurrence — identical math to
    the Pallas kernel (matmul-heavy, log-space-stable), used as the non-TPU
    production path. Differentiable (pure jnp)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    per_channel = a.ndim == 4
    cdim = min(chunk, s)
    spad = -(-s // cdim) * cdim
    if spad != s:
        x = jnp.pad(x, ((0, 0), (0, spad - s), (0, 0), (0, 0)))
        pa = ((0, 0), (0, spad - s), (0, 0)) if not per_channel else (
            (0, 0), (0, spad - s), (0, 0), (0, 0))
        a = jnp.pad(a, pa, constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, spad - s), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, spad - s), (0, 0), (0, 0)))
    nch = spad // cdim
    resh = lambda t: jnp.moveaxis(
        t.reshape(bsz, nch, cdim, *t.shape[2:]).astype(jnp.float32), 1, 0
    )
    xc, ac, bc, cc = resh(x), resh(a), resh(b), resh(c)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    tri = jnp.tril(jnp.ones((cdim, cdim), bool))

    def body(hst, xs):
        xb, ab, bb, cb = xs  # (B, C, H, ...)
        la = jnp.log(jnp.maximum(ab, 1e-37))
        L = jnp.cumsum(la, axis=1)  # (B,C,H[,N]) non-increasing
        if per_channel:
            diff = L[:, :, None] - L[:, None]  # (B,C,C,H,N)
            w = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
            sti = jnp.einsum("btihn,bthn,bihn->bhti", w, cb, bb)
            Ln = L
        else:
            diff = L[:, :, None] - L[:, None]  # (B,C,C,H)
            w = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
            sti = jnp.einsum("btih,bthn,bihn->bhti", w, cb, bb)
            Ln = jnp.broadcast_to(L[..., None], (*L.shape, n))
        y = jnp.einsum("bhti,bihp->bthp", sti, xb)
        y = y + jnp.einsum("bthn,bhnp->bthp", cb * jnp.exp(Ln), hst)
        dlast = jnp.exp(Ln[:, -1][:, None] - Ln)  # (B,C,H,N) ≤ 1
        hnew = jnp.exp(Ln[:, -1])[..., None] * hst + jnp.einsum(
            "bthn,bthp->bhnp", bb * dlast, xb
        )
        return hnew, y

    hf, ys = jax.lax.scan(body, h0, (xc, ac, bc, cc), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, spad, h, p)[:, :s]
    return y.astype(x.dtype), hf


def ssm_scan_stub(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    h0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Analysis-mode stand-in: preserves shapes and data dependencies on all
    inputs with O(S) cost. The dry-run adds the closed-form cost of the real
    chunked algorithm (launch/hlo_analysis.ssm_scan_costs) in its place."""
    amean = (a if a.ndim == 4 else a[..., None]).mean(-1, keepdims=True)
    y = x * amean * b.mean(-1, keepdims=True) * c.mean(-1, keepdims=True)
    hf = b[:, -1, :, :, None] * x[:, -1, :, None, :]
    return y.astype(x.dtype), hf.astype(jnp.float32)

"""Jitted dispatching wrappers for the Pallas kernels.

Every wrapper picks the Pallas path on TPU backends and the pure-XLA
reference path elsewhere (this CPU container validates kernels via
``interpret=True`` in the tests; production runs lower the real kernels).
The choice is overridable per call for testing/benchmarking.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def morph_reconstruct(
    marker: jax.Array,
    mask: jax.Array,
    *,
    conn: int = 8,
    use_kernel: Optional[bool] = None,
    block: Tuple[int, int] = (256, 256),
    inner_iters: int = 8,
) -> jax.Array:
    """Morphological reconstruction by dilation (see kernels/morph_recon.py)."""
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        from repro.kernels.morph_recon import morph_reconstruct_pallas

        return morph_reconstruct_pallas(
            marker,
            mask,
            conn=conn,
            block=block,
            inner_iters=inner_iters,
            interpret=not _on_tpu(),
        )
    return kref.morph_reconstruct_ref(marker, mask, conn=conn)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_kernel: Optional[bool] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Blocked FlashAttention-2 (see kernels/flash_attention.py)."""
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(
            q,
            k,
            v,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            interpret=not _on_tpu(),
        )
    return kref.attention_ref(q, k, v, causal=causal, window=window)


def ssm_scan(
    x: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    h0: Optional[jax.Array] = None,
    *,
    use_kernel: Optional[bool] = None,
    chunk: int = 64,
    analysis: bool = False,
):
    """Chunked diagonal-gated linear recurrence (see kernels/ssm_scan.py).
    ``analysis=True`` swaps in a shape-preserving stub whose true cost the
    roofline harness adds in closed form (XLA cost analysis cannot see
    through the sequential chunk loop)."""
    if analysis:
        return kref.ssm_scan_stub(x, a, b, c, h0)
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        from repro.kernels.ssm_scan import ssm_scan_pallas

        return ssm_scan_pallas(x, a, b, c, h0, chunk=chunk, interpret=not _on_tpu())
    return kref.ssm_scan_xla(x, a, b, c, h0, chunk=chunk)

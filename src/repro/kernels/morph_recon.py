"""Pallas TPU kernel for morphological reconstruction by dilation — the
propagation hot-spot of the paper's segmentation stage (it also powers
fill-holes and the watershed flooding).

TPU adaptation (DESIGN.md §2/§8): the CPU/GPU algorithms use irregular
wavefront queues, which do not map to the MXU/VPU. Instead we tile the image
into VMEM-resident blocks and run *many local sweeps per block per kernel
launch* (raster + anti-raster, the classic two-pass SE decomposition), so the
bulk of the propagation happens at VMEM bandwidth; a cheap global dilate-min
step between launches carries wavefronts across tile boundaries, and an outer
``while_loop`` iterates to the global fixpoint. Convergence is exact — the
fixpoint test is on the full image.

Blocks default to 256×256 fp32 (256 KiB/buffer; marker+mask+out ≈ 768 KiB of
VMEM, well under the ~16 MiB/core budget), and both block dims are multiples
of the 8×128 VPU tile.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_FILL = -3.0e38  # acts as -inf for propagation fills (plain float: kernels
# must not capture traced constants)


def _shift_block(x: jax.Array, dy: int, dx: int) -> jax.Array:
    """Static-shape shift with -inf fill, using concat (TPU-lowerable)."""
    h, w = x.shape
    if dy == 1:
        x = jnp.concatenate([jnp.full((1, w), _FILL, x.dtype), x[:-1]], axis=0)
    elif dy == -1:
        x = jnp.concatenate([x[1:], jnp.full((1, w), _FILL, x.dtype)], axis=0)
    if dx == 1:
        x = jnp.concatenate([jnp.full((h, 1), _FILL, x.dtype), x[:, :-1]], axis=1)
    elif dx == -1:
        x = jnp.concatenate([x[:, 1:], jnp.full((h, 1), _FILL, x.dtype)], axis=1)
    return x


def _neighbors(conn: int) -> Tuple[Tuple[int, int], ...]:
    if conn == 4:
        return ((1, 0), (-1, 0), (0, 1), (0, -1))
    return ((1, 0), (-1, 0), (0, 1), (0, -1), (1, 1), (1, -1), (-1, 1), (-1, -1))


def _recon_sweep_kernel(marker_ref, mask_ref, out_ref, *, conn: int, inner_iters: int):
    """``inner_iters`` local dilate-min sweeps over one VMEM block."""
    m = marker_ref[...]
    mk = mask_ref[...]

    def body(_, m):
        d = m
        for dy, dx in _neighbors(conn):
            d = jnp.maximum(d, _shift_block(m, dy, dx))
        return jnp.minimum(d, mk)

    out_ref[...] = jax.lax.fori_loop(0, inner_iters, body, m)


@functools.partial(
    jax.jit, static_argnames=("conn", "block", "inner_iters", "interpret")
)
def tile_sweep(
    marker: jax.Array,
    mask: jax.Array,
    *,
    conn: int = 8,
    block: Tuple[int, int] = (256, 256),
    inner_iters: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """One kernel launch: every block independently runs ``inner_iters``
    local reconstruction sweeps. Pads to block multiples with -inf marker /
    -inf mask so padding can never propagate into the image."""
    h, w = marker.shape
    bh = min(block[0], max(8, h))
    bw = min(block[1], max(128, w)) if w >= 128 else w
    hp = -(-h // bh) * bh
    wp = -(-w // bw) * bw
    mk = jnp.pad(marker.astype(jnp.float32), ((0, hp - h), (0, wp - w)), constant_values=float(_FILL))
    ms = jnp.pad(mask.astype(jnp.float32), ((0, hp - h), (0, wp - w)), constant_values=float(_FILL))
    out = pl.pallas_call(
        functools.partial(_recon_sweep_kernel, conn=conn, inner_iters=inner_iters),
        grid=(hp // bh, wp // bw),
        in_specs=[
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((hp, wp), jnp.float32),
        interpret=interpret,
    )(mk, ms)
    return out[:h, :w]


@functools.partial(
    jax.jit, static_argnames=("conn", "block", "inner_iters", "interpret")
)
def morph_reconstruct_pallas(
    marker: jax.Array,
    mask: jax.Array,
    *,
    conn: int = 8,
    block: Tuple[int, int] = (256, 256),
    inner_iters: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Full reconstruction to the global fixpoint (kernel sweeps + cross-tile
    exchange). Matches ``ref.morph_reconstruct_ref`` exactly."""
    from repro.kernels import ref as kref

    marker = jnp.minimum(marker.astype(jnp.float32), mask.astype(jnp.float32))
    mask = mask.astype(jnp.float32)

    def body(state):
        m, _ = state
        m1 = tile_sweep(
            m, mask, conn=conn, block=block, inner_iters=inner_iters, interpret=interpret
        )
        m2 = jnp.minimum(kref.dilate(m1, conn=conn), mask)  # cross-tile carry
        return m2, jnp.any(m2 != m)

    out, _ = jax.lax.while_loop(lambda s: s[1], body, (marker, jnp.bool_(True)))
    return out

"""Pallas TPU kernels for the compute hot-spots, with jnp oracles in ref.py
and dispatching wrappers in ops.py.

* ``morph_recon``      — tiled morphological reconstruction (the paper's
                         segmentation propagation hot-spot).
* ``flash_attention``  — blocked FlashAttention-2 (causal + sliding window +
                         GQA) for the LM prefill path.
* ``ssm_scan``         — chunked diagonal-gated linear recurrence for the
                         Mamba2 / RWKV6 architectures.
"""

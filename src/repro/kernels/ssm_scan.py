"""Pallas TPU kernel: chunked diagonal-gated linear recurrence (Mamba2 SSD /
RWKV6 core).

TPU adaptation: the token-recurrent form is VPU-serial; the chunked form
rewrites it as dense matmuls (MXU work) with a tiny cross-chunk carry:

  within a chunk (length C), with L_t = Σ_{i≤t} log a_i (L decreasing):
    y_intra[t] = Σ_{i≤t} (c_t · (exp(L_t − L_i) ⊙ b_i)) x_i   — masked matmul
    y_carry[t] = (c_t ⊙ exp(L_t)) · h_prev
    h_next     = exp(L_C) ⊙ h_prev + Σ_i (exp(L_C − L_i) ⊙ b_i) ⊗ x_i

  Every exponent is ≤ 0 (decays ≤ 1), so the log-space form is
  underflow-safe — no division by vanishing cumulative decays.

Grid: (B·H parallel, S/C sequential); the (N, P) fp32 state lives in VMEM
scratch across the sequential chunk dimension. Default C=64, N,P ≤ 128 keeps
every block well inside VMEM (the (C, C, N) intra tensor is the largest at
~1 MiB fp32).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # (C, P)
    a = a_ref[0].astype(jnp.float32)  # (C, N)
    b = b_ref[0].astype(jnp.float32)  # (C, N)
    c = c_ref[0].astype(jnp.float32)  # (C, N)
    cdim = x.shape[0]

    la = jnp.log(jnp.maximum(a, 1e-37))
    L = jnp.cumsum(la, axis=0)  # (C, N), non-increasing
    # intra-chunk: w[t, i, n] = exp(L_t - L_i) for t >= i
    diff = L[:, None, :] - L[None, :, :]  # (C, C, N), ≤ 0 on the lower tri
    tri = (jnp.arange(cdim)[:, None] >= jnp.arange(cdim)[None, :])[..., None]
    w = jnp.where(tri, jnp.exp(diff), 0.0)
    s = jnp.einsum("tin,tn,in->ti", w, c, b)  # (C, C)
    y = s @ x  # (C, P)
    # carry-in from previous chunks
    h = h_scr[...]
    y += (c * jnp.exp(L)) @ h  # (C,N)@(N,P)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    decay_last = jnp.exp(L[-1][None, :] - L)  # (C, N), ≤ 1
    h_new = jnp.exp(L[-1])[:, None] * h + (b * decay_last).T @ x
    h_scr[...] = h_new

    @pl.when(j == nj - 1)
    def _emit():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_pallas(
    x: jax.Array,                      # (B, S, H, P)
    a: jax.Array,                      # (B, S, H) or (B, S, H, N)
    b: jax.Array,                      # (B, S, H, N)
    c: jax.Array,                      # (B, S, H, N)
    h0: Optional[jax.Array] = None,    # must be None/zeros (kernel owns state)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if a.ndim == 3:
        a = jnp.broadcast_to(a[..., None], (bsz, s, h, n))
    if h0 is not None:
        # Kernel owns the state across chunks; non-zero h0 is folded in by
        # the wrapper via a virtual first chunk — unsupported here.
        raise NotImplementedError("ssm_scan_pallas requires h0=None (zeros)")
    cdim = min(chunk, s)
    spad = -(-s // cdim) * cdim
    if spad != s:
        # pad with a=1 (no decay), b=0 (no input) so padding is inert
        x = jnp.pad(x, ((0, 0), (0, spad - s), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, spad - s), (0, 0), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, spad - s), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, spad - s), (0, 0), (0, 0)))

    # (B, S, H, ·) -> (B·H, S, ·)
    def fold(t):
        return jnp.moveaxis(t, 2, 1).reshape(bsz * h, spad, t.shape[-1])

    xf, af, bf, cf = fold(x), fold(a), fold(b), fold(c)
    nchunks = spad // cdim

    y, hout = pl.pallas_call(
        _ssm_chunk_kernel,
        grid=(bsz * h, nchunks),
        in_specs=[
            pl.BlockSpec((1, cdim, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cdim, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cdim, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cdim, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cdim, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, spad, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xf, af, bf, cf)

    y = jnp.moveaxis(y.reshape(bsz, h, spad, p), 1, 2)[:, :s]
    hfinal = hout.reshape(bsz, h, n, p)
    return y, hfinal

"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --steps 20 \
        [--reduced] [--microbatches N] [--ckpt-dir DIR] [--mesh single|multi|none]

On this CPU container use --reduced (full configs need the 256/512-chip
meshes; the dry-run proves those compile). XLA latency-hiding/overlap flags
for real TPU runs are recorded below and applied when backend == tpu.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

# Collective/compute overlap knobs for real TPU deployments (no-ops on CPU).
_TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true"
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    import jax

    if jax.default_backend() == "tpu" and "xla_tpu" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + _TPU_XLA_FLAGS
        )

    import jax.numpy as jnp

    from repro.checkpoint import Checkpointer
    from repro.configs import SHAPES, get_config, reduced_config
    from repro.data import TokenPipeline
    from repro.dist.sharding import make_ctx, param_shardings
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim import OptConfig, adamw_init

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = dataclasses.replace(
        SHAPES["train_4k"], seq_len=args.seq, global_batch=args.batch
    )
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    ctx = make_ctx(mesh, mode="train") if mesh else None

    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    pipe = TokenPipeline(cfg, shape, seed=0)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore((params, opt_state))
        pipe.restore(meta["pipeline"])
        start = pipe.step
        print(f"[train] resumed at step {start}")

    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps)
    step_fn = make_train_step(cfg, ctx, opt_cfg, microbatches=args.microbatches)
    if mesh is not None:
        sh = param_shardings(jax.eval_shape(lambda: params), ctx)
        jitted = jax.jit(step_fn, in_shardings=(sh, None, None), out_shardings=(sh, None, None))
    else:
        jitted = jax.jit(step_fn)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = jitted(params, opt_state, batch)
        pipe.step = step + 1
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, (params, opt_state), metadata={"pipeline": pipe.state()})
        if step % 5 == 0 or step + 1 == args.steps:
            print(
                f"[train] step {step} loss {float(metrics['loss']):.4f} "
                f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)"
            )
    if ckpt:
        ckpt.save(args.steps, (params, opt_state), metadata={"pipeline": pipe.state()})
        ckpt.wait()


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input — shared by the dry-run,
the roofline harness and the AOT tests. Weak-type-correct, shardable, no
device allocation."""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_cache, init_params

__all__ = ["input_specs", "params_specs", "cache_specs"]

S = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one step of the given kind (train/prefill/decode)."""
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frame_embeds": S((b, s, cfg.d_model), bf16),
                "labels": S((b, s, cfg.num_codebooks), i32),
            }
        if cfg.family == "vlm":
            st = s - cfg.num_patches
            return {
                "patch_embeds": S((b, cfg.num_patches, cfg.d_model), bf16),
                "tokens": S((b, st), i32),
                "labels": S((b, st), i32),
            }
        return {"tokens": S((b, s), i32), "labels": S((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frame_embeds": S((b, s, cfg.d_model), bf16)}
        if cfg.family == "vlm":
            return {
                "patch_embeds": S((b, cfg.num_patches, cfg.d_model), bf16),
                "tokens": S((b, s - cfg.num_patches), i32),
            }
        return {"tokens": S((b, s), i32)}
    # decode: one new token against a seq_len cache
    if cfg.family == "audio":
        return {"frame_embeds": S((b, 1, cfg.d_model), bf16)}
    return {"tokens": S((b, 1), i32)}


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, max_len=shape.seq_len)
    )

"""Roofline-term extraction from compiled AOT artifacts.

``cost_analysis()`` provides per-device HLO FLOPs and bytes; collective bytes
are NOT included there, so we parse the post-SPMD HLO text and sum the result
shapes of every collective op. Shapes in the partitioned module are already
per-device, so wire-bytes-per-chip = result_bytes × multiplier, where the
multiplier accounts for the algorithm (ring all-reduce moves ~2× the payload;
all-gather/reduce-scatter/all-to-all/permute ~1×).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")

_MULTIPLIER = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, from post-SPMD HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _MULTIPLIER}
    count: Dict[str, int] = {k: 0 for k in _MULTIPLIER}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_shapes, single_shape, kind = m.group(1), m.group(2), m.group(3)
        shape_str = tuple_shapes if tuple_shapes else single_shape
        out[kind] += _shape_bytes(shape_str) * _MULTIPLIER[kind]
        count[kind] += 1
    out["total"] = sum(out[k] for k in _MULTIPLIER)
    out["ops"] = sum(count.values())
    out.update({f"n_{k}": count[k] for k in count})
    return out


def roofline_terms(
    cost: Dict[str, float], coll: Dict[str, float], n_chips: int
) -> Dict[str, float]:
    """Three roofline terms in seconds (per step, per chip — the SPMD program
    is identical on every chip, so per-chip latency == step latency)."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cterms = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": bytes_acc / HW["hbm_bw"],
        "collective_s": coll["total"] / HW["ici_bw"],
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll["total"],
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: cterms[k])
    cterms["dominant"] = dom
    denom = max(cterms["compute_s"], cterms["memory_s"], cterms["collective_s"])
    cterms["roofline_fraction_compute"] = (
        cterms["compute_s"] / denom if denom > 0 else 0.0
    )
    return cterms


def ssm_scan_costs(cfg, shape) -> Dict[str, float]:
    """Closed-form FLOPs/bytes of the chunked SSM scan (kernels/ssm_scan.py
    algorithm) for the whole model — GLOBAL totals. The dry-run's analysis
    compiles stub this scan out (XLA cost analysis cannot see through its
    sequential chunk loop), so its true cost is added back here.

    Only train/prefill shapes invoke the scan (decode updates state
    directly). Train counts fwd + remat-fwd + bwd ≈ 4× fwd FLOPs.
    """
    if cfg.family not in ("ssm", "hybrid") or shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    b, s = shape.global_batch, shape.seq_len
    h = cfg.ssm_heads
    n = cfg.ssm_state if not cfg.rwkv else cfg.ssm_head_dim
    p = cfg.ssm_head_dim
    chunk = 64
    nch = -(-s // chunk)
    c = chunk
    per_channel = cfg.rwkv
    if per_channel:
        per_chunk_flops = 5 * c * c * n + 2 * c * c * p + 4 * c * n * p + 6 * c * n
    else:
        per_chunk_flops = 2 * c * c * n + c * c + 2 * c * c * p + 4 * c * n * p + 6 * c * n
    per_chunk_bytes = (4 * c * p + 3 * c * n + 2 * n * p) * 4
    n_layers = cfg.num_layers  # all layers carry the scan in ssm/hybrid
    factor = 4.0 if shape.kind == "train" else 1.0
    total_flops = per_chunk_flops * nch * b * h * n_layers * factor
    total_bytes = per_chunk_bytes * nch * b * h * n_layers * min(factor, 3.0)
    return {"flops": float(total_flops), "bytes": float(total_bytes)}


def model_flops(cfg, shape, n_chips: int) -> float:
    """Idealized model FLOPs per step (GLOBAL, all chips): 6·N_active·D for
    training, 2·N_active·D for prefill, 2·N_active·B (+ attention cache
    reads) for decode."""
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_active * b * s
        attn = 0.0
        if cfg.family not in ("ssm",):
            windows = cfg.layer_windows(s)
            per_layer = [min(w, s) for w in windows]
            attn = sum(
                6.0 * 2.0 * b * s * w * cfg.num_heads * cfg.head_dim * 0.5
                for w in per_layer
            )
        return base + attn
    if shape.kind == "prefill":
        base = 2.0 * n_active * b * s
        attn = 0.0
        if cfg.family != "ssm":
            windows = cfg.layer_windows(s)
            attn = sum(
                2.0 * 2.0 * b * s * min(w, s) * cfg.num_heads * cfg.head_dim * 0.5
                for w in windows
            )
        return base + attn
    # decode: one token per sequence
    base = 2.0 * n_active * b
    attn = 0.0
    if cfg.family != "ssm":
        windows = cfg.layer_windows(s)
        attn = sum(
            2.0 * 2.0 * b * min(w, s) * cfg.num_heads * cfg.head_dim for w in windows
        )
    return base + attn

"""Step factories: the jit-able train / prefill / decode step functions that
the launcher, the dry-run and the benchmarks all share."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParallelCtx
from repro.models import decode_step, forward_train, prefill
from repro.optim import OptConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "make_decode_step", "make_prefill_step", "cast_for_compute"]


def cast_for_compute(params, enable: bool = True):
    """Cast fp32 master weight matrices to bf16 *before* the FSDP gathers —
    the cast commutes with the at-rest sharding, so every weight all-gather
    moves half the bytes (EXPERIMENTS.md §Perf it.2). Rank-<2 leaves (norms,
    biases, decay vectors) stay fp32 for numerics."""
    if not enable:
        return params
    return jax.tree.map(
        lambda w: w.astype(jnp.bfloat16)
        if (w.ndim >= 2 and w.dtype == jnp.float32)
        else w,
        params,
    )


def make_train_step(cfg: ModelConfig, ctx: Optional[ParallelCtx], opt_cfg: OptConfig,
                    *, cast_before_gather: bool = True, microbatches: int = 1):
    """``microbatches`` > 1 enables gradient accumulation: the global batch is
    split on the batch axis and scanned, dividing activation memory by the
    microbatch count at the cost of repeating the per-layer weight gathers —
    how the big train cells fit 16 GB HBM (EXPERIMENTS.md §Perf it.5)."""

    def loss_fn(p, batch):
        return forward_train(cfg, cast_for_compute(p, cast_before_gather), batch, ctx)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mb = {
                k: v.reshape(microbatches, v.shape[0] // microbatches, *v.shape[1:])
                for k, v in batch.items()
            }

            def acc(carry, mbatch):
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                return (
                    carry[0] + l / microbatches,
                    jax.tree.map(lambda a, b: a + b / microbatches, carry[1], g),
                ), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
            )
            unroll = bool(ctx is not None and getattr(ctx, "analysis", False))
            (loss, grads), _ = jax.lax.scan(acc, zero, mb, unroll=unroll)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_decode_step(cfg: ModelConfig, ctx: Optional[ParallelCtx],
                     *, cast_before_gather: bool = True):
    def serve_step(params, cache, batch, cur_len):
        logits, cache = decode_step(
            cfg, cast_for_compute(params, cast_before_gather), batch, cache, cur_len, ctx
        )
        if cfg.family == "audio":
            nxt = jnp.argmax(
                logits.reshape(logits.shape[0], cfg.num_codebooks, -1), axis=-1
            ).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ParallelCtx], max_len: int,
                      *, cast_before_gather: bool = True):
    def prefill_step(params, batch):
        logits, cache, length = prefill(
            cfg, cast_for_compute(params, cast_before_gather), batch,
            max_len=max_len, ctx=ctx,
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return prefill_step

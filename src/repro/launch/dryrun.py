import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell and record memory / cost / collective analysis.

Run:  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
          [--mesh single|multi|both] [--out experiments/dryrun.json]

The two XLA_FLAGS lines above MUST stay the first statements of this module:
jax locks the device count at first init, and the production meshes need 512
placeholder host devices. Smoke tests and benchmarks never import this
module, so they keep seeing 1 device.
"""

import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, supports_long_context
from repro.dist.sharding import (
    cache_shardings,
    input_shardings,
    make_ctx,
    param_shardings,
)
from repro.launch.hlo_analysis import (
    HW,
    collective_bytes,
    model_flops,
    roofline_terms,
    ssm_scan_costs,
)
from repro.launch.inputs import cache_specs, input_specs, params_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.optim import OptConfig, adamw_init


def _opt_specs(params_sds):
    return jax.eval_shape(adamw_init, params_sds)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               cfg=None, analysis: bool = False):
    """Build and lower one cell; returns (lowered, n_chips, aux)."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mode = "train" if shape.kind == "train" else "serve"
    ctx = make_ctx(mesh, mode=mode)
    if analysis:
        ctx = dataclasses.replace(ctx, analysis=True)

    p_sds = params_specs(cfg)
    p_sh = param_shardings(p_sds, ctx)
    in_sds = input_specs(cfg, shape)
    in_sp = input_shardings(cfg, shape, ctx)
    in_sh = {k: NamedSharding(mesh, v) for k, v in in_sp.items()}

    with mesh:
        if shape.kind == "train":
            opt_sds = _opt_specs(p_sds)
            opt_sh = jax.tree.map(
                lambda s, x: s if x.ndim > 0 else NamedSharding(mesh, P()),
                param_shardings(opt_sds, ctx), opt_sds,
            )
            step = make_train_step(
                cfg, ctx, OptConfig(), microbatches=int(os.environ.get("REPRO_MICROBATCHES", "1"))
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, in_sh),
                out_shardings=(p_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_sds, opt_sds, in_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx, max_len=shape.seq_len)
            c_sh_fn = cache_shardings(cfg, shape, ctx)
            c_sds = cache_specs(cfg, shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, in_sh),
                out_shardings=(None, c_sh_fn(c_sds)),
            )
            lowered = jitted.lower(p_sds, in_sds)
        else:  # decode
            step = make_decode_step(cfg, ctx)
            c_sds = cache_specs(cfg, shape)
            c_sh = cache_shardings(cfg, shape, ctx)(c_sds)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, in_sh, NamedSharding(mesh, P())),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                p_sds, c_sds, in_sds, jax.ShapeDtypeStruct((), jnp.int32)
            )
    return lowered, n_chips, (cfg, shape)


def _pattern_period(cfg) -> int:
    return cfg.global_every or cfg.attn_every or 1


def analysis_terms(arch: str, shape_name: str, multi_pod: bool, n_chips: int):
    """Roofline terms from depth-p and depth-2p ANALYSIS compiles (fully
    unrolled scans so cost analysis sees every iteration), scaled to the real
    depth; plus the closed-form SSM-scan term (see ssm_scan_costs)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p = _pattern_period(cfg)
    units_real = cfg.num_layers / p
    pts = []
    for units in (1, 2):
        cfg_small = dataclasses.replace(cfg, num_layers=p * units)
        lowered, _, _ = lower_cell(
            arch, shape_name, multi_pod, cfg=cfg_small, analysis=True
        )
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        pts.append(
            (
                float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                float(coll["total"]),
            )
        )
    scaled = [a + (b - a) * (units_real - 1.0) for a, b in zip(pts[0], pts[1])]
    corr = ssm_scan_costs(cfg, shape)
    scaled[0] += corr["flops"] / n_chips
    scaled[1] += corr["bytes"] / n_chips
    cost = {"flops": scaled[0], "bytes accessed": scaled[1]}
    coll = {"total": scaled[2]}
    return roofline_terms(cost, coll, n_chips)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    cfg = get_config(arch)
    if shape_name == "long_500k" and not supports_long_context(cfg):
        rec["status"] = "skip(full-attn)"
        return rec
    t0 = time.time()
    try:
        lowered, n_chips, (cfg, shape) = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        if multi_pod:
            # multi-pod pass proves the 'pod' axis shards; the roofline table
            # (§Roofline) is single-pod only, so skip the analysis compiles
            terms = roofline_terms(cost, coll, n_chips)
            terms["analysis"] = "raw(loop-bodies-once)"
        else:
            terms = analysis_terms(arch, shape_name, multi_pod, n_chips)
            terms["analysis"] = "depth-scaled"
        mf = model_flops(cfg, shape, n_chips)
        hlo_global_flops = terms["hlo_flops_per_chip"] * n_chips
        rec.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            bytes_per_device=int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            collectives={
                k: v for k, v in coll.items() if k.startswith("n_") or k == "total"
            },
            **{k: v for k, v in terms.items()},
            model_flops_global=mf,
            useful_flops_ratio=(mf / hlo_global_flops) if hlo_global_flops else 0.0,
        )
    except Exception as e:  # noqa: BLE001 — record, keep sweeping
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = []
    if out_path.exists():
        records = json.loads(out_path.read_text())

    done = {(r["arch"], r["shape"], r["mesh"]) for r in records if r.get("status", "").startswith(("ok", "skip"))}
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                rec = run_cell(arch, shape, mp)
                print(
                    f"[dryrun] {key} -> {rec['status']}"
                    + (
                        f" compute={rec['compute_s']:.4f}s memory={rec['memory_s']:.4f}s"
                        f" coll={rec['collective_s']:.4f}s dom={rec['dominant']}"
                        f" bytes/dev={rec['bytes_per_device']/1e9:.2f}GB"
                        if rec["status"] == "ok"
                        else ""
                    ),
                    flush=True,
                )
                records = [r for r in records if (r["arch"], r["shape"], r["mesh"]) != key]
                records.append(rec)
                out_path.write_text(json.dumps(records, indent=1))
    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"].startswith("skip"))
    n_fail = len(records) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail")


if __name__ == "__main__":
    main()

"""Launch layer: meshes, step factories, dry-run, trainer and server."""

"""Serving launcher: batched prefill + decode with the reuse-aware SA-serve
path as an option.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b --reduced \
        [--batch 2] [--prompt-len 16] [--gen 12] [--sa-reuse]
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--sa-reuse", action="store_true",
                    help="run the reuse-tree SA-serve study instead of plain decode")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced_config
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import init_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    if args.sa_reuse:
        import itertools

        from repro.core.sa_serve import run_sa_serve

        prompts = {
            pid: rng.integers(0, cfg.vocab_size, (1, args.prompt_len)).astype(np.int32)
            for pid in range(2)
        }
        sets = [
            tuple(sorted({"prompt_id": p, "rep_penalty": rp, "top_k": 8,
                          "threshold": th}.items()))
            for p, rp, th in itertools.product(range(2), (1.0, 1.2), (0.2, 0.4))
        ]
        out = run_sa_serve(cfg, params, prompts, sets, gen_len=args.gen,
                           max_len=args.prompt_len + args.gen + 4)
        print(f"[serve] SA-reuse: {out['tasks_executed']}/{out['tasks_total']} tasks "
              f"({out['reuse_fraction']*100:.0f}% reuse), "
              f"accept rates {list(out['accept_rate'].values())[:4]}")
        return

    max_len = args.prompt_len + args.gen
    toks = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    prefill_fn = jax.jit(make_prefill_step(cfg, None, max_len=max_len))
    decode_fn = jax.jit(make_decode_step(cfg, None))
    t0 = time.time()
    nxt, cache = prefill_fn(params, {"tokens": jnp.asarray(toks)})
    outs = [nxt]
    for i in range(args.gen - 1):
        nxt, cache = decode_fn(params, cache, {"tokens": nxt}, jnp.int32(args.prompt_len + i))
        outs.append(nxt)
    gen = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    print(f"[serve] generated {gen.shape} in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s incl. compile)")
    print(np.asarray(gen)[:, :10])


if __name__ == "__main__":
    main()

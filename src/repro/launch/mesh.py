"""Production meshes.

Defined as FUNCTIONS (importing this module never touches jax device state).
The production pod is 16×16 = 256 chips (TPU v5e pod); multi-pod adds a
leading 'pod' axis (2 × 256 = 512 chips). When the process exposes more
devices than a mesh needs (the dry-run forces 512 host devices), the first
``prod(shape)`` devices are used.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh_from_devices"]


def make_mesh_from_devices(
    shape: Tuple[int, ...], axes: Tuple[str, ...], devices: Optional[Sequence] = None
) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    need = int(np.prod(shape))
    if len(devices) < need:
        raise ValueError(
            f"mesh {shape} needs {need} devices, only {len(devices)} available "
            "(the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import)"
        )
    arr = np.array(devices[:need]).reshape(shape)
    return Mesh(arr, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if len(jax.devices()) == int(np.prod(shape)):
        return jax.make_mesh(shape, axes)
    return make_mesh_from_devices(shape, axes)

"""Fleet-of-K StudyDrivers pooling one SharedStore vs single-process and
vs K independent studies (DESIGN.md §12) — ``BENCH_fleet.json``.

The cross-process payoff the related work leans on (1811.11653 §V runs SA
executors over a shared reuse pool at 256 nodes): one adaptive study's
per-round run-list sharded across K worker *processes*, all mounting the
same crash-safe SharedStore directory, with round N+1 planned against the
union of every process's committed keys.

Reported / asserted:

* **fleet == single, bit-identically** — objectives, SA indices and
  decisions per round are equal (tasks are pure; sharding is invisible);
* **fleet < K independent** — combined tasks executed across the fleet are
  strictly fewer than K processes each running the study alone (the pooled
  store turns K−1 of every shared prefix into rehydrations);
* **zero corrupt reads** — the atomic-write + verify + quarantine protocol
  under real multi-process traffic.
"""

from __future__ import annotations

import time
from typing import List

from repro.app.pipeline import pathology_fleet_build
from repro.core.metrics import reuse_factor
from repro.study import StudyDriver, run_fleet_study

from benchmarks.common import SMOKE

N_PROCS = 2

SPACE_DICT = {
    "B": [210, 220, 230], "G": [210, 220, 230], "R": [210, 220, 230],
    "T1": [2.5, 5.0, 7.5], "T2": [2.5, 5.0, 7.5],
    "G1": [20, 40, 60], "G2": [10, 20, 30],
    "minS": [2, 10, 20], "maxS": [900, 1200, 1500],
    "minSPL": [5, 20, 40], "minSS": [2, 10, 20], "maxSS": [900, 1200, 1500],
    "FH": [4, 8], "RC": [4, 8], "WConn": [4, 8],
}


def run(csv: List[str]) -> None:
    import tempfile

    size = 24 if SMOKE else 48
    max_rounds = 2 if SMOKE else 3
    seed = 11
    build_kwargs = {
        "size": size,
        "n_tiles": 1,
        "seed": seed,
        "space_dict": SPACE_DICT,
    }

    # ---------------- single-process reference ---------------------------
    spec = pathology_fleet_build(**build_kwargs)
    t0 = time.perf_counter()
    driver = StudyDriver(
        spec["workflow"], spec["space"], spec["inputs"],
        objective=spec["objective"], seed=seed, n_boot=8,
        input_keys=spec.get("input_keys"),
    )
    try:
        single = driver.run(max_rounds=max_rounds)
    finally:
        driver.close()
    t_single = time.perf_counter() - t0
    single_tasks = single.tasks_executed

    # ---------------- fleet of N_PROCS over one SharedStore --------------
    t0 = time.perf_counter()
    fleet_state, fleet = run_fleet_study(
        pathology_fleet_build,
        build_kwargs,
        n_procs=N_PROCS,
        store_dir=tempfile.mkdtemp(prefix="rtf_fleet_bench_"),
        max_rounds=max_rounds,
        seed=seed,
        n_boot=8,
    )
    t_fleet = time.perf_counter() - t0
    fleet_tasks = fleet["tasks_executed"]

    # bit-identical science: objectives, indices, decisions per round
    assert fleet_state.evaluated == single.evaluated, (
        "fleet sharding changed an objective value"
    )
    assert len(fleet_state.rounds) == len(single.rounds)
    for fr, sr in zip(fleet_state.rounds, single.rounds):
        assert fr.outputs == sr.outputs, f"round {fr.index} outputs differ"
        assert fr.analysis == sr.analysis, f"round {fr.index} indices differ"
    # crash-safety under real multi-process traffic
    assert fleet["corrupt"] == 0, f"corrupt store reads: {fleet['corrupt']}"
    # strictly fewer combined tasks than N_PROCS independent studies
    independent_tasks = N_PROCS * single_tasks
    assert fleet_tasks < independent_tasks, (
        f"fleet ({fleet_tasks}) must beat {N_PROCS} independent studies "
        f"({independent_tasks})"
    )

    rf = reuse_factor(fleet_tasks, fleet_state.tasks_requested)
    csv.append(
        f"fleet_study_{N_PROCS}proc,{t_fleet*1e6:.0f},"
        f"rounds={len(fleet_state.rounds)}_tasks={fleet_tasks}"
        f"_reuse_factor={rf:.2f}x"
        f"_rehydrations={fleet['store_disk_hits']}"
        f"_dedup_writes={fleet['dedup_writes']}"
        f"_corrupt={fleet['corrupt']}"
    )
    csv.append(
        f"fleet_single_reference,{t_single*1e6:.0f},"
        f"tasks={single_tasks}"
        f"_independent_x{N_PROCS}={independent_tasks}"
        f"_fleet_saves={independent_tasks - fleet_tasks}tasks"
    )

"""Socket vs process WorkerBackend on the streaming workload, over the
object-store tier (DESIGN.md §16) — ``BENCH_net.json``.

The multi-host control plane's cost model on loopback: the same hybrid
plan over the same tiles executed through (a) the in-process
:class:`ThreadBackend`, (b) the all-flags :class:`ProcessRpcBackend` (the
single-host shipping default: pipes + shared-memory handoff), and (c) a
:class:`SocketBackend` fleet — ≥2 worker processes joining by TCP against
an ``obj:<root>`` store, i.e. NO shared working directory beyond the
store root, and no shm route (results cross as inline payloads or store
keys). The socket row reports its wall-time ratio against both, plus the
**per-frame overhead**: the socket-minus-thread wall-time delta divided by
the control frames the leader actually moved (lease frames + completion
batches + heartbeats observed), the figure a deployment multiplies by its
own RTT.

A final fault row replays the ISSUE-8 acceptance scenario at benchmark
scale: a 3-worker fleet loses one worker to SIGKILL and a second to a cut
TCP connection mid-lease, finishes every task with exactly-once callbacks,
and the surviving session then runs the full study — bit-identical to the
thread oracle. The row records the degraded-session study wall time.

Asserted:

* **bit-identical outputs** — every mask from every socket session equals
  the thread backend's, per tile per run (frames and object entries are a
  transport, never an approximation);
* **real dispatch** — socket sessions route every bucket through the
  socket backend;
* **exactly-once** — in the fault scenario every callback fires once
  despite a kill and a partition;
* **the ratio gate** — the loopback socket fleet must hold within
  ``MAX_RATIO`` of thread wall time; a regression raises, the harness
  exits non-zero, and CI's ``net-smoke`` guard step fails the job.
"""

from __future__ import annotations

import os
import pathlib
import shutil
import signal
import tempfile
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, pathology_rpc_build
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.runtime import Manager, ProcessRpcBackend, SocketBackend, WorkItem
from repro.runtime.transport import process_flag_kwargs

from benchmarks.common import SMOKE, moat_param_sets

N_WORKERS = 2
MAX_RATIO = 6.0  # gate: loopback socket fleet (obj store) vs thread.
# Wider than rpc.py's 2× because the socket row pays for everything the
# multi-host design gives up on purpose: no shm handoff, sha256-etag
# object writes, and smoke-profile tasks small enough that per-frame
# latency dominates (observed ~3× on loopback smoke; the gate catches
# step regressions, not noise).
WARMUP_PASSES = 2


def _quick_task(tag):
    return f"q-{tag}"


def _hang_until_killed(marker_dir):
    marker = pathlib.Path(marker_dir) / "kill_pid"
    if not marker.exists():
        # write-then-rename: the reader polls for existence, so the pid
        # must be complete the instant the path appears
        tmp = marker.with_suffix(".tmp")
        tmp.write_text(str(os.getpid()))
        os.replace(tmp, marker)
        time.sleep(60.0)
        return "hung"
    return "fast"


def _slow_first(marker_dir):
    marker = pathlib.Path(marker_dir) / "slow"
    try:
        fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        os.close(fd)
    except FileExistsError:
        return "done"
    time.sleep(2.0)
    return "done"


def _assert_identical(stream, thread_stream, n_tiles: int, n_runs: int,
                      label: str) -> None:
    for i in range(n_tiles):
        for rid in range(n_runs):
            assert np.array_equal(
                np.asarray(stream.outputs[i][rid]["mask"]),
                np.asarray(thread_stream.outputs[i][rid]["mask"]),
            ), f"[{label}] tile {i} run {rid} diverged across the wire"


def _wait_for(pred, timeout: float, what: str) -> None:
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() >= deadline:
            raise RuntimeError(f"timed out waiting for {what}")
        time.sleep(0.02)


def run(csv: List[str]) -> None:
    size = 32 if SMOKE else 56
    n_tiles = 2 if SMOKE else 4
    n_runs = 8 if SMOKE else 24
    wf = build_workflow(size, size)
    sets = moat_param_sets(n_runs, seed=9)
    n_runs = len(sets)  # MOAT rounds to whole trajectories of dim+1 runs
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=8, active_paths=2)
    tiles_np = [synthetic_tile(size, size, seed=t) for t in range(n_tiles)]
    tiles = [{"raw": jnp.asarray(im)} for im in tiles_np]

    execute_plan(plan, tiles[0])  # warm: jit compile every task variant

    # ---------------- thread backend (the in-process oracle) -------------
    t0 = time.perf_counter()
    thread_stream = execute_study(
        plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS)
    )
    t_thread = time.perf_counter() - t0
    assert thread_stream.backend == "thread"
    csv.append(
        f"net_thread_workers{N_WORKERS},{t_thread*1e6/n_tiles:.0f},"
        f"throughput={thread_stream.throughput:.2f}tiles_s"
    )

    # ---------------- process backend (single-host reference) ------------
    backend = ProcessRpcBackend(
        build=pathology_rpc_build,
        build_kwargs={"images": tiles_np},
        **process_flag_kwargs("process"),
    )
    mgr = Manager(backend=backend)
    mgr.start(N_WORKERS)
    try:
        # untimed warmups under distinct input_keys (see benchmarks/rpc.py
        # for the full rationale: spawn + jit + plan builds stay out of the
        # timed window, and the warmup outputs can never serve it)
        passes = [f"warm{p}" for p in range(WARMUP_PASSES)]
        for n, p in enumerate(passes + [passes[-1]]):
            execute_study(
                plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
                manager=mgr,
                input_keys=[f"{p}:{t}" for t in range(n_tiles)],
                key_prefix=f"w{n}:",
            )
        t0 = time.perf_counter()
        proc_stream = execute_study(
            plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
            manager=mgr, key_prefix="t:",
        )
        t_proc = time.perf_counter() - t0
        assert proc_stream.backend == "process"
        _assert_identical(proc_stream, thread_stream, n_tiles, n_runs, "process")
    finally:
        mgr.close()
        backend.cleanup()
    csv.append(
        f"net_process_all,{t_proc*1e6/n_tiles:.0f},"
        f"vs_thread={t_proc/max(t_thread, 1e-9):.2f}x"
    )

    # ---------------- socket fleet over the object-store tier ------------
    obj_root = tempfile.mkdtemp(prefix="bench_net_obj_")
    backend = SocketBackend(
        build=pathology_rpc_build,
        build_kwargs={"images": tiles_np},
        store=f"obj:{obj_root}",
    )
    mgr = Manager(backend=backend)
    mgr.start(N_WORKERS)
    try:
        passes = [f"warm{p}" for p in range(WARMUP_PASSES)]
        for n, p in enumerate(passes + [passes[-1]]):
            execute_study(
                plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
                manager=mgr,
                input_keys=[f"{p}:{t}" for t in range(n_tiles)],
                key_prefix=f"w{n}:",
            )
        frames_before = backend.stats()["leader"]
        t0 = time.perf_counter()
        sock_stream = execute_study(
            plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
            manager=mgr, key_prefix="t:",
        )
        t_sock = time.perf_counter() - t0
        assert sock_stream.backend == "socket"
        assert set(sock_stream.dispatch_counts) == {"socket"}
        _assert_identical(sock_stream, thread_stream, n_tiles, n_runs, "socket")
        leader = backend.stats()["leader"]
        frames = (
            (leader["lease_frames"] - frames_before["lease_frames"])
            + (leader["comp_batches"] - frames_before["comp_batches"])
        )
        # everything durable went through the object store: entries exist
        # under the root and serve reads back (no shared dir beyond it)
        entries = pathlib.Path(obj_root) / "entries"
        assert entries.is_dir() and any(entries.iterdir()), "no object entries?"
        committed = [
            k for k in backend.store.committed_keys() if k.startswith("rpc:")
        ]
        assert committed, "no store commits over the object tier?"
    finally:
        mgr.close()
        backend.cleanup()
        shutil.rmtree(obj_root, ignore_errors=True)

    ratio_thread = t_sock / max(t_thread, 1e-9)
    ratio_proc = t_sock / max(t_proc, 1e-9)
    overhead_us = (t_sock - t_thread) * 1e6 / max(frames, 1)
    csv.append(
        f"net_socket_loopback,{t_sock*1e6/n_tiles:.0f},"
        f"throughput={sock_stream.throughput:.2f}tiles_s"
        f"_vs_thread={ratio_thread:.2f}x"
        f"_vs_process={ratio_proc:.2f}x"
        f"_frames={frames}"
        f"_overhead_per_frame={overhead_us:.0f}us"
        f"_committed_keys={len(committed)}"
    )

    # ---------------- fault recovery (the acceptance scenario) -----------
    obj_root = tempfile.mkdtemp(prefix="bench_net_fault_")
    marker_dir = tempfile.mkdtemp(prefix="bench_net_marker_")
    fired = {}
    backend = SocketBackend(
        build=pathology_rpc_build,
        build_kwargs={"images": tiles_np},
        store=f"obj:{obj_root}",
        heartbeat_interval=0.05,
    )
    mgr = Manager(backend=backend, enable_backup_tasks=False, max_attempts=3)
    mgr.start(3)
    try:
        def cb(key, value):
            fired[key] = fired.get(key, 0) + 1

        t0 = time.perf_counter()
        mgr.submit(WorkItem(key="killed", callback=cb,
                            spec=("call", _hang_until_killed, (marker_dir,), {})))
        mgr.submit(WorkItem(key="cut", callback=cb,
                            spec=("call", _slow_first, (marker_dir,), {})))
        for i in range(4):
            mgr.submit(WorkItem(key=f"pad{i}", callback=cb,
                                spec=("call", _quick_task, (i,), {})))

        pid_file = pathlib.Path(marker_dir) / "kill_pid"
        _wait_for(pid_file.exists, 30, "hang task to start")
        victim_pid = int(pid_file.read_text())

        def cut_holder():
            for wid, st in backend.heartbeat_view().items():
                if wid >= 0 and st.alive and any(
                    lid.startswith("cut#") for lid in st.inflight
                ):
                    return wid
            return None

        _wait_for(lambda: cut_holder() is not None, 15, "cut task leased")
        cut_wid = cut_holder()
        os.kill(victim_pid, signal.SIGKILL)  # fault 1: a dead host
        assert backend.disconnect(cut_wid)   # fault 2: a partition
        mgr.drain()
        t_recover = time.perf_counter() - t0
        out = mgr.results()
        assert out["killed"] == "fast" and out["cut"] == "done"
        assert all(n == 1 for n in fired.values()), fired  # exactly once
        assert len(fired) == 6

        # the degraded session still runs the full study, bit-identical
        t0 = time.perf_counter()
        fault_stream = execute_study(
            plan, tiles, cluster=ClusterSpec(n_workers=2), manager=mgr,
            key_prefix="f:",
        )
        t_fault = time.perf_counter() - t0
        assert fault_stream.backend == "socket"
        _assert_identical(fault_stream, thread_stream, n_tiles, n_runs, "fault")
        leader = backend.stats()["leader"]
    finally:
        mgr.close()
        backend.cleanup()
        shutil.rmtree(obj_root, ignore_errors=True)
        shutil.rmtree(marker_dir, ignore_errors=True)
    csv.append(
        f"net_fault_recovery,{t_fault*1e6/n_tiles:.0f},"
        f"drain={t_recover:.2f}s"
        f"_callbacks={len(fired)}x1"
        f"_reconnects={leader['reconnects']}"
        f"_disconnects={leader['disconnects']}"
    )

    # the acceptance gate (ISSUE 8): the loopback fleet over the object
    # tier must hold within MAX_RATIO of the in-process oracle
    if ratio_thread > MAX_RATIO:
        raise RuntimeError(
            f"socket backend is {ratio_thread:.2f}x thread wall time — "
            f"regression past the {MAX_RATIO:.1f}x gate "
            f"(vs process: {ratio_proc:.2f}x, per-frame {overhead_us:.0f}us)"
        )

"""Table II — task reuse attained by RTMA vs RMSR as images grow, for 64 GB
and 128 GB machines, on a VBD study with 8,000 parameter sets — reuse
accounting read off StudyPlanner plans.

RTMA memory is width-proportional: bucket × (47 fp32 planes × px) — the
calibration implied by the paper's (9K, 64 GB) → bucket 4 anchor; larger
images then force smaller buckets and less reuse (the paper's 31.75% →
21.82% decay). RMSR's activePaths bound makes bucket 10 feasible at any
memory, holding reuse constant.
"""

from __future__ import annotations

from typing import List

from repro.app import TABLE1_SPACE
from repro.app.pipeline import build_segmentation_stage
from repro.core import Workflow
from repro.core.sa import saltelli_sample
from repro.engine import plan_study

from benchmarks.common import PLANES_PER_INSTANCE

GB = 1 << 30


def run(csv: List[str]) -> None:
    sets, _ = saltelli_sample(TABLE1_SPACE, 8000 // (TABLE1_SPACE.dim + 2), seed=3)
    for size_k in (9, 10, 11):
        px = size_k * 1024
        stage = build_segmentation_stage(px, px)
        wf = Workflow(stages=(stage,))
        w_inst = PLANES_PER_INSTANCE * px * px * 4
        for mem_gb in (64, 128):
            b = max(1, min(10, int(mem_gb * GB // w_inst)))
            plan = plan_study(wf, sets, policy="rtma", max_bucket_size=b)
            csv.append(
                f"table2_rtma_{size_k}K_{mem_gb}GB,0,"
                f"bucket={b}_reuse={plan.reuse_fraction*100:.2f}%"
            )
        plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=10, active_paths=1)
        csv.append(
            f"table2_rmsr_{size_k}K_anyGB,0,bucket=10_reuse={plan.reuse_fraction*100:.2f}%"
        )

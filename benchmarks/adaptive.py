"""Adaptive multi-round study vs one-shot rounds (DESIGN.md §11) —
``BENCH_adaptive.json``.

The scenario the paper's reuse machinery exists for: an iterative SA
campaign (MOAT screening → prune → VBD on the survivors → refinement)
where each round's run-list overlaps the history. Two executions of the
*identical* round sequence over ``TABLE1_SPACE`` on a real tile:

* **adaptive** — ``repro.study.StudyDriver``: one persistent Manager
  session, a round-shared result cache backed by the hierarchical store,
  delta-only planning against the cached trie;
* **one-shot** — every round replayed as an independent study (fresh plan,
  fresh cache, fresh session), the pre-``repro.study`` workflow.

Reported: total tasks executed (must be strictly fewer adaptively; the
outputs are bit-identical by purity), wall clock, and the study-wide reuse
factor.
"""

from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.app import TABLE1_SPACE, synthetic_tile
from repro.app.pipeline import build_workflow
from repro.core import dice
from repro.core.metrics import reuse_factor
from repro.engine import ClusterSpec, execute_study, plan_study
from repro.study import (
    MoatSampler,
    RefinementSampler,
    SaltelliSampler,
    StudyDriver,
)

from benchmarks.common import SMOKE


def run(csv: List[str]) -> None:
    size = 32 if SMOKE else 64
    n_traj = 1 if SMOKE else 2
    n_base = 2 if SMOKE else 4
    max_rounds = 3 if SMOKE else 4
    wf = build_workflow(size, size)
    # backups off: a straggler clone that wins with cache hits perturbs
    # tasks_executed run-to-run, and this benchmark compares REUSE — the
    # task-count delta must be the planner's doing, not the fault layer's
    cluster = ClusterSpec(n_workers=2, enable_backup_tasks=False)
    tile = {"raw": jnp.asarray(synthetic_tile(size, size, seed=0))}

    ref_plan = plan_study(wf, [TABLE1_SPACE.default()], policy="rmsr", active_paths=1)
    ref_mask = execute_study(ref_plan, [tile]).outputs[0][0]["mask"]

    # warm every jit variant (conn-style params are static args, so both
    # grid values trigger a compile) — whichever side runs first must not
    # be charged for XLA compilation
    defaults = dict(TABLE1_SPACE.default())
    warm_sets = []
    for conn in (4, 8):
        d = dict(defaults)
        d.update(FH=conn, RC=conn, WConn=conn)
        warm_sets.append(tuple(sorted(d.items())))
    execute_study(plan_study(wf, warm_sets, policy="rmsr", active_paths=1), [tile])

    def objective(leaf_state, _i):
        return 1.0 - float(dice(leaf_state["mask"], ref_mask))

    # warm the OBJECTIVE's jit too (dice): the adaptive side evaluates it
    # first and must not be charged its compile either
    float(dice(ref_mask, ref_mask))

    def make_driver():
        return StudyDriver(
            wf, TABLE1_SPACE, [tile],
            objective=objective, seed=11, cluster=cluster,
            samplers={
                "moat": MoatSampler(n_traj),
                "vbd": SaltelliSampler(n_base),
                "refine": RefinementSampler(),
            },
            n_boot=16, input_keys=["tile0"],
        )

    # ---------------- adaptive: the repro.study driver -------------------
    t0 = time.perf_counter()
    driver = make_driver()
    try:
        state = driver.run(max_rounds=max_rounds)
    finally:
        driver.close()
    t_adaptive = time.perf_counter() - t0
    adaptive_tasks = state.tasks_executed

    # ---------------- one-shot oracle: same rounds, no cross-round state --
    t0 = time.perf_counter()
    oneshot_tasks = 0
    for r in state.rounds:
        plan = plan_study(
            wf, list(dict.fromkeys(r.param_sets)),
            policy="hybrid", active_paths=4, cluster=cluster,
        )
        stream = execute_study(plan, [tile], cluster=cluster)
        oneshot_tasks += stream.tasks_executed
        for rid, ps in enumerate(dict.fromkeys(r.param_sets)):
            assert np.isclose(
                1.0 - float(dice(stream.outputs[0][rid]["mask"], ref_mask)),
                state.evaluated[ps],
            ), "adaptive reuse changed a result"
    t_oneshot = time.perf_counter() - t0

    assert adaptive_tasks < oneshot_tasks, (
        f"adaptive ({adaptive_tasks}) must beat one-shot ({oneshot_tasks})"
    )
    rf = reuse_factor(adaptive_tasks, state.tasks_requested)
    csv.append(
        f"adaptive_study,{t_adaptive*1e6:.0f},"
        f"rounds={len(state.rounds)}_tasks={adaptive_tasks}"
        f"_reuse_factor={rf:.2f}x_active={len(state.active)}"
    )
    csv.append(
        f"adaptive_oneshot_oracle,{t_oneshot*1e6:.0f},"
        f"tasks={oneshot_tasks}"
        f"_adaptive_saves={oneshot_tasks - adaptive_tasks}tasks"
        f"_speedup={t_oneshot/max(t_adaptive,1e-9):.2f}x"
    )

"""Fig 6 — performance benefit of reuse strategies (No reuse / Stage-level /
multi-level RTMA) for MOAT studies of two sampling sizes, planned by the
StudyPlanner engine (one plan_study call per policy).

Paper claims (640 sets): Stage ≈ 1.7×, RTMA multi-level ≈ 2.6× vs No reuse.
"""

from __future__ import annotations

from typing import List

from repro.app.pipeline import build_segmentation_stage

from benchmarks.common import SMOKE, measure_task_costs, moat_param_sets, plan_strategy

H = W = 64 if SMOKE else 128
SIZES = (64, 128) if SMOKE else (320, 640)


def run(csv: List[str]) -> None:
    costs = measure_task_costs(H, W)
    profiles = {"measured": costs}
    # paper-cost-profile: the paper's app spends ~41% of a run in the
    # parameter-free normalization (that ratio is what yields its 1.7×
    # stage-level gain); validate the multi-level mechanism under it.
    seg_total = sum(v for k, v in costs.items() if k != "normalize")
    profiles["papercal"] = dict(costs, normalize=seg_total * 0.41 / 0.59)
    for pname, prof in profiles.items():
        stage = build_segmentation_stage(
            H, W, costs={k: v for k, v in prof.items()}
        )
        norm_cost = prof["normalize"]
        for n_runs in SIZES:
            sets = moat_param_sets(n_runs, seed=1)
            base = plan_strategy(stage, norm_cost, sets, "none")
            for strat in ("stage", "rtma", "hybrid"):
                plan = plan_strategy(stage, norm_cost, sets, strat, max_bucket=8)
                speedup = base.work_seconds / plan.work_seconds
                csv.append(
                    f"fig6_{pname}_{strat}_n{n_runs},"
                    f"{plan.work_seconds*1e6/max(n_runs,1):.1f},"
                    f"speedup={speedup:.2f}x_tasks={plan.stages[1].tasks_executed}"
                )

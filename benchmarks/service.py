"""SA-as-a-service benchmark (DESIGN.md §18) — ``BENCH_service.json``.

Three rows against one long-lived :class:`~repro.service.StudyServer`
over the real pathology workflow:

* **service_shared** — two tenants submit the *same* study concurrently;
  the content-addressed shared path must execute it once (combined
  dispatch strictly below the sum of independent submissions, asserted);
* **service_cancel** — cancellation latency: wall time from ``cancel()``
  until the revoked job is terminal AND the pool's queues are empty —
  the freed-within-a-heartbeat claim, asserted well under the 60 s
  heartbeat default;
* **service_fairshare** — a weight-0.25 tenant's 2-run job completes
  while a weight-1.0 tenant's multi-job grid backlog is still draining
  (monotonic progress under contention, asserted).
"""

from __future__ import annotations

import time
from typing import List

from repro.app.pipeline import pathology_service_build
from repro.service import StudyServer, StudySpec

from benchmarks.common import SMOKE


def _dispatched(srv: StudyServer) -> int:
    return sum(srv.manager.dispatch_counts.values())


def run(csv: List[str]) -> None:
    size = 24 if SMOKE else 48
    srv = StudyServer.from_build(
        pathology_service_build,
        {"size": size, "n_tiles": 1 if SMOKE else 2},
        n_workers=2,
    )
    try:
        # ------------- cross-tenant dedup: combined < sum ----------------
        solo = StudySpec(sampler="moat", n_trajectories=1, seed=3)
        d0 = _dispatched(srv)
        r0 = srv.result(srv.submit("solo", solo), wait=True, timeout=900)
        assert r0["state"] == "DONE", r0
        single = _dispatched(srv) - d0

        shared = StudySpec(sampler="moat", n_trajectories=1, seed=11)
        d1 = _dispatched(srv)
        t0 = time.perf_counter()
        ja = srv.submit("alice", shared)
        jb = srv.submit("bob", shared)
        ra = srv.result(ja, wait=True, timeout=900)
        rb = srv.result(jb, wait=True, timeout=900)
        t_shared = time.perf_counter() - t0
        combined = _dispatched(srv) - d1
        assert ra["state"] == "DONE" and rb["state"] == "DONE", (ra, rb)
        assert ra["result"]["objective"] == rb["result"]["objective"]
        assert combined < 2 * single, (
            f"shared submissions must beat independent ones: "
            f"combined={combined} vs 2x single={2 * single}"
        )
        csv.append(
            f"service_shared,{t_shared * 1e6:.0f},"
            f"tenants=2_combined={combined}_single={single}"
            f"_saved={2 * single - combined}tasks"
        )

        # ------------- cancellation latency ------------------------------
        sweep = StudySpec(
            sampler="grid",
            names=["T1", "G1"],
            bounds={"T1": [2.5, 3.0, 3.5, 4.0], "G1": [5, 10, 15, 20]},
        )
        job = srv.submit("hog", sweep)
        deadline = time.monotonic() + 120
        while srv.status(job)["state"] == "QUEUED":
            assert time.monotonic() < deadline, "sweep never started"
            time.sleep(0.005)
        t0 = time.perf_counter()
        srv.cancel(job)
        while (
            srv.status(job)["state"] != "CANCELLED"
            or srv.manager.scheduler_stats()["tenant_depths"]
        ):
            assert time.monotonic() < deadline, "cancel never freed the pool"
            time.sleep(0.005)
        latency = time.perf_counter() - t0
        assert latency < 30.0, f"cancel latency {latency:.2f}s"
        csv.append(
            f"service_cancel,{latency * 1e6:.0f},"
            f"queued_purged_and_pool_freed_lt_heartbeat"
        )

        # ------------- fair share under a heavy backlog ------------------
        srv.set_tenant_weight("hog", 1.0)
        srv.set_tenant_weight("mouse", 0.25)
        hog_jobs = [
            srv.submit(
                "hog",
                StudySpec(
                    sampler="grid",
                    names=["T1", "FH"],
                    bounds={"T1": [2.5, 3.0, 3.5, 4.0][: 2 if SMOKE else 4]},
                ),
            ),
            srv.submit(
                "hog",
                StudySpec(
                    sampler="grid",
                    names=["T2", "RC"],
                    bounds={"T2": [2.5, 3.0, 3.5, 4.0][: 2 if SMOKE else 4]},
                ),
            ),
        ]
        t0 = time.perf_counter()
        mouse = srv.submit(
            "mouse",
            StudySpec(sampler="explicit", param_sets=[{}, {"FH": 4}]),
        )
        rm = srv.result(mouse, wait=True, timeout=900)
        t_mouse = time.perf_counter() - t0
        assert rm["state"] == "DONE", rm
        hog_done = [
            srv.result(j, wait=True, timeout=900)["finished_at"]
            for j in hog_jobs
        ]
        assert rm["finished_at"] <= max(hog_done), (
            "low-weight tenant starved behind the hog backlog"
        )
        dispatch = srv.manager.scheduler_stats()["tenant_dispatch"]
        csv.append(
            f"service_fairshare,{t_mouse * 1e6:.0f},"
            f"mouse_weight=0.25_done_before_backlog_drained"
            f"_dispatch_mouse={dispatch.get('mouse', 0)}"
            f"_hog={dispatch.get('hog', 0)}"
        )
    finally:
        srv.close()

"""Streaming dataset executor benchmark (DESIGN.md §10) — the perf
trajectory's first machine-readable series (``BENCH_streaming.json``).

Two measurements:

(1) **real** — a multi-tile study on small tiles with real JAX tasks:
    K sequential ``execute_plan`` calls (one Manager session per call)
    versus one ``execute_study`` over the same tiles (one persistent
    session, per-tile stage edges), at 1/2/4 Workers. Reports wall-clock,
    throughput, parallel efficiency and the Manager-session count.

(2) **paper scale** — the discrete-event streaming model
    (``runtime.simulate_stream``) fed by the hybrid plan's frozen per-stage
    bucket makespans (measured JAX costs scaled to 4K×4K tiles), 6,113
    tiles at 32→256 nodes × 28 cores, streaming vs the pre-streaming
    global stage barrier. Paper claim: ≈0.92 efficiency at 256 nodes.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_segmentation_stage, build_workflow
from repro.core import Workflow
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.runtime import simulate_stream
from repro.runtime.manager import Manager

from benchmarks.common import SMOKE, measure_task_costs, moat_param_sets

TILE = 4096  # paper §IV-B whole-slide tile size
N_TILES_PAPER = 200 if SMOKE else 6113


def run(csv: List[str]) -> None:
    # ---------------- (1) real streaming execution, container scale ------
    size = 48 if SMOKE else 64
    n_tiles = 3 if SMOKE else 6
    n_runs = 16 if SMOKE else 32
    wf = build_workflow(size, size)
    sets = moat_param_sets(n_runs, seed=7)
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=8, active_paths=2)
    tiles = [
        {"raw": jnp.asarray(synthetic_tile(size, size, seed=t))}
        for t in range(n_tiles)
    ]

    execute_plan(plan, tiles[0])  # warm: jit compile every task variant

    t0 = time.perf_counter()
    sessions0 = Manager.sessions_started
    seq_outputs = [execute_plan(plan, tile).outputs for tile in tiles]
    t_seq = time.perf_counter() - t0
    seq_sessions = Manager.sessions_started - sessions0
    csv.append(
        f"streaming_real_sequential,{t_seq*1e6/n_tiles:.0f},"
        f"tiles={n_tiles}_sessions={seq_sessions}"
    )

    for w in (1, 2, 4):
        t0 = time.perf_counter()
        sessions0 = Manager.sessions_started
        stream = execute_study(plan, tiles, cluster=ClusterSpec(n_workers=w))
        dt = time.perf_counter() - t0
        assert Manager.sessions_started - sessions0 == 1
        for i in range(n_tiles):  # bit-identical to sequential per-tile runs
            for rid in range(n_runs):
                assert np.array_equal(
                    np.asarray(stream.outputs[i][rid]["mask"]),
                    np.asarray(seq_outputs[i][rid]["mask"]),
                )
        csv.append(
            f"streaming_real_workers{w},{dt*1e6/n_tiles:.0f},"
            f"throughput={stream.throughput:.2f}tiles_s"
            f"_eff={stream.parallel_efficiency:.2f}"
            f"_speedup_vs_seq={t_seq/max(dt,1e-9):.2f}x_sessions=1"
        )

    # ---------------- (2) paper-scale streaming simulation ---------------
    mh = 64 if SMOKE else 128
    costs = measure_task_costs(mh, mh)
    scale = (TILE / mh) ** 2
    seg = build_segmentation_stage(
        TILE, TILE, costs={k: v * scale for k, v in costs.items()}
    )
    sim_sets = moat_param_sets(40 if SMOKE else 160, seed=4)
    sim_plan = plan_study(
        Workflow(stages=(seg,)), sim_sets,
        policy="hybrid", max_bucket_size=28, active_paths=28,
    )
    stage_bucket_costs = [
        [b.schedule.makespan for b in sp.buckets] for sp in sim_plan.stages
    ]
    # normalization as a cheap parameter-free front stage, per DESIGN §10
    stage_bucket_costs.insert(0, [costs["normalize"] * scale])

    nodes_list = (32, 256) if SMOKE else (32, 64, 128, 256)
    for nodes in nodes_list:
        sim = simulate_stream(
            stage_bucket_costs, N_TILES_PAPER, n_nodes=nodes, seed=0
        )
        bar = simulate_stream(
            stage_bucket_costs, N_TILES_PAPER, n_nodes=nodes, seed=0, barrier=True
        )
        csv.append(
            f"streaming_sim_nodes{nodes},{sim.makespan*1e6:.0f},"
            f"eff={sim.parallel_efficiency:.3f}"
            f"_tput={sim.throughput:.2f}tiles_s"
            f"_vs_barrier={bar.makespan/max(sim.makespan,1e-12):.2f}x"
        )

"""Streaming dataset executor benchmark (DESIGN.md §10, §15) — the perf
trajectory's machine-readable series (``BENCH_streaming.json``).

Three measurements:

(1) **real** — a multi-tile study on small tiles with real JAX tasks:
    K sequential ``execute_plan`` calls (one Manager session per call)
    versus one ``execute_study`` over the same tiles (one persistent
    session, per-tile stage edges) at 1/2/4 Workers, plus the same study
    through the HIERARCHICAL scheduler (fanout=2 sub-manager pumps,
    locality + stealing). Every row reports the scheduler observables —
    pump occupancy, mean worker idle fraction, locality hit-rate — and
    hierarchical outputs are asserted bit-identical to flat.

(2) **paper scale** — the discrete-event streaming model
    (``runtime.simulate_stream``) fed by the hybrid plan's frozen per-stage
    bucket makespans (measured JAX costs scaled to 4K×4K tiles), the full
    6,113 tiles at 32→256 nodes × 28 cores. The flat single pump is
    charged ``PUMP_SERVICE`` per scheduling event (the measured
    order-of-magnitude of the Python pump's per-event cost — see the real
    rows' pump occupancy), which saturates it at 256 nodes
    (occupancy ≈ 0.87); fanout=16 sub-pumps with locality + stealing
    recover the paper's regime. Paper claim: >92% efficiency at 256 nodes;
    the artifact records the ``EFF_FLOOR`` gate CI enforces.

(3) **autotune** — ``runtime.autotune_stream`` over re-planned bucket-size
    candidates × pump fan-outs, minimizing simulated makespan. This is the
    reuse-vs-balance trade made visible: coarse buckets maximize merged-
    prefix reuse (least total work, best makespan), finer buckets maximize
    efficiency; the chosen point and the best-efficiency point are both
    reported.

NOTE: the DES section deliberately ignores ``SMOKE`` for tile count and
run count — the simulator is cheap, and a 200-tile smoke study hits a
parallelism ceiling at 7,168 cores that reads as an efficiency collapse
but is only a small-sample artifact. Only the measured-cost tile size
shrinks under smoke.
"""

from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_segmentation_stage, build_workflow
from repro.core import Workflow
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.runtime import autotune_stream, simulate_stream
from repro.runtime.manager import Manager

from benchmarks.common import SMOKE, measure_task_costs, moat_param_sets

TILE = 4096  # paper §IV-B whole-slide tile size
N_TILES_PAPER = 6113  # full dataset even under SMOKE (see module docstring)
N_SIM_RUNS = 160

# Charged per scheduling event (dispatch or completion settle) in the DES:
# the measured order-of-magnitude of the Python pump's per-event cost
# (lock + lease bookkeeping + callback; the real rows' pump_occ is the
# container-scale measurement of the same quantity).
PUMP_SERVICE = 1.5e-3
HIER_FANOUT = 16
# The paper-scale operating point: bucket size 14 keeps per-bucket work
# fine enough that 7,168 cores stay load-balanced (bucket 28 trades that
# balance for deeper merged-prefix reuse — the autotune rows quantify it).
OPERATING_BUCKET = 14
BUCKET_CANDIDATES = (14, 28) if SMOKE else (7, 14, 28)

# CI regression gate (the sched-smoke job re-reads this from the artifact):
# hierarchical simulated efficiency at 256 nodes must stay ≥ this floor.
EFF_FLOOR = 0.90


def _sched_tags(sched: Dict) -> str:
    """The per-row scheduler observables (DESIGN.md §15)."""
    return (
        f"pump_occ={sched['pump_occupancy']:.2f}"
        f"_idle={sched['worker_idle_fraction']:.2f}"
        f"_hit={sched['locality_hit_rate']:.2f}"
        f"_steals={sched['steals']}"
    )


def run(csv: List[str]) -> None:
    # ---------------- (1) real streaming execution, container scale ------
    size = 48 if SMOKE else 64
    n_tiles = 3 if SMOKE else 6
    n_runs = 16 if SMOKE else 32
    wf = build_workflow(size, size)
    sets = moat_param_sets(n_runs, seed=7)
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=8, active_paths=2)
    tiles = [
        {"raw": jnp.asarray(synthetic_tile(size, size, seed=t))}
        for t in range(n_tiles)
    ]

    execute_plan(plan, tiles[0])  # warm: jit compile every task variant

    t0 = time.perf_counter()
    sessions0 = Manager.sessions_started
    seq_outputs = [execute_plan(plan, tile).outputs for tile in tiles]
    t_seq = time.perf_counter() - t0
    seq_sessions = Manager.sessions_started - sessions0
    csv.append(
        f"streaming_real_sequential,{t_seq*1e6/n_tiles:.0f},"
        f"tiles={n_tiles}_sessions={seq_sessions}"
    )

    def check_identical(stream):
        for i in range(n_tiles):  # bit-identical to sequential per-tile runs
            for rid in range(n_runs):
                assert np.array_equal(
                    np.asarray(stream.outputs[i][rid]["mask"]),
                    np.asarray(seq_outputs[i][rid]["mask"]),
                )

    for w in (1, 2, 4):
        t0 = time.perf_counter()
        sessions0 = Manager.sessions_started
        stream = execute_study(plan, tiles, cluster=ClusterSpec(n_workers=w))
        dt = time.perf_counter() - t0
        assert Manager.sessions_started - sessions0 == 1
        check_identical(stream)
        csv.append(
            f"streaming_real_workers{w},{dt*1e6/n_tiles:.0f},"
            f"throughput={stream.throughput:.2f}tiles_s"
            f"_eff={stream.parallel_efficiency:.2f}"
            f"_speedup_vs_seq={t_seq/max(dt,1e-9):.2f}x_sessions=1"
            f"_{_sched_tags(stream.scheduler)}"
        )

    # hierarchical scheduler over the same study: 2 sub-manager pumps,
    # locality-aware dispatch + stealing — outputs must stay bit-identical
    t0 = time.perf_counter()
    sessions0 = Manager.sessions_started
    hier = execute_study(
        plan, tiles, cluster=ClusterSpec(n_workers=4), hierarchy="fanout=2,block=2"
    )
    dt = time.perf_counter() - t0
    assert Manager.sessions_started - sessions0 == 1
    assert hier.scheduler["mode"] == "hierarchical"
    check_identical(hier)
    csv.append(
        f"streaming_real_hier_workers4_fanout2,{dt*1e6/n_tiles:.0f},"
        f"throughput={hier.throughput:.2f}tiles_s"
        f"_eff={hier.parallel_efficiency:.2f}"
        f"_speedup_vs_seq={t_seq/max(dt,1e-9):.2f}x_sessions=1"
        f"_{_sched_tags(hier.scheduler)}"
    )

    # ---------------- (2) paper-scale streaming simulation ---------------
    mh = 64 if SMOKE else 128
    costs = measure_task_costs(mh, mh)
    scale = (TILE / mh) ** 2
    seg = build_segmentation_stage(
        TILE, TILE, costs={k: v * scale for k, v in costs.items()}
    )
    sim_sets = moat_param_sets(N_SIM_RUNS, seed=4)

    def bucket_costs(bucket_size: int) -> List[List[float]]:
        sim_plan = plan_study(
            Workflow(stages=(seg,)), sim_sets,
            policy="hybrid", max_bucket_size=bucket_size,
            active_paths=min(bucket_size, 28),
        )
        sbc = [
            [b.schedule.makespan for b in sp.buckets] for sp in sim_plan.stages
        ]
        # normalization as a cheap parameter-free front stage, per DESIGN §10
        sbc.insert(0, [costs["normalize"] * scale])
        return sbc

    costs_by_bucket = {bs: bucket_costs(bs) for bs in BUCKET_CANDIDATES}
    op_costs = costs_by_bucket[OPERATING_BUCKET]

    def sim_row(name: str, sim, extra: str = "") -> None:
        csv.append(
            f"{name},{sim.makespan*1e6:.0f},"
            f"eff={sim.parallel_efficiency:.3f}"
            f"_tput={sim.throughput:.2f}tiles_s"
            f"_pump_occ={sim.pump_occupancy:.2f}"
            f"_idle={sim.worker_idle_fraction:.2f}"
            f"_hit={sim.locality_hit_rate:.2f}"
            f"_steals={sim.steals}{extra}"
        )

    nodes_list = (32, 256) if SMOKE else (32, 64, 128, 256)
    hier_eff_256 = 0.0
    for nodes in nodes_list:
        flat = simulate_stream(
            op_costs, N_TILES_PAPER, n_nodes=nodes, seed=0,
            pump_service=PUMP_SERVICE,
        )
        bar = simulate_stream(
            op_costs, N_TILES_PAPER, n_nodes=nodes, seed=0,
            pump_service=PUMP_SERVICE, barrier=True,
        )
        sim_row(
            f"streaming_sim_nodes{nodes}_flat", flat,
            extra=f"_vs_barrier={bar.makespan/max(flat.makespan,1e-12):.2f}x",
        )
        hier = simulate_stream(
            op_costs, N_TILES_PAPER, n_nodes=nodes, seed=0,
            pump_service=PUMP_SERVICE, fanout=HIER_FANOUT, locality=True,
        )
        sim_row(
            f"streaming_sim_nodes{nodes}_hier", hier,
            extra=f"_fanout={hier.fanout}"
            f"_vs_flat={flat.makespan/max(hier.makespan,1e-12):.2f}x",
        )
        if nodes == 256:
            hier_eff_256 = hier.parallel_efficiency

    # ---------------- (3) autotune bucket size × fan-out -----------------
    tuned = autotune_stream(
        costs_by_bucket, N_TILES_PAPER, n_nodes=256,
        pump_service=PUMP_SERVICE, locality=True, seed=0,
    )
    sim_row(
        f"streaming_autotune_bucket{tuned.bucket_size}_fanout{tuned.fanout}",
        tuned.sim,
        extra=f"_candidates={len(tuned.table)}",
    )
    best_eff = max(tuned.table, key=lambda row: row[3])
    csv.append(
        f"streaming_autotune_best_eff,{best_eff[2]*1e6:.0f},"
        f"bucket={best_eff[0]}_fanout={best_eff[1]}_eff={best_eff[3]:.3f}"
    )

    # the recorded regression gate: CI fails if the hierarchical 256-node
    # efficiency ever drops below the floor written into this artifact
    assert hier_eff_256 >= EFF_FLOOR, (
        f"hierarchical 256-node efficiency {hier_eff_256:.3f} fell below "
        f"the {EFF_FLOOR} floor"
    )
    csv.append(
        f"streaming_sim_floor,{EFF_FLOOR*1e6:.0f},"
        f"floor={EFF_FLOOR:.2f}_achieved={hier_eff_256:.3f}_nodes=256"
        f"_paper=0.92"
    )

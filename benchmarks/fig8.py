"""Fig 8 — multi-core and multi-node scalability.

(a) multi-core: RMSR makespan vs worker count on one merged stage.
(b) multi-node: discrete-event simulation of the Manager-Worker cluster at
    paper scale (6,113 4K×4K tiles, 32→256 nodes × 28 cores), plus a REAL
    multi-worker Manager run at container scale (threads, real JAX tasks).

Paper claim: ≈ 0.92 parallel efficiency at 256 nodes (7,168 cores).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_segmentation_stage
from repro.core import Workflow, build_reuse_tree, rtma_buckets, simulate_execution
from repro.core.rmsr import execute_merged_stage
from repro.runtime import Manager, WorkItem, simulate_cluster

from benchmarks.common import measure_task_costs, moat_param_sets


def run(csv: List[str]) -> None:
    costs = measure_task_costs(128, 128)
    scale = (4096 / 128) ** 2
    stage = build_segmentation_stage(4096, 4096, costs={k: v * scale for k, v in costs.items()})
    sets = moat_param_sets(160, seed=4)
    insts = Workflow(stages=(stage,)).instantiate(sets)[stage.name]
    tree = build_reuse_tree(stage, insts)

    # (a) multi-core scaling of one merged stage under RMSR
    t1 = simulate_execution(tree, 1).makespan
    for w in (2, 4, 8, 16, 28):
        tw = simulate_execution(tree, w).makespan
        csv.append(f"fig8a_cores{w},{tw*1e6:.0f},speedup={t1/tw:.2f}x_ideal={w}")

    # (b) multi-node: 6,113 tiles × per-tile merged-stage bucket costs
    buckets = rtma_buckets(stage, insts, 28)
    per_bucket = [simulate_execution(b.tree(stage), 28).makespan for b in buckets]
    tile_costs = []
    rng = np.random.default_rng(0)
    for _ in range(6113):
        tile_costs.extend(c * rng.uniform(0.9, 1.1) for c in per_bucket)
    base = simulate_cluster(tile_costs, n_nodes=1)
    for nodes in (32, 64, 128, 256):
        sim = simulate_cluster(tile_costs, n_nodes=nodes)
        eff = base.makespan / (sim.makespan * nodes)
        csv.append(
            f"fig8b_nodes{nodes},{sim.makespan*1e6:.0f},efficiency={eff:.3f}"
        )

    # real multi-worker Manager run (threads, real JAX execution, small tiles)
    tile = synthetic_tile(64, 64, seed=5)
    import jax.numpy as jnp
    from repro.app.pipeline import build_workflow

    wf = build_workflow(64, 64)
    norm, seg = wf.stages
    state = norm.tasks[0].fn({"raw": jnp.asarray(tile)})
    small_sets = moat_param_sets(32, seed=6)
    small_insts = Workflow(stages=(seg,)).instantiate(small_sets)[seg.name]
    small_buckets = rtma_buckets(seg, small_insts, 8)

    def exec_bucket(bk):
        return execute_merged_stage(bk.tree(seg), state, active_paths=2)

    for bk in small_buckets:  # warm: jit compile every task variant
        exec_bucket(bk)

    times = {}
    for w in (1, 2, 4):
        mgr = Manager()
        for i, bk in enumerate(small_buckets):
            mgr.submit(WorkItem(key=f"b{i}", fn=lambda bk=bk: exec_bucket(bk)))
        t0 = time.perf_counter()
        mgr.run(w, expected=len(small_buckets))
        times[w] = time.perf_counter() - t0
        csv.append(
            f"fig8real_workers{w},{times[w]*1e6:.0f},"
            f"speedup={times[1]/times[w]:.2f}x_(container_has_1_core)"
        )

"""Fig 8 — multi-core and multi-node scalability, driven by the engine.

(a) multi-core: RMSR makespan vs active-path count on one merged stage
    (a ``policy="rmsr"`` plan per worker count).
(b) multi-node: discrete-event simulation of the Manager-Worker cluster at
    paper scale (6,113 4K×4K tiles, 32→256 nodes × 28 cores) fed by the
    hybrid plan's per-bucket makespans, plus a REAL multi-worker
    ``execute_plan`` run at container scale (threads, real JAX tasks).

Paper claim: ≈ 0.92 parallel efficiency at 256 nodes (7,168 cores).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_segmentation_stage, build_workflow
from repro.core import Workflow
from repro.engine import ClusterSpec, execute_plan, plan_study
from repro.runtime import simulate_cluster

from benchmarks.common import measure_task_costs, moat_param_sets


def run(csv: List[str]) -> None:
    costs = measure_task_costs(128, 128)
    scale = (4096 / 128) ** 2
    stage = build_segmentation_stage(4096, 4096, costs={k: v * scale for k, v in costs.items()})
    sets = moat_param_sets(160, seed=4)
    wf = Workflow(stages=(stage,))

    # (a) multi-core scaling of one merged stage under RMSR
    t1 = plan_study(wf, sets, policy="rmsr", active_paths=1).makespan
    for w in (2, 4, 8, 16, 28):
        tw = plan_study(wf, sets, policy="rmsr", active_paths=w).makespan
        csv.append(f"fig8a_cores{w},{tw*1e6:.0f},speedup={t1/tw:.2f}x_ideal={w}")

    # (b) multi-node: 6,113 tiles × per-tile merged-stage bucket costs
    plan28 = plan_study(wf, sets, policy="hybrid", max_bucket_size=28, active_paths=28)
    per_bucket = [b.schedule.makespan for b in plan28.stages[0].buckets]
    tile_costs = []
    rng = np.random.default_rng(0)
    for _ in range(6113):
        tile_costs.extend(c * rng.uniform(0.9, 1.1) for c in per_bucket)
    base = simulate_cluster(tile_costs, n_nodes=1)
    for nodes in (32, 64, 128, 256):
        sim = simulate_cluster(tile_costs, n_nodes=nodes)
        eff = base.makespan / (sim.makespan * nodes)
        csv.append(
            f"fig8b_nodes{nodes},{sim.makespan*1e6:.0f},efficiency={eff:.3f}"
        )

    # real multi-worker engine run (threads, real JAX execution, small tiles)
    import jax.numpy as jnp

    small_wf = build_workflow(64, 64)
    raw = {"raw": jnp.asarray(synthetic_tile(64, 64, seed=5))}
    small_sets = moat_param_sets(32, seed=6)
    small_plan = plan_study(small_wf, small_sets, policy="hybrid",
                            max_bucket_size=8, active_paths=2)

    execute_plan(small_plan, raw)  # warm: jit compile every task variant

    times = {}
    for w in (1, 2, 4):
        t0 = time.perf_counter()
        execute_plan(small_plan, raw, cluster=ClusterSpec(n_workers=w))
        times[w] = time.perf_counter() - t0
        csv.append(
            f"fig8real_workers{w},{times[w]*1e6:.0f},"
            f"speedup={times[1]/times[w]:.2f}x_(container_has_1_core)"
        )

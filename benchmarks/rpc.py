"""Thread vs process WorkerBackend on the streaming workload (DESIGN.md
§13) — ``BENCH_rpc.json``.

The dispatch boundary's cost model, measured: the same hybrid plan over the
same tiles executed through (a) the in-process :class:`ThreadBackend` and
(b) the :class:`ProcessRpcBackend` — N spawn worker processes, a
length-prefixed pickle control plane, and every bucket result crossing the
boundary as a SharedStore key (commit-to-disk on the worker, hydrate on the
leader). Reports wall-clock, throughput, parallel efficiency and the
per-backend dispatch counts.

Asserted (the conformance claims at benchmark scale):

* **bit-identical outputs** — every mask from the process backend equals
  the thread backend's, per tile per run (results-by-store-reference is an
  optimization, never an approximation);
* **real dispatch** — both sessions route every bucket through their
  declared backend (dispatch_counts name exactly one backend each).

The process backend pays spawn + store round-trips on container-scale
tiles, so thread wins small; the interesting number is the gap closing as
task cost grows — the paper's multi-node regime is where the boundary
earns its keep (workers on other hosts, which threads cannot reach at
all).
"""

from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, pathology_rpc_build
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.runtime import ProcessRpcBackend

from benchmarks.common import SMOKE, moat_param_sets

N_WORKERS = 2


def run(csv: List[str]) -> None:
    size = 32 if SMOKE else 56
    n_tiles = 2 if SMOKE else 4
    n_runs = 8 if SMOKE else 24
    wf = build_workflow(size, size)
    sets = moat_param_sets(n_runs, seed=9)
    n_runs = len(sets)  # MOAT rounds to whole trajectories of dim+1 runs
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=8, active_paths=2)
    tiles_np = [synthetic_tile(size, size, seed=t) for t in range(n_tiles)]
    tiles = [{"raw": jnp.asarray(im)} for im in tiles_np]

    execute_plan(plan, tiles[0])  # warm: jit compile every task variant

    # ---------------- thread backend (the in-process oracle) -------------
    t0 = time.perf_counter()
    thread_stream = execute_study(
        plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS)
    )
    t_thread = time.perf_counter() - t0
    assert thread_stream.backend == "thread"
    assert set(thread_stream.dispatch_counts) == {"thread"}
    csv.append(
        f"rpc_thread_workers{N_WORKERS},{t_thread*1e6/n_tiles:.0f},"
        f"throughput={thread_stream.throughput:.2f}tiles_s"
        f"_eff={thread_stream.parallel_efficiency:.2f}"
        f"_dispatched={thread_stream.dispatch_counts.get('thread', 0)}"
    )

    # ---------------- process backend (RPC boundary) ---------------------
    # store_dir=None: the backend owns a throwaway tempdir, so the
    # cleanup() below actually removes it (a caller-supplied dir would be
    # treated as a persistent reuse pool and left alone). The session is
    # external so the store can be inspected BEFORE close() purges the
    # transient rpc:* transport entries.
    backend = ProcessRpcBackend(
        build=pathology_rpc_build,
        build_kwargs={"images": tiles_np},
    )
    from repro.runtime import Manager

    mgr = Manager(backend=backend)
    mgr.start(N_WORKERS)
    try:
        t0 = time.perf_counter()
        proc_stream = execute_study(
            plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS), manager=mgr
        )
        t_proc = time.perf_counter() - t0
        assert proc_stream.backend == "process"
        assert set(proc_stream.dispatch_counts) == {"process"}

        # bit-identical across the boundary: every mask, every tile, run
        for i in range(n_tiles):
            for rid in range(n_runs):
                assert np.array_equal(
                    np.asarray(proc_stream.outputs[i][rid]["mask"]),
                    np.asarray(thread_stream.outputs[i][rid]["mask"]),
                ), f"tile {i} run {rid} diverged across the RPC boundary"

        # results only ever crossed as store keys: the live store still
        # serves every bucket's committed entry (checked pre-purge)
        committed = [
            k for k in backend.store.committed_keys() if k.startswith("rpc:")
        ]
        assert committed, "no store commits?"
        assert backend.store.get(committed[0]) is not None
    finally:
        mgr.close()
        backend.cleanup()  # throwaway tempdir store; drop it once inspected

    csv.append(
        f"rpc_process_workers{N_WORKERS},{t_proc*1e6/n_tiles:.0f},"
        f"throughput={proc_stream.throughput:.2f}tiles_s"
        f"_eff={proc_stream.parallel_efficiency:.2f}"
        f"_dispatched={proc_stream.dispatch_counts.get('process', 0)}"
        f"_committed_keys={len(committed)}"
        f"_vs_thread={t_proc/max(t_thread,1e-9):.2f}x"
    )

"""Thread vs process WorkerBackend on the streaming workload, with a
per-optimization breakdown of the process fast path (DESIGN.md §13–§14) —
``BENCH_rpc.json``.

The dispatch boundary's cost model, measured: the same hybrid plan over the
same tiles executed through (a) the in-process :class:`ThreadBackend` and
(b) a matrix of :class:`ProcessRpcBackend` configurations — every flag off
(the original one-frame-per-task, commit-before-ack wire behavior), each
mechanism isolated (``batch`` / ``warm`` / ``shm`` / ``async``), and all
four on (the shipping default). Every process row reports its
``vs_thread`` wall-time ratio so the artifact attributes the win
per-optimization run over run.

Each process session gets untimed warmup passes first (spawn cost, worker
jit compiles, plan rebuilds), mirroring the thread session's
``execute_plan`` warmup — the timed window measures the control plane, not
one-time compilation. Warmup passes run under distinct
``input_keys``, so the workers' task-level ResultCache cannot serve the
timed workload from memory: the timed pass executes the same compute the
thread oracle does, and only the boundary differs.

Asserted (the conformance claims at benchmark scale):

* **bit-identical outputs** — every mask from every process configuration
  equals the thread backend's, per tile per run (each handoff route —
  store key, shared memory, inline/staged — is an optimization, never an
  approximation);
* **real dispatch** — every session routes every bucket through its
  declared backend (dispatch_counts name exactly one backend each);
* **the 2× gate** — with all flags on, process wall time must be within
  ``MAX_RATIO`` (2×) of thread on this workload; a regression raises, the
  harness exits non-zero, and CI's guard step fails the job.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.app import synthetic_tile
from repro.app.pipeline import build_workflow, pathology_rpc_build
from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.runtime import Manager, ProcessRpcBackend
from repro.runtime.transport import process_flag_kwargs

from benchmarks.common import SMOKE, moat_param_sets

N_WORKERS = 2
MAX_RATIO = 2.0  # the acceptance gate: all-flags process vs thread
WARMUP_PASSES = 2  # per session, untimed: covers both workers' jit caches

# label → backend spec (process_flag_kwargs syntax). Ordered so the
# artifact reads as an ablation: nothing → each mechanism alone → all.
MATRIX = [
    ("none", "process[none]"),
    ("batch", "process[none,batch]"),
    ("warm", "process[none,warm]"),
    ("shm", "process[none,shm]"),
    ("async", "process[none,async]"),
    ("all", "process"),
]


def _assert_identical(proc_stream, thread_stream, n_tiles: int, n_runs: int,
                      label: str) -> None:
    for i in range(n_tiles):
        for rid in range(n_runs):
            assert np.array_equal(
                np.asarray(proc_stream.outputs[i][rid]["mask"]),
                np.asarray(thread_stream.outputs[i][rid]["mask"]),
            ), f"[{label}] tile {i} run {rid} diverged across the RPC boundary"


def run(csv: List[str]) -> None:
    size = 32 if SMOKE else 56
    n_tiles = 2 if SMOKE else 4
    n_runs = 8 if SMOKE else 24
    wf = build_workflow(size, size)
    sets = moat_param_sets(n_runs, seed=9)
    n_runs = len(sets)  # MOAT rounds to whole trajectories of dim+1 runs
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=8, active_paths=2)
    tiles_np = [synthetic_tile(size, size, seed=t) for t in range(n_tiles)]
    tiles = [{"raw": jnp.asarray(im)} for im in tiles_np]

    execute_plan(plan, tiles[0])  # warm: jit compile every task variant

    # ---------------- thread backend (the in-process oracle) -------------
    t0 = time.perf_counter()
    thread_stream = execute_study(
        plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS)
    )
    t_thread = time.perf_counter() - t0
    assert thread_stream.backend == "thread"
    assert set(thread_stream.dispatch_counts) == {"thread"}
    csv.append(
        f"rpc_thread_workers{N_WORKERS},{t_thread*1e6/n_tiles:.0f},"
        f"throughput={thread_stream.throughput:.2f}tiles_s"
        f"_eff={thread_stream.parallel_efficiency:.2f}"
        f"_dispatched={thread_stream.dispatch_counts.get('thread', 0)}"
    )

    # ---------------- process backend flag matrix ------------------------
    ratios: Dict[str, float] = {}
    for label, spec in MATRIX:
        # store_dir=None: each session owns a throwaway tempdir, so
        # cleanup() below actually removes it (a caller-supplied dir would
        # be a persistent reuse pool and left alone). The session is
        # external so the store can be inspected BEFORE close() purges the
        # transient rpc:* transport entries.
        backend = ProcessRpcBackend(
            build=pathology_rpc_build,
            build_kwargs={"images": tiles_np},
            **process_flag_kwargs(spec),
        )
        mgr = Manager(backend=backend)
        mgr.start(N_WORKERS)
        try:
            # untimed warmup: worker spawn + per-worker jit compiles + the
            # first plan build; two passes so round-robin placement leaves
            # no worker with a cold kernel inside the timed window. Each
            # pass runs under its own input_keys, so its cached task
            # outputs can never serve the timed run — the timed pass does
            # the same compute the thread oracle did, only the boundary
            # differs.
            # the final (settling) pass repeats the last pass's keys: all
            # task-cache hits, so the install that opens the TIMED session
            # finds no unpublished history to fsync — without it, warm-off
            # configs would be billed for flushing warmup outputs and the
            # per-mechanism rows would measure disk history, not the wire.
            # Every pass gets its own key_prefix: the Manager memoises
            # WorkItem results by key inside a shared session, so rounds
            # must not submit identical keys (the documented idiom).
            passes = [f"warm{p}" for p in range(WARMUP_PASSES)]
            for n, p in enumerate(passes + [passes[-1]]):
                execute_study(
                    plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
                    manager=mgr,
                    input_keys=[f"{p}:{t}" for t in range(n_tiles)],
                    key_prefix=f"w{n}:",
                )
            t0 = time.perf_counter()
            proc_stream = execute_study(
                plan, tiles, cluster=ClusterSpec(n_workers=N_WORKERS),
                manager=mgr,
                key_prefix="t:",
            )
            t_proc = time.perf_counter() - t0
            assert proc_stream.backend == "process"
            assert set(proc_stream.dispatch_counts) == {"process"}
            _assert_identical(proc_stream, thread_stream, n_tiles, n_runs, label)

            # results crossed by store key / shm segment / staged inline —
            # after drain()'s barrier the store serves every bucket entry
            # regardless of route (checked pre-purge)
            committed = [
                k for k in backend.store.committed_keys() if k.startswith("rpc:")
            ]
            assert committed, f"[{label}] no store commits?"
            assert backend.store.get(committed[0]) is not None
            stats = backend.stats()
        finally:
            mgr.close()
            backend.cleanup()  # throwaway tempdir store; drop it

        ratio = t_proc / max(t_thread, 1e-9)
        ratios[label] = ratio
        w = stats.get("worker", {})
        csv.append(
            f"rpc_process_{label},{t_proc*1e6/n_tiles:.0f},"
            f"throughput={proc_stream.throughput:.2f}tiles_s"
            f"_eff={proc_stream.parallel_efficiency:.2f}"
            f"_dispatched={proc_stream.dispatch_counts.get('process', 0)}"
            f"_committed_keys={len(committed)}"
            f"_plan_hits={w.get('plan_hits', 0)}"
            f"_shm={w.get('shm_sends', 0)}"
            f"_inline={w.get('inline_sends', 0)}"
            f"_store={w.get('store_sends', 0)}"
            f"_batches={stats.get('leader', {}).get('comp_batches', 0)}"
            f"_vs_thread={ratio:.2f}x"
        )

    # the acceptance gate (ISSUE 6): all optimizations on must hold the
    # boundary within MAX_RATIO of the in-process oracle
    if ratios["all"] > MAX_RATIO:
        raise RuntimeError(
            f"process backend (all flags) is {ratios['all']:.2f}x thread "
            f"wall time — regression past the {MAX_RATIO:.1f}x gate "
            f"(full matrix: {ratios})"
        )

"""Fig 7 — RMSR vs RTMA under memory budgets, both planned by the engine.

Memory model (calibrated once, §EXPERIMENTS.md): an in-flight stage instance
(or active RMSR path) holds ~47 fp32 image planes of working set — the value
implied by the paper's own anchors (RTMA(2,2) on 4K×4K tiles = its 6 GB
baseline: 2 × 47 × 4096² × 4B ≈ 6.3 GB, and Table II's (9K, 64 GB) → bucket
4). RTMA memory is width-proportional (bucket × instance set — the paper's
§II-B statement); RMSR memory is activePaths-proportional. The calibrated
bucket/path counts are passed to ``plan_study`` explicitly; makespans come
from the plans' frozen schedules.

Paper claims: RMSR(2,28) ≈ 2.8× RTMA(2,2) at 6 GB; RMSR(8,28) ≈ 1.6×
RTMA(8,8) at 24 GB. MOAT study with 800 parameter sets (paper §IV-B).
"""

from __future__ import annotations

from typing import List

from repro.app.pipeline import build_segmentation_stage
from repro.core import Workflow
from repro.engine import plan_study

from benchmarks.common import PLANES_PER_INSTANCE, measure_task_costs, moat_param_sets

TILE = 4096  # 4K×4K pixels (paper §IV-B)


def run(csv: List[str]) -> None:
    costs = measure_task_costs(128, 128)
    scale = (TILE / 128) ** 2
    stage = build_segmentation_stage(
        TILE, TILE, costs={k: v * scale for k, v in costs.items()}
    )
    sets = moat_param_sets(800, seed=2)
    wf = Workflow(stages=(stage,))

    w_inst = PLANES_PER_INSTANCE * TILE * TILE * 4  # bytes per active instance/path
    for mult, y in ((1, 2), (2, 4), (4, 8)):
        budget = 2 * w_inst * mult  # 6 / 12 / 24 "GB" in the paper's units
        bx = max(1, int(budget // w_inst))  # RTMA width-proportional memory
        rtma = plan_study(wf, sets, policy="rtma", max_bucket_size=bx, workers=y)
        # RMSR: aggressive merging (28), activePaths = y fits by construction
        # (y × w_inst ≤ budget for every configuration above)
        rmsr = plan_study(wf, sets, policy="hybrid", max_bucket_size=28, active_paths=y)
        csv.append(f"fig7_mem{mult}x_RTMA({y}_{bx}),{rtma.makespan*1e6:.0f},baseline")
        csv.append(
            f"fig7_mem{mult}x_RMSR({y}_28),{rmsr.makespan*1e6:.0f},"
            f"speedup={rtma.makespan/max(rmsr.makespan,1e-12):.2f}x"
        )

"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (plus the roofline summary if a
dry-run JSON is present).

Run: PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7,table2,fig8]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback
from typing import List


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list: fig6,fig7,table2,fig8")
    args = ap.parse_args()
    wanted = set(args.only.split(",")) if args.only else None

    from benchmarks import fig6, fig7, fig8, table2

    modules = {"fig6": fig6, "fig7": fig7, "table2": table2, "fig8": fig8}
    csv: List[str] = ["name,us_per_call,derived"]
    for name, mod in modules.items():
        if wanted and name not in wanted:
            continue
        t0 = time.time()
        try:
            mod.run(csv)
            print(f"# {name}: ok ({time.time()-t0:.1f}s)", file=sys.stderr)
        except Exception:  # noqa: BLE001
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)
            csv.append(f"{name}_FAILED,0,error")

    # roofline summary from the dry-run, when present
    dj = pathlib.Path("experiments/dryrun.json")
    if dj.exists() and (wanted is None or "roofline" in wanted):
        for r in json.loads(dj.read_text()):
            if r.get("status") != "ok":
                continue
            csv.append(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
                f"dom={r['dominant'].replace('_s','')}"
                f"_cf={r['roofline_fraction_compute']:.2f}"
                f"_useful={r.get('useful_flops_ratio', 0):.2f}"
            )
    print("\n".join(csv))


if __name__ == "__main__":
    main()

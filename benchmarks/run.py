"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (plus the roofline summary if a
dry-run JSON is present), and writes one machine-readable
``BENCH_<module>.json`` artifact per executed module next to the CSV
(``--out-dir``, default CWD) so the perf trajectory accumulates run over
run. A failed module still produces its artifact (``"ok": false`` + the
traceback) and makes the harness exit non-zero after the remaining modules
finish.

Run: PYTHONPATH=src python -m benchmarks.run
     [--only fig6,fig7,table2,fig8,streaming,adaptive,fleet,rpc,net,service,analysis]
     [--out-dir DIR]
     [--quick]   (the CI smoke profile: shrinks sizes, same pipeline;
                  equivalent to REPRO_BENCH_SMOKE=1)

Modules are imported lazily, one by one, so a selection that needs no
accelerator stack (``--only analysis``, the static-analysis gate) runs in
a bare environment without jax installed.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
import traceback
from typing import List


def _rows_to_json(rows: List[str]) -> List[dict]:
    out = []
    for row in rows:
        name, us, derived = (row.split(",", 2) + ["", ""])[:3]
        try:
            us_val: object = float(us)
        except ValueError:
            us_val = us
        out.append({"name": name, "us_per_call": us_val, "derived": derived})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        help=(
            "comma list: fig6,fig7,table2,fig8,streaming,adaptive,fleet,"
            "rpc,net,service,analysis"
        ),
    )
    ap.add_argument(
        "--out-dir", default=".", help="where BENCH_<module>.json artifacts land"
    )
    ap.add_argument(
        "--quick",
        action="store_true",
        help="smoke profile (reduced sizes; numbers not comparable to full runs)",
    )
    args = ap.parse_args()
    if args.quick:
        # must precede the benchmarks.* imports: common.SMOKE reads it once
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    wanted = set(args.only.split(",")) if args.only else None
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    # names only — each module is imported when (and only when) selected,
    # so jax-free selections (--only analysis) run in a bare environment
    module_names = [
        "analysis", "fig6", "fig7", "table2", "fig8", "streaming",
        "adaptive", "fleet", "rpc", "net", "service",
    ]
    if wanted:
        unknown = wanted - set(module_names) - {"roofline"}
        if unknown:
            ap.error(f"unknown modules in --only: {sorted(unknown)}")
    import importlib

    csv: List[str] = ["name,us_per_call,derived"]
    failed: List[str] = []
    for name in module_names:
        if wanted and name not in wanted:
            continue
        t0 = time.time()
        start = len(csv)
        payload = {"module": name, "ok": True}
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(csv)
            print(f"# {name}: ok ({time.time()-t0:.1f}s)", file=sys.stderr)
        except Exception:  # noqa: BLE001
            err = traceback.format_exc()
            print(f"# {name}: FAILED\n{err}", file=sys.stderr)
            csv.append(f"{name}_FAILED,0,error")
            payload.update(ok=False, error=err)
            failed.append(name)
        payload.update(
            seconds=round(time.time() - t0, 3),
            rows=_rows_to_json(csv[start:]),
        )
        (out_dir / f"BENCH_{name}.json").write_text(json.dumps(payload, indent=1))

    # roofline summary from the dry-run, when present
    dj = pathlib.Path("experiments/dryrun.json")
    if dj.exists() and (wanted is None or "roofline" in wanted):
        for r in json.loads(dj.read_text()):
            if r.get("status") != "ok":
                continue
            csv.append(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.0f},"
                f"dom={r['dominant'].replace('_s','')}"
                f"_cf={r['roofline_fraction_compute']:.2f}"
                f"_useful={r.get('useful_flops_ratio', 0):.2f}"
            )
    print("\n".join(csv))
    if failed:
        print(f"# failing modules: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

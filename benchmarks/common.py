"""Shared benchmark utilities: measured per-task costs + cost composition.

Methodology (DESIGN.md §9): computation-reuse speedups come purely from WHICH
duplicate tasks are skipped, so makespans are composed from *measured* JAX
wall-times of the real pipeline tasks. Reuse fractions are exact analytic
counts on the reuse trie — the same accounting the paper uses.
"""

from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.app import TABLE1_SPACE, synthetic_tile
from repro.app.pipeline import build_workflow
from repro.core import (
    StageSpec,
    Workflow,
    build_reuse_tree,
    morris_trajectories,
    rtma_buckets,
    simulate_execution,
    stage_level_dedup,
)
from repro.core.params import ParamSet, ParamSpace


def measure_task_costs(h: int = 128, w: int = 128, *, repeats: int = 2) -> Dict[str, float]:
    """Wall-time each pipeline task once (jit-warmed) on a real tile."""
    wf = build_workflow(h, w)
    tile = synthetic_tile(h, w, seed=0)
    norm, seg = wf.stages
    defaults = dict(TABLE1_SPACE.default())
    costs: Dict[str, float] = {}

    state = {"raw": jnp.asarray(tile)}
    state = norm.tasks[0].fn(state)  # warm (jit compile)
    jax.block_until_ready(state["rgb"])
    t0 = time.perf_counter()
    for _ in range(repeats):
        state = norm.tasks[0].fn({"raw": jnp.asarray(tile)})
        jax.block_until_ready(state["rgb"])
    costs["normalize"] = (time.perf_counter() - t0) / repeats

    for task in seg.tasks:
        kw = {k: defaults[k] for k in task.param_names}
        out = task.fn(state, **kw)  # warm
        jax.block_until_ready(list(out.values())[0])
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = task.fn(state, **kw)
            jax.block_until_ready(list(out.values())[0])
        costs[task.name] = (time.perf_counter() - t0) / repeats
        state = out
    return costs


def moat_param_sets(n_runs: int, *, seed: int = 0, space: ParamSpace = TABLE1_SPACE) -> List[ParamSet]:
    """A MOAT study with ~n_runs runs (trajectories of dim+1 runs each)."""
    n_traj = max(1, n_runs // (space.dim + 1))
    sets, _ = morris_trajectories(space, n_traj, seed=seed)
    return sets[:n_runs]


def strategy_work_seconds(
    stage: StageSpec,
    norm_cost: float,
    param_sets: Sequence[ParamSet],
    strategy: str,
    *,
    max_bucket: int = 8,
    workers: int = 1,
) -> Dict[str, float]:
    """Total work + makespan (measured-cost-weighted) for one reuse strategy.

    Normalization is parameter-free: with any reuse it runs once; without
    reuse it runs per-instance (the paper's stage-level baseline gain)."""
    wf = Workflow(stages=(stage,))
    insts = wf.instantiate(list(param_sets))[stage.name]
    n = len(insts)

    if strategy == "none":
        total = n * norm_cost
        tree_work = sum(
            t.bound_cost(dict(i.params)) for i in insts for t in stage.tasks
        )
        return {"work_s": total + tree_work, "tasks": n * len(stage.tasks)}
    if strategy == "stage":
        reps, _ = stage_level_dedup(insts)
        work = norm_cost + sum(
            t.bound_cost(dict(r.params)) for r in reps for t in stage.tasks
        )
        return {"work_s": work, "tasks": len(reps) * len(stage.tasks)}
    if strategy in ("rtma", "rmsr"):
        b = max_bucket if strategy == "rtma" else n
        buckets = rtma_buckets(stage, insts, b)
        work = norm_cost
        tasks = 0
        for bk in buckets:
            tree = build_reuse_tree(stage, bk.instances)
            res = simulate_execution(tree, 10**9)
            work += res.total_cost
            tasks += tree.unique_task_count()
        return {"work_s": work, "tasks": tasks}
    raise ValueError(strategy)


# Calibration (see fig7/table2 docstrings): working-set planes per in-flight
# stage instance / active RMSR path, implied by the paper's memory anchors
# (RTMA(2,2) @4K = 6 GB; Table II (9K, 64 GB) -> bucket 4).
PLANES_PER_INSTANCE = 47

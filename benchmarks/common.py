"""Shared benchmark utilities: measured per-task costs + cost composition.

Methodology (DESIGN.md §9): computation-reuse speedups come purely from WHICH
duplicate tasks are skipped, so makespans are composed from *measured* JAX
wall-times of the real pipeline tasks. Reuse fractions are exact analytic
counts on the reuse trie — the same accounting the paper uses.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.app import TABLE1_SPACE, synthetic_tile
from repro.app.pipeline import build_workflow
from repro.core import StageSpec, TaskSpec, Workflow, morris_trajectories
from repro.core.params import ParamSet, ParamSpace
from repro.engine import MemoryBudget, StudyPlan, plan_study

# CI smoke mode (REPRO_BENCH_SMOKE=1): modules shrink tile sizes / run
# counts so the full pipeline (plan → execute → JSON artifact) exercises in
# seconds; numbers are NOT comparable across smoke and full runs.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def measure_task_costs(h: int = 128, w: int = 128, *, repeats: int = 2) -> Dict[str, float]:
    """Wall-time each pipeline task once (jit-warmed) on a real tile."""
    wf = build_workflow(h, w)
    tile = synthetic_tile(h, w, seed=0)
    norm, seg = wf.stages
    defaults = dict(TABLE1_SPACE.default())
    costs: Dict[str, float] = {}

    state = {"raw": jnp.asarray(tile)}
    state = norm.tasks[0].fn(state)  # warm (jit compile)
    jax.block_until_ready(state["rgb"])
    t0 = time.perf_counter()
    for _ in range(repeats):
        state = norm.tasks[0].fn({"raw": jnp.asarray(tile)})
        jax.block_until_ready(state["rgb"])
    costs["normalize"] = (time.perf_counter() - t0) / repeats

    for task in seg.tasks:
        kw = {k: defaults[k] for k in task.param_names}
        out = task.fn(state, **kw)  # warm
        jax.block_until_ready(list(out.values())[0])
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = task.fn(state, **kw)
            jax.block_until_ready(list(out.values())[0])
        costs[task.name] = (time.perf_counter() - t0) / repeats
        state = out
    return costs


def moat_param_sets(n_runs: int, *, seed: int = 0, space: ParamSpace = TABLE1_SPACE) -> List[ParamSet]:
    """A MOAT study with ~n_runs runs (trajectories of dim+1 runs each)."""
    n_traj = max(1, n_runs // (space.dim + 1))
    sets, _ = morris_trajectories(space, n_traj, seed=seed)
    return sets[:n_runs]


def staged_workflow(stage: StageSpec, norm_cost: float) -> Workflow:
    """(normalization, stage) as a 2-stage engine workflow; the engine's
    upstream-signature grouping makes the parameter-free normalization run
    once under any reuse policy and per-instance under ``"none"`` — the
    paper's stage-level baseline gain, derived rather than special-cased."""
    norm = StageSpec(
        name="normalization",
        tasks=(TaskSpec("normalize", (), fn=None, cost=norm_cost, output_bytes=0),),
    )
    return Workflow(stages=(norm, stage))


def plan_strategy(
    stage: StageSpec,
    norm_cost: float,
    param_sets: Sequence[ParamSet],
    policy: str,
    *,
    max_bucket: int = 8,
    active_paths: int | None = None,
    workers: int | None = None,
    budget_bytes: int | None = None,
) -> StudyPlan:
    """Plan one reuse policy with measured task costs (no execution)."""
    return plan_study(
        staged_workflow(stage, norm_cost),
        list(param_sets),
        policy=policy,
        memory=MemoryBudget(bytes=budget_bytes),
        max_bucket_size=max_bucket if policy in ("rtma", "hybrid") else None,
        active_paths=active_paths,
        workers=workers,
    )


def strategy_work_seconds(
    stage: StageSpec,
    norm_cost: float,
    param_sets: Sequence[ParamSet],
    strategy: str,
    *,
    max_bucket: int = 8,
) -> Dict[str, float]:
    """Total work (measured-cost-weighted) + task count for one policy."""
    if strategy == "rmsr":
        strategy, max_bucket = "hybrid", len(list(param_sets))
    plan = plan_strategy(stage, norm_cost, param_sets, strategy, max_bucket=max_bucket)
    # report the merged stage's task count (the paper's accounting), not the
    # shared normalization executions
    return {"work_s": plan.work_seconds, "tasks": plan.stages[1].tasks_executed}


# Calibration (see fig7/table2 docstrings): working-set planes per in-flight
# stage instance / active RMSR path, implied by the paper's memory anchors
# (RTMA(2,2) @4K = 6 GB; Table II (9K, 64 GB) -> bucket 4).
PLANES_PER_INSTANCE = 47

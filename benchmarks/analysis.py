"""Static-analysis gate as a benchmark module (DESIGN.md §17) —
``BENCH_analysis.json``.

Runs the four-pass AST suite (lock discipline + ordering, blocking-under-
lock, wire-frame conformance, spawn/determinism) over ``src/repro`` and
reports wall time per file plus the findings tally. A tree that is not
clean under ``--strict`` semantics (any unsuppressed finding, or a stale
baseline entry) fails the module — and therefore the harness — exactly
like the CI ``analysis-smoke`` job.

Pure stdlib on purpose: this module must stay importable and runnable in
an environment with no jax/numpy, so the gate can run first and fastest.
"""

from __future__ import annotations

import time
from typing import List


def run(csv: List[str]) -> None:
    from repro.analysis import run_paths

    t0 = time.time()
    report = run_paths()
    dt = time.time() - t0
    per_file_us = dt * 1e6 / max(1, report.files)
    csv.append(
        f"analysis_strict,{per_file_us:.0f},"
        f"files={report.files}"
        f"_findings={len(report.findings)}"
        f"_suppressed={report.suppressed}"
        f"_baselined={len(report.baselined)}"
        f"_stale={len(report.stale)}"
    )
    if not report.strict_ok:
        raise AssertionError(
            "static-analysis gate failed:\n" + report.render()
        )

"""StudyPlanner engine tests: plan→bucket→schedule→dispatch, policy matrix,
multi-stage dataflow, result cache — plus the RTMA bucketing edge cases and
the min_active_paths / Manager regressions (no hypothesis dependency)."""

import threading

import pytest

from repro.core import (
    ParamSpace,
    StageSpec,
    TaskSpec,
    Workflow,
    build_reuse_tree,
    halton_sequence,
    min_active_paths,
    rmsr_schedule,
    rtma_buckets,
)
from repro.engine import (
    ClusterSpec,
    MemoryBudget,
    ResultCache,
    execute_bucket,
    execute_plan,
    plan_study,
)
from repro.runtime import Manager, WorkItem

BYTES = 100


def make_stage(name="seg", n_tasks=3, prefix="p", bytes_per_task=BYTES, track=None):
    def make_fn(i):
        def fn(x, **kw):
            if track is not None:
                track.append(f"{name}_t{i}")
            return x + sum(kw.values())

        return fn

    tasks = tuple(
        TaskSpec(
            name=f"{name}_t{i}",
            param_names=(f"{prefix}{i}",),
            fn=make_fn(i),
            cost=1.0,
            output_bytes=bytes_per_task,
        )
        for i in range(n_tasks)
    )
    return StageSpec(name=name, tasks=tasks)


def make_sets(n, n_tasks=3, card=3, prefix="p"):
    space = ParamSpace.from_dict({f"{prefix}{i}": list(range(card)) for i in range(n_tasks)})
    return space.quantise(halton_sequence(n, space.dim))


def naive_outputs(stages, sets, x0):
    out = {}
    for rid, ps in enumerate(sets):
        d = dict(ps)
        x = x0
        for stage in stages:
            for t in stage.tasks:
                x = t.fn(x, **{k: d[k] for k in t.param_names})
        out[rid] = x
    return out


class TestPlannerPolicies:
    def test_policy_counters_ordering(self):
        stage = make_stage()
        wf = Workflow(stages=(stage,))
        sets = make_sets(40)
        plans = {
            pol: plan_study(wf, sets, policy=pol, max_bucket_size=8, active_paths=2)
            for pol in ("none", "stage", "rtma", "rmsr", "hybrid")
        }
        assert plans["none"].tasks_executed == plans["none"].tasks_total
        assert plans["stage"].tasks_executed <= plans["none"].tasks_executed
        assert plans["rtma"].tasks_executed <= plans["stage"].tasks_executed
        assert plans["rmsr"].tasks_executed <= plans["rtma"].tasks_executed
        # hybrid uses RTMA's buckets: identical task count, lower/equal peak
        assert plans["hybrid"].tasks_executed == plans["rtma"].tasks_executed
        assert plans["hybrid"].peak_bytes <= plans["rtma"].peak_bytes

    def test_unknown_policy_raises(self):
        stage = make_stage()
        with pytest.raises(ValueError):
            plan_study(Workflow(stages=(stage,)), make_sets(4), policy="zigzag")

    def test_budget_solves_bucket_and_paths(self):
        stage = make_stage(n_tasks=4, bytes_per_task=BYTES)
        wf = Workflow(stages=(stage,))
        sets = make_sets(32, n_tasks=4, card=4)
        budget = 12 * BYTES
        rtma = plan_study(wf, sets, policy="rtma", memory=MemoryBudget(bytes=budget))
        assert rtma.peak_bytes <= budget
        rmsr = plan_study(wf, sets, policy="rmsr", memory=MemoryBudget(bytes=budget))
        assert rmsr.peak_bytes <= budget
        # maximal merge executes the perfect-reuse minimum
        tree = build_reuse_tree(stage, Workflow(stages=(stage,)).instantiate(sets)[stage.name])
        assert rmsr.tasks_executed == tree.unique_task_count()

    def test_cache_reservation_stays_inside_budget(self):
        """Schedule peak is solved against bytes − cache reservation, so
        live buffers + retained cache entries together fit the budget."""
        stage = make_stage(n_tasks=4, bytes_per_task=BYTES)
        wf = Workflow(stages=(stage,))
        sets = make_sets(32, n_tasks=4, card=4)
        budget = MemoryBudget(bytes=16 * BYTES, cache_bytes=1 << 30)
        assert budget.effective_cache_bytes == 2 * BYTES  # clamped to bytes/8
        plan = plan_study(wf, sets, policy="rmsr", memory=budget)
        assert plan.peak_bytes <= budget.schedule_bytes
        assert plan.peak_bytes + budget.effective_cache_bytes <= budget.bytes

    def test_param_free_stage_collapses(self):
        norm = StageSpec(
            name="norm",
            tasks=(TaskSpec("normalize", (), fn=lambda x: x * 2, cost=1.0, output_bytes=8),),
        )
        seg = make_stage()
        wf = Workflow(stages=(norm, seg))
        sets = make_sets(16)
        for pol in ("stage", "rtma", "rmsr", "hybrid"):
            plan = plan_study(wf, sets, policy=pol, max_bucket_size=4)
            assert plan.stages[0].tasks_executed == 1, pol
        # the no-reuse baseline pays normalization per run
        plan = plan_study(wf, sets, policy="none")
        assert plan.stages[0].tasks_executed == len(sets)


class TestMultiStageDataflow:
    def test_outputs_match_naive_through_stages(self):
        s0 = make_stage("a", 2, "p")
        s1 = make_stage("b", 2, "q")
        wf = Workflow(stages=(s0, s1))
        space = ParamSpace.from_dict(
            {"p0": [0, 1], "p1": [0, 1, 2], "q0": [0, 1], "q1": [0, 1, 2]}
        )
        sets = space.quantise(halton_sequence(24, space.dim))
        want = naive_outputs((s0, s1), sets, 1.0)
        for pol in ("none", "stage", "rtma", "rmsr", "hybrid"):
            res = execute_plan(plan_study(wf, sets, policy=pol, max_bucket_size=3), 1.0)
            assert res.outputs == want, pol

    def test_no_merging_across_distinct_upstream_outputs(self):
        """Stage-1 instances whose stage-0 parameters differ receive different
        inputs and must NOT be merged, even when their own params agree."""
        s0 = make_stage("a", 1, "p")
        s1 = make_stage("b", 1, "q")
        wf = Workflow(stages=(s0, s1))
        sets = [(("p0", 1), ("q0", 5)), (("p0", 2), ("q0", 5))]
        plan = plan_study(wf, sets, policy="rmsr")
        # q0 agrees, but the two runs sit in different upstream groups
        assert plan.stages[1].tasks_executed == 2
        res = execute_plan(plan, 0.0)
        assert res.outputs[0] == 6.0 and res.outputs[1] == 7.0

    def test_plan_is_input_independent(self):
        stage = make_stage()
        wf = Workflow(stages=(stage,))
        sets = make_sets(10)
        plan = plan_study(wf, sets, policy="rmsr")
        r1 = execute_plan(plan, 0.0)
        r2 = execute_plan(plan, 100.0)
        assert all(r2.outputs[k] == r1.outputs[k] + 100.0 for k in r1.outputs)


class TestExecutorDispatch:
    def test_bit_identical_across_policies_and_workers(self):
        """Acceptance: execute_plan outputs identical across the policy
        matrix and across n_workers ∈ {1, 4}."""
        stage = make_stage("seg", 4, "p")
        wf = Workflow(stages=(stage,))
        sets = make_sets(64, n_tasks=4, card=3)
        want = naive_outputs((stage,), sets, 0.0)
        for pol in ("rtma", "rmsr", "hybrid"):
            for workers in (1, 4):
                res = execute_plan(
                    plan_study(wf, sets, policy=pol, max_bucket_size=8, active_paths=2),
                    0.0,
                    cluster=ClusterSpec(n_workers=workers),
                )
                assert res.outputs == want, (pol, workers)

    def test_executed_plus_hits_covers_plan(self):
        stage = make_stage()
        wf = Workflow(stages=(stage,))
        sets = make_sets(30)
        plan = plan_study(wf, sets, policy="rtma", max_bucket_size=4)
        res = execute_plan(plan, 0.0)
        assert res.tasks_executed + res.cache_hits == plan.tasks_executed
        assert res.tasks_executed <= plan.tasks_executed

    def test_cache_disabled_for_baseline_policies(self):
        stage = make_stage()
        wf = Workflow(stages=(stage,))
        sets = make_sets(12, card=1)  # all identical: maximal sharing bait
        plan = plan_study(wf, sets, policy="none")
        res = execute_plan(plan, 0.0)
        assert res.cache_hits == 0
        assert res.tasks_executed == plan.tasks_total


class TestResultCache:
    def test_backup_replay_never_recomputes(self):
        """Re-executing a bucket (retry / straggler backup) with the shared
        cache re-runs zero tasks."""
        calls = []
        stage = make_stage(track=calls)
        wf = Workflow(stages=(stage,))
        sets = make_sets(10)
        plan = plan_study(wf, sets, policy="rmsr")
        bucket = plan.stages[0].buckets[0]
        cache = ResultCache(1 << 20)
        out1, exec1, hits1 = execute_bucket(bucket, 0.0, cache)
        n_first = len(calls)
        out2, exec2, hits2 = execute_bucket(bucket, 0.0, cache)
        assert out2 == out1
        assert exec1 == n_first and hits1 == 0
        assert exec2 == 0 and hits2 == exec1
        assert len(calls) == n_first  # no new task invocations

    def test_sibling_buckets_share_merged_prefixes(self):
        stage = make_stage()
        wf = Workflow(stages=(stage,))
        sets = make_sets(24, card=2)
        plan = plan_study(wf, sets, policy="rtma", max_bucket_size=3)
        res = execute_plan(plan, 0.0)
        # cross-bucket duplicate prefixes become hits, not recomputation
        full_tree = build_reuse_tree(
            stage, Workflow(stages=(stage,)).instantiate(sets)[stage.name]
        )
        assert res.tasks_executed == full_tree.unique_task_count()
        assert res.cache_hits == plan.tasks_executed - res.tasks_executed

    def test_byte_bound_evicts_lru(self):
        cache = ResultCache(100)
        cache.put(("a",), 1, 60)
        cache.put(("b",), 2, 60)  # evicts ("a",)
        hit_a, _ = cache.get(("a",))
        hit_b, val = cache.get(("b",))
        assert not hit_a and hit_b and val == 2

    def test_oversized_entry_not_admitted(self):
        cache = ResultCache(10)
        cache.put(("big",), 1, 100)
        hit, _ = cache.get(("big",))
        assert not hit

    def test_eviction_spills_to_store_and_rehydrates(self):
        from repro.runtime import HierarchicalStore

        store = HierarchicalStore(ram_bytes=1 << 20)
        cache = ResultCache(100, spill_store=store)
        cache.put(("a",), 1.0, 60)
        cache.put(("b",), 2.0, 60)  # evicts ("a",) -> spilled, not dropped
        assert cache.spills == 1
        hit_a, val_a = cache.get(("a",))
        assert hit_a and float(val_a) == 1.0
        assert cache.rehydrations == 1
        assert cache.hits == 1 and cache.misses == 0

    def test_oversized_entry_spills_when_store_present(self):
        from repro.runtime import HierarchicalStore

        cache = ResultCache(10, spill_store=HierarchicalStore(ram_bytes=1 << 20))
        cache.put(("big",), 7.0, 100)
        assert cache.spills == 1
        hit, val = cache.get(("big",))
        assert hit and float(val) == 7.0

    def test_flush_persists_ram_entries_to_disk(self, tmp_path):
        from repro.runtime import HierarchicalStore

        store = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        cache = ResultCache(1 << 10, spill_store=store)
        cache.put(("x",), 3.0, 8)
        cache.flush()
        # a cold cache over a re-opened store resolves the key from disk
        cold = ResultCache(
            1 << 10,
            spill_store=HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path)),
        )
        hit, val = cold.get(("x",))
        assert hit and float(val) == 3.0 and cold.rehydrations == 1

    def test_flush_also_persists_previously_evicted_entries(self, tmp_path):
        """An entry evicted into the store's RAM tier before flush() must
        still reach disk: resume would otherwise silently recompute exactly
        the entries that eviction produced."""
        from repro.runtime import HierarchicalStore

        store = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        cache = ResultCache(100, spill_store=store)
        cache.put(("a",), 1.0, 60)
        cache.put(("b",), 2.0, 60)  # evicts ("a",) -> store RAM tier only
        cache.flush()
        cold = ResultCache(
            100,
            spill_store=HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path)),
        )
        for key, want in ((("a",), 1.0), (("b",), 2.0)):
            hit, val = cold.get(key)
            assert hit and float(val) == want, key

    def test_rehydration_does_not_readmit_oversized_entries(self):
        """A deliberately-never-admitted entry (declared bytes > cap) must
        not slip into the RAM tier via a store round-trip: the declared
        byte model governs admission, not the measured payload size."""
        from repro.runtime import HierarchicalStore

        cache = ResultCache(10, spill_store=HierarchicalStore(ram_bytes=1 << 20))
        cache.put(("big",), 7.0, 100)  # spilled, never admitted
        for expect_rehydrations in (1, 2):
            hit, val = cache.get(("big",))
            assert hit and float(val) == 7.0
            assert cache.rehydrations == expect_rehydrations  # still not RAM
        assert cache._bytes == 0


class TestRTMAEdgeCases:
    def test_max_bucket_size_one(self):
        stage = make_stage()
        insts = Workflow(stages=(stage,)).instantiate(make_sets(9))[stage.name]
        buckets = rtma_buckets(stage, insts, 1)
        assert len(buckets) == 9
        assert all(len(b.instances) == 1 for b in buckets)
        rids = sorted(i.run_id for b in buckets for i in b.instances)
        assert rids == list(range(9))  # exact partition

    def test_all_identical_instances_single_leaf(self):
        stage = make_stage()
        sets = make_sets(10, card=1)  # every run identical -> one trie leaf
        insts = Workflow(stages=(stage,)).instantiate(sets)[stage.name]
        buckets = rtma_buckets(stage, insts, 4)
        sizes = sorted(len(b.instances) for b in buckets)
        assert sizes == [2, 4, 4]
        rids = sorted(i.run_id for b in buckets for i in b.instances)
        assert rids == list(range(10))

    def test_partial_root_bucket(self):
        stage = make_stage(n_tasks=1)
        # disjoint single-param instances: no sharing anywhere, leftovers
        # bubble to the root and form one final under-full bucket
        sets = [(("p0", i),) for i in range(7)]
        insts = Workflow(stages=(stage,)).instantiate(sets)[stage.name]
        buckets = rtma_buckets(stage, insts, 3)
        sizes = [len(b.instances) for b in buckets]
        assert sum(sizes) == 7
        assert all(s <= 3 for s in sizes)
        assert sum(1 for s in sizes if s < 3) == 1  # exactly one partial bucket
        rids = sorted(i.run_id for b in buckets for i in b.instances)
        assert rids == list(range(7))


class TestMinActivePathsRegression:
    def test_exact_not_power_of_two(self):
        """The doubling search used to return only powers of two; the binary
        search must find the true largest fitting active_paths."""
        stage = make_stage(n_tasks=4, bytes_per_task=BYTES)
        sets = make_sets(64, n_tasks=4, card=4)
        insts = Workflow(stages=(stage,)).instantiate(sets)[stage.name]
        tree = build_reuse_tree(stage, insts)
        n_leaves = len(tree.leaves())
        peaks = {p: rmsr_schedule(tree, p).peak_bytes for p in range(1, n_leaves + 1)}
        probed_budgets = sorted(set(peaks.values()))
        assert any(
            max(p for p in peaks if peaks[p] <= b) not in (1, 2, 4, 8, 16, 32, 64)
            for b in probed_budgets
        ), "test vector too weak: every answer is a power of two"
        for budget in probed_budgets:
            want = max(p for p in peaks if peaks[p] <= budget)
            assert min_active_paths(tree, budget) == want, budget

    def test_below_minimum_returns_none(self):
        stage = make_stage()
        insts = Workflow(stages=(stage,)).instantiate(make_sets(8))[stage.name]
        tree = build_reuse_tree(stage, insts)
        assert min_active_paths(tree, 0) is None

    def test_huge_budget_returns_leaf_count(self):
        stage = make_stage()
        insts = Workflow(stages=(stage,)).instantiate(make_sets(11, card=4))[stage.name]
        tree = build_reuse_tree(stage, insts)
        assert min_active_paths(tree, 10**12) == len(tree.leaves())


class TestManagerRaceRegression:
    def test_no_premature_exit_under_contention(self):
        """The empty-queue/empty-running window between dequeue and lease
        registration used to let workers exit early; dequeue+lease are now
        atomic, so every run must return all results."""
        for trial in range(30):
            mgr = Manager(enable_backup_tasks=False)
            n = 60
            for i in range(n):
                mgr.submit(WorkItem(key=f"k{i}", fn=lambda i=i: i))
            out = mgr.run(8, expected=n)
            assert len(out) == n, f"trial {trial}: premature exit, {len(out)}/{n}"

    def test_retry_not_dropped_at_idle_check(self):
        """A failing item re-enqueued by a peer must be seen by idling
        workers (resubmit happens under the same lock as lease release)."""
        attempts = {"n": 0}
        lock = threading.Lock()

        def flaky():
            with lock:
                attempts["n"] += 1
                if attempts["n"] < 3:
                    raise RuntimeError("transient")
            return "ok"

        for _ in range(10):
            attempts["n"] = 0
            mgr = Manager(max_attempts=5, enable_backup_tasks=False)
            mgr.submit(WorkItem(key="flaky", fn=flaky))
            for i in range(4):
                mgr.submit(WorkItem(key=f"pad{i}", fn=lambda: "p"))
            out = mgr.run(6, expected=5)
            assert out["flaky"] == "ok"

"""SA-as-a-service suite (DESIGN.md §18, ISSUE 10).

Acceptance scenario and unit coverage for the multi-tenant study server:

* **bit-identical** — a job's objective vector equals the naive oracle
  computed outside the service (exact integer workloads, `==` not ≈);
* **executes once** — two tenants submitting equal-signature specs
  concurrently share one execution (combined dispatch < sum asserted);
* **cross-tenant reuse** — an overlapping later spec reuses the shared
  ResultCache (fewer misses than a standalone run of the same plan);
* **cancellation** — cancelling one tenant's job mid-study frees its
  queued work without perturbing the other tenant's results;
* **fair share** — a low-weight tenant's small job completes while a
  heavy tenant's backlog is still draining (monotonic progress, no
  starvation), plus FairQueue unit laws;
* **quotas, wire protocol, timeouts, idle-pool accounting.**
"""

import threading
import time

import pytest

from repro.core.params import ParamSpace
from repro.engine import ClusterSpec, ResultCache, execute_study, plan_study
from repro.engine.streaming import study_task_keys
from repro.runtime import Manager, WorkItem
from repro.runtime.fairshare import FairQueue
from repro.service import (
    QuotaExceeded,
    ServiceClient,
    ServiceError,
    SpecError,
    StudyServer,
    StudySpec,
    TenantQuota,
)

from study_gen import naive_outputs, sleep_workflow, workflow_from_layout

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")


# ---------------------------------------------------------------------------
# Fixtures: fast integer-mixing service + a sleepy one for race windows
# ---------------------------------------------------------------------------

_LAYOUT = [
    [("s0t0", ("a",), 1.0, 64), ("s0t1", ("b",), 1.0, 64)],
    [("s1t0", ("c", "d"), 1.0, 64)],
]
_SPACE = ParamSpace.from_dict(
    {"a": [0, 1, 2], "b": [0, 1, 2], "c": [0, 1], "d": [0, 1, 2]}
)
_INPUTS = [3, 8]

_SLEEP_SPACE = ParamSpace.from_dict(
    {"sp0": [0, 1, 2, 3], "sp1": [0, 1, 2, 3]}
)


def _int_objective(leaf, input_index):
    return float(leaf % 997)


def _oracle_objective(workflow, runs, inputs):
    """Expected per-run objective vector, straight-line, outside the
    engine entirely."""
    per_input = [naive_outputs(workflow, runs, x) for x in inputs]
    return [
        sum(_int_objective(per_input[i][rid], i) for i in range(len(inputs)))
        / len(inputs)
        for rid in range(len(runs))
    ]


@pytest.fixture
def server():
    srv = StudyServer(
        workflow=workflow_from_layout(_LAYOUT),
        space=_SPACE,
        inputs=_INPUTS,
        objective=_int_objective,
        n_workers=2,
    )
    yield srv
    srv.close()


@pytest.fixture
def sleepy_server():
    srv = StudyServer(
        workflow=sleep_workflow([0.03, 0.03]),
        space=_SLEEP_SPACE,
        inputs=[5],
        objective=_int_objective,
        n_workers=2,
    )
    yield srv
    srv.close()


# ---------------------------------------------------------------------------
# StudySpec: validation, wire form, signature semantics
# ---------------------------------------------------------------------------


def test_spec_validation_rejects_bad_specs():
    for bad in [
        StudySpec(sampler="nope"),
        StudySpec(sampler="explicit", param_sets=None),
        StudySpec(policy="nope"),
        StudySpec(priority=99),
        StudySpec(sampler="moat", n_trajectories=0),
        StudySpec(bounds={"ghost": [1]}),
        StudySpec(bounds={"a": []}),
        StudySpec(sampler="explicit", param_sets=[{"ghost": 1}]),
        StudySpec(sampler="grid", names=["ghost"]),
    ]:
        with pytest.raises(SpecError):
            bad.resolve(_SPACE)


def test_spec_wire_form_roundtrip_and_unknown_fields():
    spec = StudySpec(
        sampler="grid", names=["a", "c"], bounds={"a": [0, 2]}, priority=3
    )
    assert StudySpec.from_json(spec.to_json()) == spec
    with pytest.raises(SpecError):
        StudySpec.from_json({"sampler": "grid", "warp_speed": 9})


def test_spec_resolution_fills_defaults_and_caps_runs():
    runs = StudySpec(
        sampler="explicit", param_sets=[{"a": 2}, {"a": 1, "d": 2}]
    ).resolve(_SPACE)
    defaults = dict(_SPACE.default())
    assert dict(runs[0])["a"] == 2
    assert dict(runs[0])["b"] == defaults["b"]
    assert dict(runs[1])["d"] == 2
    # grid over bounded sub-space
    grid = StudySpec(sampler="grid", names=["a", "c"], bounds={"a": [0, 1]})
    assert len(grid.resolve(_SPACE)) == 2 * 2
    # the run-count guardrail fires before anything is planned
    wide = ParamSpace.from_dict({f"w{i}": list(range(10)) for i in range(5)})
    with pytest.raises(SpecError):
        StudySpec(sampler="grid").resolve(wide)


def test_spec_signature_content_addressing():
    base = StudySpec(sampler="grid", names=["a", "b"])
    same_work = StudySpec(
        sampler="grid", names=["a", "b"], priority=5, timeout_s=9.0,
        metrics=["objective", "per_input"], poll_s=1.0,
    )
    # dispatch-only fields do not change WHAT is computed
    assert base.signature(_SPACE) == same_work.signature(_SPACE)
    for different in [
        StudySpec(sampler="grid", names=["a", "c"]),
        StudySpec(sampler="grid", names=["a", "b"], policy="rmsr"),
        StudySpec(sampler="grid", names=["a", "b"], bounds={"a": [0, 1]}),
        StudySpec(sampler="grid", names=["a", "b"], max_bucket_size=2),
    ]:
        assert base.signature(_SPACE) != different.signature(_SPACE)
    # explicit spec listing the same runs the grid denotes = same work
    grid_runs = base.resolve(_SPACE)
    explicit = StudySpec(
        sampler="explicit", param_sets=[dict(ps) for ps in grid_runs]
    )
    assert explicit.signature(_SPACE) == base.signature(_SPACE)


# ---------------------------------------------------------------------------
# The job API: results bit-identical to the oracle
# ---------------------------------------------------------------------------


def test_job_lifecycle_and_bit_identical_objective(server):
    spec = StudySpec(
        sampler="explicit",
        param_sets=[{"a": 0, "b": 1}, {"a": 2, "c": 1, "d": 2}, {}],
        metrics=["objective", "per_input"],
    )
    job = server.submit("alice", spec)
    assert job == "alice/j0"
    snap = server.result(job, wait=True, timeout=120)
    assert snap["state"] == "DONE"
    assert snap["done_tasks"] == snap["total_tasks"] > 0

    runs = spec.resolve(_SPACE)
    expected = _oracle_objective(workflow_from_layout(_LAYOUT), runs, _INPUTS)
    assert snap["result"]["objective"] == expected  # exact, not approx
    assert len(snap["result"]["per_input"]) == len(runs)
    assert snap["result"]["n_inputs"] == len(_INPUTS)
    # the registry released every key at job end
    assert server.registry.stats()["live_keys"] == 0
    jobs = server.list_jobs("alice")
    assert [j["job_id"] for j in jobs] == [job]


def test_identical_specs_execute_once_combined_lt_sum(sleepy_server):
    srv = sleepy_server
    mgr = srv.manager
    # Baseline: one tenant alone, a same-shape different-signature spec.
    warm = StudySpec(sampler="grid", bounds={"sp0": [0, 1], "sp1": [0, 1]})
    d0 = sum(mgr.dispatch_counts.values())
    assert srv.result(
        srv.submit("alice", warm), wait=True, timeout=120
    )["state"] == "DONE"
    single = sum(mgr.dispatch_counts.values()) - d0
    assert single > 0

    # Two tenants, equal signature, concurrent: one execution, two jobs.
    spec = StudySpec(sampler="grid", bounds={"sp0": [2, 3], "sp1": [2, 3]})
    d1 = sum(mgr.dispatch_counts.values())
    ja = srv.submit("alice", spec)
    jb = srv.submit("bob", spec)
    ra = srv.result(ja, wait=True, timeout=120)
    rb = srv.result(jb, wait=True, timeout=120)
    combined = sum(mgr.dispatch_counts.values()) - d1
    assert ra["state"] == "DONE" and rb["state"] == "DONE"
    assert ra["result"]["objective"] == rb["result"]["objective"]
    assert ra["signature"] == rb["signature"]
    # the tentpole claim: combined tasks < sum of independent submissions
    assert combined < 2 * single, (combined, single)


def test_overlapping_specs_reuse_shared_cache(server):
    rows_a = [{"a": i, "b": 0} for i in range(3)]
    rows_b = [{"a": i, "b": 0} for i in range(2)] + [{"a": 0, "b": 1}]
    spec_a = StudySpec(sampler="explicit", param_sets=rows_a)
    spec_b = StudySpec(sampler="explicit", param_sets=rows_b)
    assert spec_a.signature(_SPACE) != spec_b.signature(_SPACE)

    assert server.result(
        server.submit("alice", spec_a), wait=True, timeout=120
    )["state"] == "DONE"
    misses_before = server.cache.misses
    hits_before = server.cache.hits
    rb = server.result(server.submit("bob", spec_b), wait=True, timeout=120)
    assert rb["state"] == "DONE"
    service_misses = server.cache.misses - misses_before

    # Standalone: the same plan against a COLD cache.
    runs_b = spec_b.resolve(_SPACE)
    plan_b = plan_study(
        server.workflow, runs_b, cluster=server.cluster,
        policy=spec_b.policy, active_paths=spec_b.active_paths,
    )
    cold = ResultCache(1 << 20)
    stream = execute_study(
        plan_b, _INPUTS, cluster=ClusterSpec(n_workers=2), cache=cold,
        input_keys=server.input_keys,
    )
    # bit-identical across the reuse boundary, and cheaper than standalone
    assert rb["result"]["objective"] == _oracle_objective(
        server.workflow, runs_b, _INPUTS
    )
    assert stream.cache_misses == cold.misses
    assert service_misses < cold.misses, (service_misses, cold.misses)
    assert server.cache.hits > hits_before


# ---------------------------------------------------------------------------
# Cancellation: frees the pool without perturbing the other tenant
# ---------------------------------------------------------------------------


def test_cancel_mid_study_leaves_other_tenant_unperturbed(sleepy_server):
    srv = sleepy_server
    big = StudySpec(sampler="grid")  # 16 runs of sleepy tasks
    small = StudySpec(
        sampler="explicit",
        param_sets=[{"sp0": 0, "sp1": 0}, {"sp0": 1, "sp1": 1}],
    )
    ja = srv.submit("hog", big)
    jb = srv.submit("mouse", small)
    # let the big job actually get airborne, then revoke it
    deadline = time.monotonic() + 30
    while srv.status(ja)["state"] == "QUEUED":
        assert time.monotonic() < deadline
        time.sleep(0.005)
    time.sleep(0.05)
    cancelled_snap = srv.cancel(ja)
    assert cancelled_snap["state"] in ("RUNNING", "CANCELLED")

    ra = srv.result(ja, wait=True, timeout=60)
    rb = srv.result(jb, wait=True, timeout=120)
    assert ra["state"] == "CANCELLED"
    assert ra["result"] is None
    # the other tenant's study is untouched — exact oracle agreement
    assert rb["state"] == "DONE"
    assert rb["result"]["objective"] == _oracle_objective(
        srv.workflow, small.resolve(_SLEEP_SPACE), [5]
    )
    # cancel is idempotent
    assert srv.cancel(ja)["state"] == "CANCELLED"
    # the pool is actually free: no pending backlog, refs all released
    deadline = time.monotonic() + 10
    while srv.manager.scheduler_stats()["tenant_depths"]:
        assert time.monotonic() < deadline, "queued work never freed"
        time.sleep(0.02)
    assert srv.registry.stats()["live_keys"] == 0
    assert srv.manager.scheduler_stats()["cancelled"] > 0


def test_timeout_cancels_job(sleepy_server):
    spec = StudySpec(sampler="grid", timeout_s=0.15)
    job = sleepy_server.submit("t", spec)
    snap = sleepy_server.result(job, wait=True, timeout=60)
    assert snap["state"] == "CANCELLED"


# ---------------------------------------------------------------------------
# Fair share: the low-weight tenant still progresses
# ---------------------------------------------------------------------------


def test_fair_share_small_tenant_finishes_under_heavy_backlog(sleepy_server):
    srv = sleepy_server
    srv.set_tenant_weight("hog", 1.0)
    srv.set_tenant_weight("mouse", 0.25)
    # three distinct-signature grid jobs = a real backlog for the hog
    hog_jobs = [
        srv.submit("hog", StudySpec(sampler="grid")),
        srv.submit("hog", StudySpec(sampler="grid", bounds={"sp0": [0, 1, 2]})),
        srv.submit("hog", StudySpec(sampler="grid", bounds={"sp1": [1, 2, 3]})),
    ]
    mouse_job = srv.submit(
        "mouse",
        StudySpec(
            sampler="explicit",
            param_sets=[{"sp0": 0, "sp1": 0}, {"sp0": 3, "sp1": 3}],
        ),
    )
    rm = srv.result(mouse_job, wait=True, timeout=120)
    assert rm["state"] == "DONE"
    # monotonic progress: the mouse's 2 runs finished while (or before)
    # the hog's ~48-run backlog drained — never starved behind it
    hogs = [srv.result(j, wait=True, timeout=240) for j in hog_jobs]
    assert all(r["state"] == "DONE" for r in hogs)
    assert rm["finished_at"] <= max(r["finished_at"] for r in hogs)
    dispatch = srv.manager.scheduler_stats()["tenant_dispatch"]
    assert dispatch.get("mouse", 0) > 0 and dispatch.get("hog", 0) > 0


def test_fairqueue_unit_laws():
    class Item:
        def __init__(self, key, tenant="", priority=0):
            self.key, self.tenant, self.priority = key, tenant, priority

    # single tenant degenerates to exact FIFO
    q = FairQueue()
    for i in range(5):
        q.append(Item(f"k{i}"))
    assert [q.popleft().key for _ in range(5)] == [f"k{i}" for i in range(5)]

    # equal weights interleave 1:1
    q = FairQueue()
    for i in range(6):
        q.append(Item(f"a{i}", "A"))
    for i in range(6):
        q.append(Item(f"b{i}", "B"))
    order = [q.popleft().tenant for _ in range(12)]
    for window in range(0, 12, 2):
        assert set(order[window:window + 2]) == {"A", "B"}, order

    # 2:1 weight drains twice as fast, low weight still progresses
    q = FairQueue()
    q.set_weight("A", 2.0)
    q.set_weight("B", 0.25)
    for i in range(12):
        q.append(Item(f"a{i}", "A"))
    for i in range(3):
        q.append(Item(f"b{i}", "B"))
    order = [q.popleft().tenant for _ in range(15)]
    assert order.index("B") <= 8  # no starvation
    assert order.count("A") == 12 and order.count("B") == 3

    # priority beats FIFO within one tenant
    q = FairQueue()
    q.append(Item("lo", "T", priority=0))
    q.append(Item("hi", "T", priority=5))
    assert q.popleft().key == "hi"

    # appendleft refunds the spent deficit; remove_keys purges exactly
    q = FairQueue()
    for i in range(4):
        q.append(Item(f"x{i}", "X"))
    head = q.popleft()
    q.appendleft(head)
    assert q.popleft().key == head.key
    assert q.remove_keys({"x1", "x3"}) == 2  # x0 already consumed
    assert len(q) == 1
    assert q.depths() == {"X": 1}


# ---------------------------------------------------------------------------
# Quotas
# ---------------------------------------------------------------------------


def test_quota_rejection_is_atomic(sleepy_server):
    srv = sleepy_server
    srv.set_tenant_quota("q", TenantQuota(max_live_jobs=1))
    j0 = srv.submit("q", StudySpec(sampler="grid"))
    with pytest.raises(QuotaExceeded):
        srv.submit("q", StudySpec(sampler="grid", bounds={"sp0": [0]}))
    srv.cancel(j0)
    assert srv.result(j0, wait=True, timeout=60)["state"] == "CANCELLED"
    # terminal jobs free their live-job slot
    j2 = srv.submit("q", StudySpec(sampler="grid", bounds={"sp0": [0]}))
    assert srv.result(j2, wait=True, timeout=120)["state"] == "DONE"

    srv.set_tenant_quota("tiny", TenantQuota(max_live_tasks=1))
    with pytest.raises(QuotaExceeded):
        srv.submit("tiny", StudySpec(sampler="grid"))
    # other tenants are not affected by 'tiny's budget
    j3 = srv.submit("other", StudySpec(sampler="grid", bounds={"sp1": [1]}))
    assert srv.result(j3, wait=True, timeout=120)["state"] == "DONE"


def test_study_task_keys_matches_execution_exactly(server):
    """The registry's admission-time key list is exactly the key set the
    executor submits (quota accounting and cancellation both hang off
    this equality)."""
    spec = StudySpec(sampler="explicit", param_sets=[{"a": 1}, {"b": 2}])
    runs = spec.resolve(_SPACE)
    plan = plan_study(
        server.workflow, runs, cluster=server.cluster, policy=spec.policy,
        active_paths=spec.active_paths,
    )
    keys = study_task_keys(plan, len(_INPUTS), "svc:x:")
    assert len(keys) == len(set(keys))
    mgr = Manager()
    mgr.start(2)
    try:
        execute_study(
            plan, _INPUTS, manager=mgr, key_prefix="svc:x:",
            input_keys=server.input_keys,
        )
        # every submitted key was enumerated, nothing extra
        assert set(mgr.results()) == set()  # executor forgets on exit
        dispatched = sum(mgr.dispatch_counts.values())
        assert dispatched <= len(keys)
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------


def test_wire_client_roundtrip(server):
    addr = server.serve_background("127.0.0.1:0")
    alice = ServiceClient(addr, "alice")
    bob = ServiceClient(addr, "bob")
    try:
        spec = StudySpec(
            sampler="explicit", param_sets=[{"a": 1}, {"c": 1}]
        )
        job = alice.submit(spec)
        snap = alice.status(job)
        assert snap["tenant"] == "alice"
        res = alice.result(job, timeout=120, poll_s=0.02)
        assert res["state"] == "DONE"
        assert res["result"]["objective"] == _oracle_objective(
            server.workflow, spec.resolve(_SPACE), _INPUTS
        )
        # bob sees only his own jobs unless he asks for all
        assert bob.list_jobs() == []
        assert [j["job_id"] for j in bob.list_jobs(all_tenants=True)] == [job]
        bob.set_tenant_weight(2.0)
        stats = bob.server_stats()
        assert stats["registry"]["jobs"] == 1
        assert "scheduler" in stats and "cache" in stats

        # error frames surface as ServiceError, connection stays usable
        with pytest.raises(ServiceError):
            alice.status("alice/ghost")
        with pytest.raises(ServiceError):
            alice.submit(StudySpec(sampler="grid", names=["ghost"]))
        assert alice.status(job)["state"] == "DONE"
    finally:
        alice.close()
        bob.close()


def test_wire_cancel_and_quota_over_socket(sleepy_server):
    addr = sleepy_server.serve_background("127.0.0.1:0")
    sleepy_server.set_tenant_quota("w", TenantQuota(max_live_jobs=1))
    client = ServiceClient(addr, "w")
    try:
        job = client.submit(StudySpec(sampler="grid"))
        with pytest.raises(ServiceError) as err:
            client.submit(StudySpec(sampler="grid", bounds={"sp0": [0]}))
        assert "QuotaExceeded" in str(err.value)
        snap = client.cancel(job)
        assert snap["state"] in ("RUNNING", "CANCELLED", "QUEUED")
        assert client.result(job, timeout=60)["state"] == "CANCELLED"
    finally:
        client.close()


def test_submit_rejects_bad_tenant_and_closed_server():
    srv = StudyServer(
        workflow=workflow_from_layout(_LAYOUT),
        space=_SPACE,
        inputs=_INPUTS,
        objective=_int_objective,
        n_workers=1,
    )
    with pytest.raises(SpecError):
        srv.submit("", StudySpec(sampler="grid", names=["a"]))
    with pytest.raises(SpecError):
        srv.submit("a/b", StudySpec(sampler="grid", names=["a"]))
    srv.close()
    with pytest.raises(RuntimeError):
        srv.submit("alice", StudySpec(sampler="grid", names=["a"]))


# ---------------------------------------------------------------------------
# Idle-pool accounting (ISSUE 10 satellite): parked pumps, honest stats
# ---------------------------------------------------------------------------


def test_idle_pool_parks_and_stats_report_active_wall():
    mgr = Manager()
    mgr.start(2)
    try:
        done = threading.Event()
        mgr.submit(
            WorkItem(key="w0", fn=lambda: 1, callback=lambda k, v: done.set())
        )
        assert done.wait(30)
        mgr.drain()
        time.sleep(0.4)  # a multi-job lifetime's idle gap
        stats = mgr.scheduler_stats()
        # the pump parked for (nearly) the whole idle window instead of
        # spinning, and idle time is excluded from the occupancy base
        assert stats["pump_parked_seconds"] > 0.25
        assert stats["active_wall_seconds"] < stats["wall_seconds"]
        assert 0.0 <= stats["worker_idle_fraction"] <= 1.0
        assert stats["pump_occupancy"] <= 1.5  # sane against ACTIVE wall

        # a second job after the idle gap still executes immediately
        t0 = time.monotonic()
        mgr.submit(WorkItem(key="w1", fn=lambda: 2))
        mgr.drain()
        assert time.monotonic() - t0 < 5.0
        assert mgr.results()["w1"] == 2
        parked_after = mgr.scheduler_stats()["pump_parked_seconds"]
        assert parked_after >= stats["pump_parked_seconds"] - 1e-6
    finally:
        mgr.close()


def test_idle_pool_parks_hierarchical_subpumps():
    mgr = Manager(hierarchy=2)
    mgr.start(4)
    try:
        for i in range(8):
            mgr.submit(WorkItem(key=f"k{i}", fn=lambda i=i: i * 3))
        mgr.drain()
        time.sleep(0.35)
        stats = mgr.scheduler_stats()
        assert stats["mode"] == "hierarchical"
        assert len(stats["sub_parked_seconds"]) == 2
        assert all(p >= 0.0 for p in stats["sub_parked_seconds"])
        assert sum(stats["sub_parked_seconds"]) > 0.2
        assert mgr.results() == {f"k{i}": i * 3 for i in range(8)}
    finally:
        mgr.close()

"""The process backend's fast-path mechanisms (ISSUE 6, DESIGN.md §14).

The four flag-gated optimizations — batched control-plane frames, warm
plan caches, shared-memory result handoff, async store commits — are
transport optimizations, never approximations. This suite pins the claims
the conformance suite (`tests/test_worker_backend.py`, which runs with all
flags at their shipping defaults) does not isolate:

* the ``"process[...]"`` flag-spec grammar (`process_flag_kwargs`);
* the shm codec round-trips arbitrary array trees **bit-identically**
  (dtype, shape, bytes) and refuses — returns None, never corrupts —
  anything only pickle can carry;
* batched frames change framing, not settlement: exactly-once callbacks
  across batch boundaries, with batching provably exercised;
* a SIGKILLed worker holding a mid-batch backlog loses nothing — its
  inflight leases re-enqueue to survivors and the store is never torn;
* ``barrier()`` is the async-commit durability point: after ``drain()``,
  a FRESH store mount on the directory resolves every committed key.

Helpers are module-level so they pickle across the spawn boundary.
"""

import os
import pathlib
import random
import signal
import threading
import time

import numpy as np
import pytest

from repro.engine import execute_study, plan_study
from repro.runtime import Manager, ProcessRpcBackend, WorkItem
from repro.runtime.storage import SharedStore
from repro.runtime.transport import process_flag_kwargs, shm_decode, shm_encode

from study_gen import (
    mix_study_build,
    random_layout,
    random_param_sets,
    workflow_from_layout,
)

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")


# ---------------------------------------------------------------------------
# Spawn-picklable task functions
# ---------------------------------------------------------------------------


def _quick(tag):
    return f"q-{tag}"


def _array_of(seed):
    # deterministic array payload: exercises shm/inline staging end to end
    return {"x": np.random.default_rng(seed).standard_normal((8, 8)), "seed": seed}


def _hang_until_killed(marker_dir):
    marker = pathlib.Path(marker_dir) / "pid"
    if not marker.exists():
        marker.write_text(str(os.getpid()))
        time.sleep(60.0)
        return "hung"
    return "fast"


def _mk(tmp_path, n_workers=2, *, backend_kwargs=None, **mgr_kwargs):
    mgr = Manager(
        backend=ProcessRpcBackend(
            store_dir=str(tmp_path / "store"),
            heartbeat_interval=0.05,
            **(backend_kwargs or {}),
        ),
        **mgr_kwargs,
    )
    mgr.start(n_workers)
    return mgr


# ---------------------------------------------------------------------------
# Flag-spec grammar
# ---------------------------------------------------------------------------


def test_flag_spec_defaults_all_on():
    # bare "process" adds nothing: the constructor defaults (all ON) rule
    assert process_flag_kwargs("process") == {}
    assert process_flag_kwargs("process[]") == {}
    assert process_flag_kwargs("process[all]") == {
        "batch_frames": True,
        "warm_plans": True,
        "shm_results": True,
        "async_commit": True,
    }


def test_flag_spec_none_and_single_enables():
    none = process_flag_kwargs("process[none]")
    assert none == {
        "batch_frames": False,
        "warm_plans": False,
        "shm_results": False,
        "async_commit": False,
    }
    only_batch = process_flag_kwargs("process[none,batch]")
    assert only_batch["batch_frames"] is True
    assert not (
        only_batch["warm_plans"]
        or only_batch["shm_results"]
        or only_batch["async_commit"]
    )


def test_flag_spec_minus_disables_and_tunables_parse():
    kw = process_flag_kwargs("process[-async,max_batch=4,max_delay_ms=0.5]")
    assert kw["async_commit"] is False
    # untouched flags stay on the constructor defaults (absent = ON)
    assert "batch_frames" not in kw and "warm_plans" not in kw
    assert kw["max_batch"] == 4 and type(kw["max_batch"]) is int
    assert kw["max_delay_ms"] == 0.5
    assert process_flag_kwargs("process[shm_max_bytes=1024]")["shm_max_bytes"] == 1024


def test_flag_spec_rejects_unknown_tokens():
    for bad in ("process[turbo]", "process[-nope]", "process[max_batch=x]",
                "process[unknown=1]", "thread"):
        with pytest.raises(ValueError):
            process_flag_kwargs(bad)


# ---------------------------------------------------------------------------
# shm codec: bit-identical round trips, safe refusals
# ---------------------------------------------------------------------------

_DTYPES = ["f4", "f8", "i4", "i8", "u1", "b1", "c8"]


def _random_tree(rng, depth=0):
    roll = rng.random()
    if depth >= 2 or roll < 0.45:
        dt = np.dtype(rng.choice(_DTYPES))
        shape = tuple(rng.randint(0, 4) for _ in range(rng.randint(0, 3)))
        a = np.asarray(np.random.default_rng(rng.randint(0, 10**9)).random(shape))
        # 0-d stays a true ndarray: the codec (like the npz store path)
        # canonicalises numpy scalars to 0-d arrays, so feed it arrays
        return np.asarray((a * 100).astype(dt))
    if roll < 0.6:
        return rng.choice([None, True, 7, -1.5, "s", b"b", 2 + 3j, np.float64(0.1)])
    if roll < 0.75:
        return [_random_tree(rng, depth + 1) for _ in range(rng.randint(0, 3))]
    if roll < 0.9:
        return tuple(_random_tree(rng, depth + 1) for _ in range(rng.randint(0, 3)))
    return {
        rng.choice(["k", 3, (1, "t"), b"kb"]): _random_tree(rng, depth + 1)
        for _ in range(rng.randint(0, 3))
    }


def _trees_identical(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (
            isinstance(a, np.ndarray)
            and isinstance(b, np.ndarray)
            and a.dtype == b.dtype
            and a.shape == b.shape
            and a.tobytes() == b.tobytes()  # bit-level, nan-proof
        )
    if isinstance(a, dict):
        return (
            isinstance(b, dict)
            and a.keys() == b.keys()
            and all(_trees_identical(a[k], b[k]) for k in a)
        )
    if isinstance(a, (list, tuple)):
        return (
            type(a) is type(b)
            and len(a) == len(b)
            and all(_trees_identical(x, y) for x, y in zip(a, b))
        )
    return type(a) is type(b) and a == b


def test_shm_roundtrip_property_bit_identical():
    rng = random.Random(1406)
    done = 0
    for i in range(40):
        tree = {"root": _random_tree(rng), "pin": np.arange(6, dtype=np.int32)}
        desc = shm_encode(tree, f"rtf_test_rt_{os.getpid()}_{i}", max_bytes=1 << 20)
        assert desc is not None  # "pin" guarantees an array leaf
        out = shm_decode(desc)
        assert _trees_identical(out, tree)
        done += 1
    assert done == 40


def test_shm_roundtrip_nan_inf_and_dtype_extremes():
    tree = {
        "nan": np.array([np.nan, -np.inf, np.inf, 0.0]),
        "big": np.array([2**62], dtype=np.int64),
        "empty": np.empty((0, 3), dtype=np.float32),
        "scalar0d": np.array(3.5, dtype=np.float16),
    }
    desc = shm_encode(tree, f"rtf_test_edge_{os.getpid()}", max_bytes=1 << 20)
    out = shm_decode(desc)
    assert _trees_identical(out, tree)


def test_shm_decode_unlinks_the_segment():
    from multiprocessing import shared_memory

    name = f"rtf_test_unlink_{os.getpid()}"
    desc = shm_encode({"a": np.ones(4)}, name, max_bytes=1 << 20)
    assert desc is not None
    shm_decode(desc)
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name)


def test_shm_refuses_what_only_pickle_can_carry():
    name = f"rtf_test_refuse_{os.getpid()}"
    # object dtype, custom objects, structured dtypes: fall back (None)
    assert shm_encode({"o": np.array([{"x": 1}], dtype=object)}, name,
                      max_bytes=1 << 20) is None
    assert shm_encode({"f": lambda: 0}, name, max_bytes=1 << 20) is None
    assert shm_encode(
        {"s": np.zeros(2, dtype=np.dtype([("x", "i4")]))}, name, max_bytes=1 << 20
    ) is None
    # no arrays at all: the frame itself is cheaper
    assert shm_encode({"n": 1, "s": "x"}, name, max_bytes=1 << 20) is None
    # over budget: fall back rather than fill /dev/shm
    assert shm_encode({"a": np.zeros(1024)}, name, max_bytes=64) is None
    # and none of the refusals may leak a segment
    from multiprocessing import shared_memory

    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name)


# ---------------------------------------------------------------------------
# Batched frames: framing changes, settlement does not
# ---------------------------------------------------------------------------


def test_batch_boundary_exactly_once_callbacks(tmp_path):
    """30 tasks through 2 workers with max_batch=4: leases and completions
    cross in multi-entry frames, yet every callback fires exactly once with
    the right value — batching is invisible to the lease table."""
    calls = {}
    lock = threading.Lock()

    def cb(key, value):
        with lock:
            calls.setdefault(key, []).append(value)

    mgr = _mk(
        tmp_path, 2,
        backend_kwargs={"max_batch": 4, "max_delay_ms": 1.0},
        enable_backup_tasks=False,
    )
    try:
        for i in range(30):
            mgr.submit(
                WorkItem(key=f"k{i}", spec=("call", _quick, (i,), {}), callback=cb)
            )
        mgr.drain()
        out = mgr.results()
        for i in range(30):
            assert out[f"k{i}"] == f"q-{i}"
            assert calls[f"k{i}"] == [f"q-{i}"], "callback not exactly-once"
        stats = mgr.backend.stats()
        assert stats["leader"]["lease_batches"] >= 1, "batching never engaged"
        assert stats["leader"]["comp_batches"] >= 1
        assert mgr.backend.slots_per_worker == 4
    finally:
        mgr.close()


def test_sigkill_mid_batch_survivor_completes_and_store_is_never_torn(tmp_path):
    """The victim worker holds a batched backlog (the hang + queued pads)
    when it is SIGKILLed. Dead-worker expiry must re-enqueue every inflight
    lease of the batch to the survivor, results must all arrive, and after
    drain()'s barrier every committed store entry must resolve from a
    FRESH mount — an interrupted async commit may lose a staged entry (the
    retry recomputes it) but can never corrupt the store."""
    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    mgr = _mk(
        tmp_path, 2,
        backend_kwargs={"max_batch": 8},
        enable_backup_tasks=False, max_attempts=3,
    )
    try:
        mgr.submit(
            WorkItem(key="victim", spec=("call", _hang_until_killed,
                                         (str(marker_dir),), {}))
        )
        for i in range(12):
            mgr.submit(
                WorkItem(key=f"pad{i}", spec=("call", _array_of, (i,), {}))
            )
        pid_file = marker_dir / "pid"
        deadline = time.monotonic() + 30
        while not pid_file.exists():
            assert time.monotonic() < deadline, "hang task never started"
            time.sleep(0.02)
        os.kill(int(pid_file.read_text()), signal.SIGKILL)
        mgr.drain()
        out = mgr.results()
        assert out["victim"] == "fast"
        for i in range(12):
            assert out[f"pad{i}"]["seed"] == i
            assert np.array_equal(
                out[f"pad{i}"]["x"],
                np.random.default_rng(i).standard_normal((8, 8)),
            )
        assert mgr.heartbeat_expiries >= 1
        # nothing the dead worker left behind may be torn: every committed
        # key resolves, from the live mount and from a fresh one
        live = mgr.backend.store
        fresh = SharedStore(64 << 20, disk_dir=mgr.backend.store_dir,
                            writer_id="probe")
        for key in sorted(k for k in live.committed_keys()
                          if k.startswith("rpc:")):
            assert fresh.get(key) is not None, f"torn/missing entry {key}"
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# Async commit: barrier() is the durability point
# ---------------------------------------------------------------------------


def test_drain_barrier_makes_every_staged_result_durable(tmp_path):
    mgr = _mk(tmp_path, 2, enable_backup_tasks=False)
    try:
        for i in range(10):
            mgr.submit(WorkItem(key=f"a{i}", spec=("call", _array_of, (i,), {})))
        mgr.drain()  # calls backend.barrier(): flusher must be empty after
        live = mgr.backend.store
        committed = [k for k in live.committed_keys() if k.startswith("rpc:")]
        assert len(committed) >= 10
        fresh = SharedStore(64 << 20, disk_dir=mgr.backend.store_dir,
                            writer_id="probe")
        for key in committed:
            got, want = fresh.get(key), live.get(key)
            assert got is not None
            if isinstance(want, dict) and "x" in want:
                assert np.array_equal(got["x"], want["x"])
        stats = mgr.backend.stats()
        assert stats["flusher"]["pending"] == 0
        assert stats["flusher"]["errors"] == 0
        assert stats["flusher"]["committed"] == stats["flusher"]["staged"]
    finally:
        mgr.close()


def test_barrier_is_truthful_noop_with_async_off(tmp_path):
    mgr = _mk(tmp_path, 1, backend_kwargs={"async_commit": False},
              enable_backup_tasks=False)
    try:
        mgr.submit(WorkItem(key="k", spec=("call", _array_of, (5,), {})))
        mgr.drain()
        assert mgr.backend.barrier(timeout=1.0) is True
        # sync mode: committed before the ack, no staging tier at all
        committed = [k for k in mgr.backend.store.committed_keys()
                     if k.startswith("rpc:")]
        assert committed
        assert "flusher" not in mgr.backend.stats()
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# Warm plan caches: identical recipes re-install as a dictionary hit
# ---------------------------------------------------------------------------


def _poll_worker_stat(backend, key, minimum, deadline_s=10.0):
    deadline = time.monotonic() + deadline_s
    while time.monotonic() < deadline:
        if backend.stats().get("worker", {}).get(key, 0) >= minimum:
            return True
        time.sleep(0.05)
    return False


def test_warm_plan_cache_hits_on_identical_recipe(tmp_path):
    rng = random.Random(777)
    layout, names, cards = random_layout(rng, max_stages=2)
    wf = workflow_from_layout(layout)
    sets = random_param_sets(rng, names, cards, 6)
    inputs = [3, 8]
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=3)
    backend = ProcessRpcBackend(
        build=mix_study_build,
        build_kwargs={"layout": layout, "inputs": inputs},
        store_dir=str(tmp_path / "store"),
        heartbeat_interval=0.05,
    )
    mgr = Manager(backend=backend, enable_backup_tasks=False)
    mgr.start(1)
    try:
        s1 = execute_study(plan, inputs, manager=mgr, key_prefix="a:")
        s2 = execute_study(plan, inputs, manager=mgr, key_prefix="b:")
        # identical results either way — the warm hit is pure reuse
        assert s1.outputs == s2.outputs
        # the second install of the SAME recipe must be a cache hit, and
        # must not have rebuilt the plan (worker stats ride heartbeats)
        assert _poll_worker_stat(backend, "plan_hits", 1), backend.stats()
        w = backend.stats()["worker"]
        assert w.get("plan_builds", 0) == 1
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# Deferred-forget resubmission: a stale memo must not swallow a new lifecycle
# ---------------------------------------------------------------------------


def test_resubmit_after_deferred_forget_starts_a_new_lifecycle():
    """A key forgotten while a losing attempt still holds a lease keeps its
    memo for first-completion-wins dedup (the deferred-forget set).
    Resubmitting that key must start a NEW lifecycle — historically it was
    a silent no-op against the stale memo, so a shared session reusing
    work keys across rounds returned the PREVIOUS round's value and the
    new round's stage never closed (the flaky rpc-benchmark KeyError).

    The stranded lease's late completion must not settle the new lifecycle
    either: its lease id is orphaned and dropped on arrival.
    """
    release = threading.Event()
    calls = {"n": 0}
    guard = threading.Lock()

    def flaky_straggler():
        with guard:
            calls["n"] += 1
            first = calls["n"] == 1
        if first:  # the original attempt stalls; the backup clone wins
            release.wait(30.0)
            return "old-straggler"
        return "old-backup"

    got = []
    mgr = Manager(straggler_factor=1.0, heartbeat_timeout=60.0)
    mgr.start(2)
    try:
        # two quick pads give the straggler detector the >=2 duration
        # samples it needs before it will clone anything
        for i in range(2):
            mgr.submit(WorkItem(key=f"pad{i}", fn=lambda i=i: _quick(i)))
        mgr.submit(WorkItem(key="K", fn=flaky_straggler))
        mgr.drain()
        assert mgr.results()["K"] == "old-backup"
        assert mgr.backups_launched >= 1
        # forget K while the losing original still holds its lease: the
        # memo is retained (deferred forget), not released
        mgr.forget(["K"])
        # resubmit the same key — a new lifecycle with a new value
        mgr.submit(WorkItem(key="K", fn=lambda: "new",
                            callback=lambda k, v: got.append(v)))
        mgr.drain()
        assert mgr.results()["K"] == "new"
        assert got == ["new"]
        # release the stranded original: its completion must be dropped,
        # never resurrecting the old lifecycle's value
        release.set()
        deadline = time.monotonic() + 10.0
        while mgr._orphaned and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not mgr._orphaned
        assert mgr.results()["K"] == "new"
        assert got == ["new"]
    finally:
        release.set()
        mgr.close()

"""Unit + property tests for the reuse trie, RTMA bucketing and RMSR scheduling."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import (
    Param,
    ParamSpace,
    StageSpec,
    TaskSpec,
    Workflow,
    build_reuse_tree,
    bucket_reuse_stats,
    execute_merged_stage,
    halton_sequence,
    latin_hypercube,
    min_active_paths,
    morris_trajectories,
    reuse_stats,
    rmsr_schedule,
    rtma_buckets,
    simulate_execution,
    stage_level_dedup,
    tree_peak_bytes,
)

BYTES = 100


def make_stage(n_tasks=3, bytes_per_task=BYTES):
    tasks = tuple(
        TaskSpec(
            name=f"t{i}",
            param_names=(f"p{i}",),
            fn=lambda x, **kw: x + sum(v for v in kw.values()),
            cost=1.0,
            output_bytes=bytes_per_task,
        )
        for i in range(n_tasks)
    )
    return StageSpec(name="seg", tasks=tasks)


def make_space(n_tasks=3, card=3):
    return ParamSpace.from_dict({f"p{i}": list(range(card)) for i in range(n_tasks)})


def instances_for(stage, space, n, sampler="halton", seed=0):
    if sampler == "halton":
        pts = halton_sequence(n, space.dim)
    else:
        pts = latin_hypercube(n, space.dim, seed=seed)
    sets = space.quantise(pts)
    wf = Workflow(stages=(stage,))
    return wf.instantiate(sets)[stage.name], sets


class TestReuseTree:
    def test_identical_instances_collapse(self):
        stage = make_stage()
        space = make_space(card=1)  # single-value grids -> all runs identical
        insts, _ = instances_for(stage, space, 8)
        tree = build_reuse_tree(stage, insts)
        assert tree.unique_task_count() == len(stage.tasks)
        stats = reuse_stats(stage, insts)
        assert stats["reuse_fraction"] == pytest.approx(1 - 3 / 24)

    def test_disjoint_instances_no_reuse(self):
        stage = make_stage(n_tasks=1)
        space = ParamSpace.from_dict({"p0": list(range(100))})
        sets = [(("p0", i),) for i in range(10)]
        wf = Workflow(stages=(stage,))
        insts = wf.instantiate(sets)[stage.name]
        assert reuse_stats(stage, insts)["reuse_fraction"] == 0.0

    def test_prefix_sharing_counts(self):
        stage = make_stage(n_tasks=2)
        sets = [(("p0", 0), ("p1", 0)), (("p0", 0), ("p1", 1))]
        wf = Workflow(stages=(stage,))
        insts = wf.instantiate(sets)[stage.name]
        tree = build_reuse_tree(stage, insts)
        # shared first task + two distinct second tasks = 3 nodes, not 4
        assert tree.unique_task_count() == 3

    def test_stage_level_dedup(self):
        stage = make_stage()
        space = make_space(card=2)
        insts, _ = instances_for(stage, space, 16)
        reps, mapping = stage_level_dedup(insts)
        assert len(reps) <= 2**3
        assert set(mapping.keys()) == {i.run_id for i in insts}


class TestRTMA:
    def test_bucket_cover_exact(self):
        stage = make_stage()
        space = make_space()
        insts, _ = instances_for(stage, space, 40)
        for b in (1, 2, 4, 7, 40):
            buckets = rtma_buckets(stage, insts, b)
            rids = sorted(i.run_id for bk in buckets for i in bk.instances)
            assert rids == sorted(i.run_id for i in insts)  # partition
            assert all(len(bk.instances) <= b for bk in buckets)

    def test_bigger_buckets_more_reuse(self):
        stage = make_stage()
        space = make_space()
        insts, _ = instances_for(stage, space, 60)
        fracs = []
        for b in (1, 2, 4, 8, 60):
            st_ = bucket_reuse_stats(stage, rtma_buckets(stage, insts, b))
            fracs.append(st_["reuse_fraction"])
        assert fracs == sorted(fracs)  # monotone non-decreasing
        assert fracs[0] == 0.0
        # full merge equals the perfect-reuse upper bound
        assert fracs[-1] == pytest.approx(reuse_stats(stage, insts)["reuse_fraction"])


class TestRMSR:
    def test_depth_first_memory_constant_in_bucket_size(self):
        """The paper's core claim: RMSR peak memory is independent of the
        number of merged instances, while RTMA's grows with it."""
        stage = make_stage()
        space = make_space(card=4)
        rtma_peaks, rmsr_peaks = [], []
        for n in (8, 32, 64):
            insts, _ = instances_for(stage, space, n)
            tree = build_reuse_tree(stage, insts)
            rtma_peaks.append(tree_peak_bytes(tree))  # breadth-eligible
            rmsr_peaks.append(rmsr_schedule(tree, active_paths=1).peak_bytes)
        assert rtma_peaks[-1] > rtma_peaks[0]
        assert max(rmsr_peaks) <= 3 * BYTES + BYTES  # ≤ depth+1 buffers
        assert rmsr_peaks[-1] <= rmsr_peaks[0] + BYTES

    def test_active_paths_bounds_memory(self):
        stage = make_stage(n_tasks=4)
        space = make_space(n_tasks=4, card=4)
        insts, _ = instances_for(stage, space, 64)
        tree = build_reuse_tree(stage, insts)
        peaks = [rmsr_schedule(tree, p).peak_bytes for p in (1, 2, 4, 8)]
        assert peaks == sorted(peaks)
        # P paths can hold at most ~P*(depth) buffers
        assert peaks[0] <= 5 * BYTES

    def test_min_active_paths(self):
        stage = make_stage()
        space = make_space(card=4)
        insts, _ = instances_for(stage, space, 32)
        tree = build_reuse_tree(stage, insts)
        p = min_active_paths(tree, budget_bytes=50 * BYTES)
        assert p is not None and p >= 1
        assert rmsr_schedule(tree, p).peak_bytes <= 50 * BYTES

    def test_schedule_is_topological_and_complete(self):
        stage = make_stage()
        space = make_space()
        insts, _ = instances_for(stage, space, 25)
        tree = build_reuse_tree(stage, insts)
        res = rmsr_schedule(tree, active_paths=3)
        seen = set()
        for node in res.order:
            if node.parent is not None and node.parent.depth >= 0:
                assert node.parent.uid in seen
            seen.add(node.uid)
        assert len(res.order) == tree.unique_task_count()

    def test_execute_merged_stage_matches_naive(self):
        """Reused execution must produce bit-identical results to naive
        per-run execution (reuse is an optimization, not an approximation)."""
        stage = make_stage()
        space = make_space(card=3)
        insts, sets = instances_for(stage, space, 20)
        tree = build_reuse_tree(stage, insts)
        got = execute_merged_stage(tree, 0.0, active_paths=2)
        for rid, ps in enumerate(sets):
            want = 0.0
            for t in stage.tasks:
                kw = {k: v for k, v in dict(ps).items() if k in t.param_names}
                want = t.fn(want, **kw)
            assert got[rid] == want

    def test_makespan_improves_with_paths(self):
        stage = make_stage(n_tasks=4)
        space = make_space(n_tasks=4, card=4)
        insts, _ = instances_for(stage, space, 64)
        tree = build_reuse_tree(stage, insts)
        m1 = simulate_execution(tree, 1).makespan
        m8 = simulate_execution(tree, 8).makespan
        assert m8 < m1


class TestSamplers:
    def test_halton_in_unit_cube(self):
        pts = halton_sequence(100, 5)
        assert pts.shape == (100, 5)
        assert (pts >= 0).all() and (pts < 1).all()

    def test_lhs_stratification(self):
        pts = latin_hypercube(50, 3, seed=1)
        for j in range(3):
            strata = np.floor(pts[:, j] * 50).astype(int)
            assert len(set(strata.tolist())) == 50

    def test_morris_one_at_a_time(self):
        space = make_space(n_tasks=4, card=5)
        sets, moves = morris_trajectories(space, 3, seed=0)
        assert len(sets) == 3 * (4 + 1)
        for traj in moves:
            for run_idx, pname in traj:
                prev, cur = dict(sets[run_idx - 1]), dict(sets[run_idx])
                diff = [k for k in cur if cur[k] != prev[k]]
                assert diff == [pname] or diff == []  # exactly one param moved


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=40),
    b=st.integers(min_value=1, max_value=12),
    card=st.integers(min_value=1, max_value=4),
    n_tasks=st.integers(min_value=1, max_value=5),
)
def test_property_bucketing_partition_and_reuse_bounds(n, b, card, n_tasks):
    """Invariants: RTMA partitions instances; reuse fraction within [0, upper
    bound]; RMSR executes every unique task exactly once."""
    stage = make_stage(n_tasks=n_tasks)
    space = make_space(n_tasks=n_tasks, card=card)
    insts, _ = instances_for(stage, space, n)
    buckets = rtma_buckets(stage, insts, b)
    rids = sorted(i.run_id for bk in buckets for i in bk.instances)
    assert rids == list(range(n))
    st_bucket = bucket_reuse_stats(stage, buckets)
    st_full = reuse_stats(stage, insts)
    assert -1e-9 <= st_bucket["reuse_fraction"] <= st_full["reuse_fraction"] + 1e-9
    tree = build_reuse_tree(stage, insts)
    res = rmsr_schedule(tree, active_paths=max(1, b))
    assert len(res.order) == tree.unique_task_count()


@settings(max_examples=20, deadline=None)
@given(p=st.integers(min_value=1, max_value=16))
def test_property_rmsr_peak_monotone_in_paths(p):
    stage = make_stage(n_tasks=3)
    space = make_space(n_tasks=3, card=3)
    insts, _ = instances_for(stage, space, 27)
    tree = build_reuse_tree(stage, insts)
    r1 = rmsr_schedule(tree, p)
    r2 = rmsr_schedule(tree, p + 1)
    assert r2.peak_bytes >= r1.peak_bytes - 1e-9
    assert r2.makespan <= r1.makespan + 1e-9

"""Elastic re-mesh (multi-device, subprocess) + gradient compression tests."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_compression import compress_decompress


class TestCompressionNumerics:
    def test_bf16_roundtrip_error_small(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1e-2, (256,)).astype(np.float32))
        out = compress_decompress(g, "bf16")
        assert float(jnp.max(jnp.abs(out - g))) < 1e-4

    def test_int8_relative_error_bounded(self):
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(0, 1.0, (512,)).astype(np.float32))
        out = compress_decompress(g, "int8")
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(out - g))) <= scale * 0.5 + 1e-6


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from repro.dist.sharding import make_ctx, param_shardings
from repro.launch.mesh import make_mesh_from_devices
from repro.models import init_params
from repro.runtime.elastic import resume_on_mesh, reshard_tree

cfg = reduced_config(get_config("yi_6b"))
params = init_params(cfg, jax.random.key(0))

# "run" on a 4x2 mesh, checkpoint
mesh_a = make_mesh_from_devices((4, 2), ("data", "model"))
ctx_a = make_ctx(mesh_a, mode="train")
pa = reshard_tree(params, param_shardings(params, ctx_a))
d = tempfile.mkdtemp()
ck = Checkpointer(d)
ck.save(7, pa, metadata={"note": "pre-failure"})

# "lose" half the devices -> resume on a 2x2 mesh
mesh_b = make_mesh_from_devices((2, 2), ("data", "model"), jax.devices()[:4])
pb, meta = resume_on_mesh(ck, params, mesh_b, mode="train")
assert meta["note"] == "pre-failure"
for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# shardings actually live on the new mesh
leaf = jax.tree.leaves(pb)[1]
assert leaf.sharding.mesh.shape == {"data": 2, "model": 2}
# and the model still steps
from repro.launch.steps import make_train_step
from repro.optim import OptConfig, adamw_init
ctx_b = make_ctx(mesh_b, mode="train")
step = jax.jit(make_train_step(cfg, ctx_b, OptConfig()))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 100, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 100, (4, 16)), jnp.int32)}
with mesh_b:
    p2, o2, m = step(pb, adamw_init(pb), batch)
assert bool(jnp.isfinite(m["loss"]))
print("ELASTIC_OK", float(m["loss"]))
"""


def test_elastic_remesh_resume():
    """Full elastic story in a subprocess with 8 host devices: checkpoint on
    a 4×2 mesh, lose half the devices, resume + train-step on 2×2."""
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
